#!/usr/bin/env python
"""Hot-path lint: structural regressions the test suite can't catch.

The mask pipeline's whole point (PR 4) is that the serving tick never
materializes dense ``(B, V)`` data on the host: masks stay packed uint32
end to end and the fused kernel unpacks in-register.  Nothing functional
breaks if someone reintroduces a dense staging array or a
``bitmask.unpack`` call on the tick path — output is identical, only 8x
slower on the mask bytes — so tests stay green while the paper's headline
property quietly rots.  This linter fails CI instead.

Rules (AST-based, stdlib only):

  R1  no dense >=2-D array allocation (``np.zeros((B, V))``-style, or
      ``np.tile``) inside the scheduler's tick-path functions or the
      masked-sample dispatch module;
  R2  no ``unpack(...)`` calls in those same scopes (packed masks must
      reach the kernel packed);
  R3  no wall-clock/global-RNG nondeterminism in ``src/repro/core/``:
      ``time.time``/``datetime.now``/``datetime.utcnow``, module-level
      ``random.*`` draws, or ``np.random.*`` (``time.perf_counter`` /
      ``time.monotonic`` are fine — they feed timing *stats*, not
      decisions; per-request ``np.random.Generator`` objects are created
      outside core/ and passed in);
  R4  no swallowed exceptions in ``src/repro/serving/``: a bare
      ``except:`` or a handler whose body is only ``pass``/``...``
      hides a failure that the fault-tolerance layer (PR 7) must map to
      an explicit per-request terminal status (``internal_error``,
      ``rejected``, ...) — silent constraint-engine failures corrupt
      downstream results without a trace;
  R5  no file-sync calls (``fsync``/``flush``/``commit_tick``/``sync``)
      inside the tick-path functions: the crash journal (PR 9) buffers
      during tick phases and does ALL its file I/O in ``_journal_tick``
      at the tick boundary — an fsync on the per-token path serializes
      decode on disk latency, which is exactly the overhead the batched
      write-ahead design exists to avoid;
  R6  no radix-tree mutation or checker-state serialization inside
      tick-path functions: prefix-cache traffic
      (``self.prefix_cache.insert/lookup/put_checker/get_checker`` and
      checker ``snapshot()`` calls) belongs at admission/teardown
      boundaries (``_admit``/``_finish``/``_preempt``/``adopt``) — a
      tree walk or a hypothesis-set fork per token would put O(prefix)
      host work back on the per-token path the cache exists to shorten.
      Only ``evict()``/``evictable()`` may run from tick functions
      (``_ensure_pages`` reclaims cache-only pages under pool pressure).

A finding is suppressed by putting ``# hotpath-lint: allow`` on the
offending physical line (or the line above it).  Every suppression is a
reviewed, deliberate exception — the scheduler's sampled-row unpack is
the canonical one.

Usage: ``python tools/lint_hotpath.py`` (from the repo root; exits 1 on
violations).  Pass file paths to restrict the run.
"""
from __future__ import annotations

import ast
import os
import sys
from typing import List, Optional, Set, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PRAGMA = "hotpath-lint: allow"

# scheduler functions on the per-token serving critical path (admission /
# teardown helpers deliberately excluded — they may allocate)
TICK_FUNCS: Set[str] = {
    "step", "_verify_width", "_reset_vacant_lens", "_checker_bits",
    "_prebuild_masks", "_choose", "_commit_first", "_run_decode",
    "_plain_step", "_spec_step", "_verify_row", "_fixup_refeed",
    "_ensure_pages", "_shrink_pages", "_sync_pages", "_reap",
    # device-resident fused loop (PR 8): the whole point is per-BLOCK
    # host sync, so its tick functions must not smuggle dense host
    # staging or unpacks back in (_build_fused is excluded — it runs
    # once, at trace time, not per tick)
    "_device_step", "_resync_row", "_sid_for", "_device_ready",
    "_advance_sid", "_audit_sid",
    # durability (PR 9): these run inside tick phases and may only
    # BUFFER journal records — _journal_tick (the designed tick-boundary
    # flush point) is deliberately NOT in this set
    "_journal_submit", "_journal_commit", "_deadline_cap",
}

ALLOC_FUNCS = {"zeros", "ones", "empty", "full", "tile"}
# R6: the only prefix-cache operations a tick function may invoke
# (allocation-pressure reclaim); everything else is boundary-only
PREFIX_CACHE_TICK_OK = {"evict", "evictable"}
# R5: journal/file-sync entry points banned from tick-path functions
SYNC_BANNED = {"fsync", "flush", "commit_tick", "sync"}
CLOCK_BANNED = {("time", "time"), ("datetime", "now"),
                ("datetime", "utcnow"), ("datetime", "today")}
RANDOM_FUNCS = {"random", "randint", "choice", "choices", "shuffle",
                "uniform", "seed", "randrange", "sample"}


class Finding:
    def __init__(self, path: str, line: int, rule: str, msg: str):
        self.path, self.line, self.rule, self.msg = path, line, rule, msg

    def __str__(self) -> str:
        rel = os.path.relpath(self.path, REPO)
        return f"{rel}:{self.line}: [{self.rule}] {self.msg}"


def _has_pragma(lines: List[str], lineno: int) -> bool:
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and PRAGMA in lines[ln - 1]:
            return True
    return False


def _call_name(node: ast.Call) -> Tuple[Optional[str], Optional[str]]:
    """('np', 'zeros') for np.zeros(...), (None, 'unpack') for unpack(...)."""
    f = node.func
    if isinstance(f, ast.Attribute):
        if isinstance(f.value, ast.Name):
            return f.value.id, f.attr
        if isinstance(f.value, ast.Attribute) \
                and isinstance(f.value.value, ast.Name):
            # e.g. np.random.randint -> ('np.random', 'randint')
            return f"{f.value.value.id}.{f.value.attr}", f.attr
        return None, f.attr
    if isinstance(f, ast.Name):
        return None, f.id
    return None, None


def _is_dense_shape(arg: ast.expr) -> bool:
    """Shape literal with >=2 dims (tuple/list of 2+ elements)."""
    return isinstance(arg, (ast.Tuple, ast.List)) and len(arg.elts) >= 2


def _check_hot_scope(tree_nodes, path: str, lines: List[str],
                     where: str) -> List[Finding]:
    out: List[Finding] = []
    for node in tree_nodes:
        if not isinstance(node, ast.Call):
            continue
        if _has_pragma(lines, node.lineno):
            continue
        base, name = _call_name(node)
        if name in ALLOC_FUNCS and base in ("np", "jnp", "numpy", "jax"):
            dense = (name == "tile"
                     and len(node.args) >= 2 and _is_dense_shape(node.args[1])
                     ) or (name != "tile" and node.args
                           and _is_dense_shape(node.args[0]))
            if dense:
                out.append(Finding(
                    path, node.lineno, "R1",
                    f"dense >=2-D allocation {base}.{name}(...) in "
                    f"{where} — the tick path must stay packed "
                    f"(ceil(V/32) uint32 words per row, reused buffers)"))
        if name == "unpack":
            out.append(Finding(
                path, node.lineno, "R2",
                f"unpack(...) call in {where} — packed masks must reach "
                f"the fused kernel packed; unpacking on the host "
                f"re-creates the dense (B, V) traffic PR 4 removed"))
        if name in SYNC_BANNED:
            out.append(Finding(
                path, node.lineno, "R5",
                f"file-sync call {name}(...) in {where} — journal I/O "
                f"must batch at the tick boundary (_journal_tick); an "
                f"fsync/flush on the per-token path serializes decode "
                f"on disk latency"))
        if (base == "self.prefix_cache"
                and name not in PREFIX_CACHE_TICK_OK) or name == "snapshot":
            out.append(Finding(
                path, node.lineno, "R6",
                f"prefix-cache/checker-state call {name}(...) in {where} "
                f"— radix-tree mutation and checker serialization belong "
                f"at admission/teardown boundaries (_admit/_finish/"
                f"_preempt/adopt), never on the per-token tick path; "
                f"only evict()/evictable() may run under pool pressure"))
    return out


def _lint_named_funcs(path: str, names: Set[str],
                      label: str) -> List[Finding]:
    with open(path) as f:
        src = f.read()
    lines = src.splitlines()
    tree = ast.parse(src, path)
    out: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name in names:
            out.extend(_check_hot_scope(
                ast.walk(node), path, lines,
                f"{label} {node.name}()"))
    return out


def lint_scheduler(path: str) -> List[Finding]:
    return _lint_named_funcs(path, TICK_FUNCS, "tick-path function")


# engine functions the scheduler tick reaches (speculative _verify_row
# calls eng._pick per rejected position): same packed-mask rules apply
ENGINE_HOT_FUNCS: Set[str] = {"_pick"}


def lint_engine(path: str) -> List[Finding]:
    return _lint_named_funcs(path, ENGINE_HOT_FUNCS,
                             "engine hot function")


def lint_kernel_dispatch(path: str) -> List[Finding]:
    with open(path) as f:
        src = f.read()
    lines = src.splitlines()
    tree = ast.parse(src, path)
    return _check_hot_scope(ast.walk(tree), path, lines,
                            "masked-sample dispatch")


def lint_core_determinism(path: str) -> List[Finding]:
    with open(path) as f:
        src = f.read()
    lines = src.splitlines()
    tree = ast.parse(src, path)
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _has_pragma(lines, node.lineno):
            continue
        base, name = _call_name(node)
        if (base, name) in CLOCK_BANNED:
            out.append(Finding(
                path, node.lineno, "R3",
                f"wall-clock call {base}.{name}() in core/ — grammar "
                f"state must be reproducible; use time.perf_counter() "
                f"for timing stats only"))
        if base in ("random",) and name in RANDOM_FUNCS:
            out.append(Finding(
                path, node.lineno, "R3",
                f"global-RNG call random.{name}() in core/ — draw from "
                f"an explicitly seeded np.random.Generator passed in by "
                f"the caller"))
        if base in ("np.random", "numpy.random") and name != "default_rng":
            out.append(Finding(
                path, node.lineno, "R3",
                f"global numpy RNG call {base}.{name}() in core/ — "
                f"module-level RNG state makes decode output depend on "
                f"call order; accept a Generator argument instead"))
    return out


def _swallows(handler: ast.ExceptHandler) -> bool:
    """Handler body does nothing but pass / ``...`` (a swallowed
    exception)."""
    for stmt in handler.body:
        if isinstance(stmt, ast.Pass):
            continue
        if isinstance(stmt, ast.Expr) \
                and isinstance(stmt.value, ast.Constant) \
                and stmt.value.value is Ellipsis:
            continue
        return False
    return True


def lint_serving_excepts(path: str) -> List[Finding]:
    with open(path) as f:
        src = f.read()
    lines = src.splitlines()
    tree = ast.parse(src, path)
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if _has_pragma(lines, node.lineno):
            continue
        if node.type is None:
            out.append(Finding(
                path, node.lineno, "R4",
                "bare `except:` in serving/ — catches SystemExit/"
                "KeyboardInterrupt too; catch Exception and map the "
                "failure to an explicit per-request terminal status"))
        elif _swallows(node):
            out.append(Finding(
                path, node.lineno, "R4",
                "swallowed exception (handler body is only pass/...) in "
                "serving/ — a failure here must surface as a request "
                "status (internal_error / rejected), never vanish"))
    return out


def main(argv: List[str]) -> int:
    if argv:
        targets = [os.path.abspath(a) for a in argv]
    else:
        targets = None
    sched = os.path.join(REPO, "src", "repro", "serving", "scheduler.py")
    engine = os.path.join(REPO, "src", "repro", "serving", "engine.py")
    dispatch = os.path.join(REPO, "src", "repro", "kernels",
                            "masked_sample", "ops.py")
    core_dir = os.path.join(REPO, "src", "repro", "core")
    serving_dir = os.path.join(REPO, "src", "repro", "serving")

    findings: List[Finding] = []
    if targets is None or sched in targets:
        findings.extend(lint_scheduler(sched))
    if targets is None or engine in targets:
        findings.extend(lint_engine(engine))
    if targets is None or dispatch in targets:
        findings.extend(lint_kernel_dispatch(dispatch))
    for fn in sorted(os.listdir(core_dir)):
        path = os.path.join(core_dir, fn)
        if fn.endswith(".py") and (targets is None or path in targets):
            findings.extend(lint_core_determinism(path))
    for fn in sorted(os.listdir(serving_dir)):
        path = os.path.join(serving_dir, fn)
        if fn.endswith(".py") and (targets is None or path in targets):
            findings.extend(lint_serving_excepts(path))

    for f in findings:
        print(f)
    if findings:
        print(f"\n{len(findings)} hot-path lint violation(s)",
              file=sys.stderr)
        return 1
    print("hot-path lint: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
