#!/usr/bin/env python
"""Kill -9 mid-run restart smoke: crash-consistency end to end.

Each drill is three subprocess runs of the serve driver (same
deterministic engine: seeded tokenizer corpus + PRNGKey(0) init):

  1. reference — fault-free run, ``--print-ids`` captures the greedy
     token ids per request;
  2. crash — same workload with ``--journal`` armed and
     ``--crash-after-syncs K``: the TokenJournal SIGKILLs the process
     (no atexit, no flush — a real crash) right after its K-th fsync,
     mid-decode;
  3. restore — ``--restore --journal``: replays the journal, resumes
     every live request from its validated committed prefix, and must
     print IDS lines bitwise-identical to the reference run.

The drill runs TWICE: once with the baseline workload, and once with
``--prefix-cache`` over a workload whose prompts repeat (a small page
size makes whole-page prefix hits certain), so restore exercises the
cache-warm path — restored admissions re-acquire shared pages through
the radix cache and adopt fork-point checker snapshots, and must STILL
be bitwise-identical to the (equally cache-enabled) reference.

The smoke fails if a crash run does NOT die by SIGKILL (workload too
small for K syncs), if restore errors, or if any row's ids differ.

Usage: python tools/restart_smoke.py [--device-loop] [--keep]
(repo root; needs PYTHONPATH=src semantics handled internally).
"""
import argparse
import os
import signal
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

WORKLOAD = ["--grammar", "json", "--mode", "domino", "--prompts", "3",
            "--max-tokens", "16", "--slots", "2", "--seed", "0"]

# cache-warm drill: 5 prompts over the 4-entry base-prompt cycle, so at
# least one prompt repeats verbatim; page size 8 keeps whole pages well
# inside the short prompts (argparse takes the LAST occurrence, so these
# override the baseline workload's values)
WARM_EXTRA = ["--prompts", "5", "--page-size", "8", "--prefix-cache"]


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _run(extra, check_rc=0):
    cmd = [sys.executable, "-m", "repro.launch.serve"] + WORKLOAD + extra
    print(f"[restart-smoke] $ {' '.join(cmd)}", flush=True)
    p = subprocess.run(cmd, cwd=REPO, env=_env(),
                       stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                       text=True)
    sys.stdout.write(p.stdout)
    if check_rc is not None and p.returncode != check_rc:
        raise SystemExit(f"[restart-smoke] FAIL: rc={p.returncode}, "
                         f"expected {check_rc}")
    return p


def _ids(out: str):
    rows = {}
    for ln in out.splitlines():
        if ln.startswith("IDS "):
            parts = ln.split()
            rows[int(parts[1])] = [int(t) for t in parts[2:]]
    return rows


def _drill(extra, crash_after_syncs, keep, label):
    """One reference -> crash -> restore cycle; returns restored rows."""
    ref = _run(extra + ["--print-ids"])
    want = _ids(ref.stdout)
    if not want or not any(want.values()):
        raise SystemExit(f"[restart-smoke] FAIL({label}): reference run "
                         f"produced no token ids")

    fd, journal = tempfile.mkstemp(prefix="restart_smoke_",
                                   suffix=".journal")
    os.close(fd)
    os.unlink(journal)                  # serve creates it fresh
    try:
        crash = _run(extra + ["--journal", journal, "--crash-after-syncs",
                              str(crash_after_syncs)],
                     check_rc=None)
        if crash.returncode != -signal.SIGKILL:
            raise SystemExit(
                f"[restart-smoke] FAIL({label}): crash run exited rc="
                f"{crash.returncode}, expected SIGKILL "
                f"(-{int(signal.SIGKILL)}) — workload finished before "
                f"{crash_after_syncs} journal syncs?")
        if not os.path.exists(journal) or not os.path.getsize(journal):
            raise SystemExit(f"[restart-smoke] FAIL({label}): crashed "
                             f"run left no journal bytes")

        rest = _run(extra + ["--restore", "--journal", journal,
                             "--print-ids"])
        got = _ids(rest.stdout)
        if got != want:
            for rid in sorted(set(want) | set(got)):
                a, b = want.get(rid), got.get(rid)
                mark = "ok" if a == b else "MISMATCH"
                print(f"[restart-smoke] rid {rid}: {mark}\n"
                      f"  reference: {a}\n  restored:  {b}")
            raise SystemExit(f"[restart-smoke] FAIL({label}): restored "
                             f"output is not bitwise-identical to the "
                             f"reference")
    finally:
        if keep:
            print(f"[restart-smoke] journal kept at {journal}")
        elif os.path.exists(journal):
            os.unlink(journal)
    print(f"[restart-smoke] {label}: SIGKILL after {crash_after_syncs} "
          f"syncs, {len(want)} request(s) restored bitwise-identical")
    return want


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--device-loop", action="store_true",
                    help="route certified rows through the fused "
                         "device loop in all runs")
    ap.add_argument("--crash-after-syncs", type=int, default=4)
    ap.add_argument("--keep", action="store_true",
                    help="keep the journal files for inspection")
    args = ap.parse_args()
    dev = ["--device-loop"] if args.device_loop else []

    base = _drill(dev, args.crash_after_syncs, args.keep, "base")
    warm = _drill(dev + WARM_EXTRA, args.crash_after_syncs, args.keep,
                  "prefix-cache")
    print(f"[restart-smoke] OK: base ({len(base)} requests) and "
          f"prefix-cache ({len(warm)} requests) drills both restored "
          f"bitwise-identical")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
