"""End-to-end system behaviour: train a ~1M-param model on the arithmetic
JSON task for a handful of steps, then serve it constrained and verify (a)
outputs stay grammar-valid, (b) DOMINO does not change what an already-
compliant model would produce (the §2 invasiveness claim, at smoke scale),
(c) the speculative path is output-identical while using fewer forwards."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import grammars
from repro.core.domino import DominoDecoder
from repro.models import build_model
from repro.serving import EngineConfig, ServingEngine
from repro.training import optimizer as opt
from repro.training.data import TaskDataset
from repro.training.train_loop import make_train_step


@pytest.fixture(scope="module")
def trained(request):
    tok = request.getfixturevalue("small_tokenizer")
    cfg = ModelConfig(arch_id="sys", family="dense", n_layers=2, d_model=96,
                      n_heads=4, n_kv_heads=4, d_ff=192,
                      vocab_size=tok.vocab_size, dtype="float32",
                      max_seq_len=512)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    step = make_train_step(m, opt.AdamWConfig(lr=3e-3, schedule="wsd",
                                              warmup_steps=5,
                                              total_steps=60))
    state = opt.init_state(params)
    data = TaskDataset(tok, seq_len=160, few_shot=1).batches(8)
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    return m, params, tok, losses


def test_training_reduces_loss(trained):
    _, _, _, losses = trained
    assert losses[-1] < losses[0] * 0.7, (losses[0], losses[-1])


def test_constrained_output_valid(trained):
    m, params, tok, _ = trained
    g = grammars.load("json_gsm8k")
    eng = ServingEngine(m, params, tok, g,
                        EngineConfig(mode="domino", max_tokens=48),
                        max_len=512)
    r = eng.generate('Q: compute 3 + 4\nA: ')
    d = DominoDecoder(g, list(tok.vocab), tok.eos_id)
    for t in r.token_ids:
        assert d.advance(t)
    if r.finished:
        assert d.eos_legal()


def test_speculation_output_identical_fewer_forwards(trained):
    m, params, tok, _ = trained
    g = grammars.load("json_gsm8k")
    plain = ServingEngine(m, params, tok, g,
                          EngineConfig(mode="domino", max_tokens=40),
                          max_len=512)
    r0 = plain.generate('Q: compute 5 + 2\nA: ')
    spec = ServingEngine(m, params, tok, g,
                         EngineConfig(mode="domino", speculative=True,
                                      spec_s=8, spec_threshold=0.4,
                                      max_tokens=40), max_len=512)
    spec.generate('Q: compute 5 + 2\nA: ')     # prior formation
    r1 = spec.generate('Q: compute 5 + 2\nA: ')
    assert r1.token_ids == r0.token_ids
    assert r1.n_forward_passes <= r0.n_forward_passes


def test_domino_noninvasive_vs_unconstrained_when_valid(trained):
    """If the unconstrained model emits a valid prefix, DOMINO(k=inf) must
    pick the same tokens over that prefix (Def. 2.1 at smoke scale)."""
    m, params, tok, _ = trained
    g = grammars.load("json_gsm8k")
    un = ServingEngine(m, params, tok, None,
                       EngineConfig(mode="unconstrained", max_tokens=32),
                       max_len=512)
    ru = un.generate('Q: compute 6 + 3\nA: ')
    # measure the longest grammar-valid prefix of the unconstrained output
    d = DominoDecoder(g, list(tok.vocab), tok.eos_id)
    valid_prefix = 0
    for t in ru.token_ids:
        if not d.advance(t):
            break
        valid_prefix += 1
    co = ServingEngine(m, params, tok, g,
                       EngineConfig(mode="domino", max_tokens=32),
                       max_len=512)
    rc = co.generate('Q: compute 6 + 3\nA: ')
    assert rc.token_ids[:valid_prefix] == ru.token_ids[:valid_prefix]
