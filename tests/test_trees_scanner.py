"""Scanner traversal + subterminal tree construction (Alg. 2)."""
import pytest

from repro.core import grammars
from repro.core.grammar import parse_grammar
from repro.core.scanner import FRESH, Scanner
from repro.core.trees import TreeCache, VocabTrie


@pytest.fixture(scope="module")
def arith():
    return parse_grammar(r'''
start: e
e: INT | "(" e ")" | e "+" e
INT: /[1-9][0-9]*|0+/
WS: /[ ]+/
%ignore WS
''')


def _tid(g, name):
    return {t.name: i for i, t in enumerate(g.terminals)}[name]


def test_traverse_simple(arith):
    sc = Scanner(arith)
    INT = _tid(arith, "INT")
    PLUS = _tid(arith, "'+'")
    branches = sc.traverse_token(FRESH, b"12")
    kinds = {(ems, pos is FRESH) for ems, pos in branches}
    # "12": still-open INT, or INT completed exactly at the boundary
    assert ((), False) in kinds
    assert ((INT,), True) in kinds


def test_traverse_bridge(arith):
    sc = Scanner(arith)
    INT = _tid(arith, "INT")
    PLUS = _tid(arith, "'+'")
    branches = sc.traverse_token(FRESH, b"1+2")
    ems_set = {ems for ems, pos in branches}
    assert (INT, PLUS) in ems_set
    # with trailing emit-at-end branch:
    assert (INT, PLUS, INT) in ems_set


def test_traverse_ignore_collapsed(arith):
    sc = Scanner(arith)
    INT = _tid(arith, "INT")
    branches = sc.traverse_token(FRESH, b"1 ")   # int then whitespace
    ems_set = {ems for ems, pos in branches}
    assert (INT,) in ems_set                      # WS not in emissions
    assert all(_tid(arith, "WS") not in ems for ems in ems_set)


def test_traverse_dead_token(arith):
    sc = Scanner(arith)
    assert sc.traverse_token(FRESH, b"a") == []


def test_tree_covers_whole_vocab(arith):
    vocab = [bytes([i]) for i in range(256)] + [b"12", b"(1", b"+ 1", None]
    tc = TreeCache(Scanner(arith), vocab)
    tree = tc.tree(FRESH)
    covered = set()

    def rec(node):
        covered.update(node.tokens_fresh)
        for toks in node.tokens_partial.values():
            covered.update(toks)
        for c in node.children.values():
            rec(c)
    rec(tree.root)
    # every byte that can start any terminal must appear somewhere
    legal_first = {i for i in range(256)
                   if tc.scanner.start_moves(i) is not None}
    assert legal_first <= covered
    assert 256 in covered and 257 in covered and 258 in covered


def test_precompute_closure(arith, small_tokenizer):
    tc = TreeCache(Scanner(arith), list(small_tokenizer.vocab))
    stats = tc.precompute()
    assert stats["positions"] >= 2
    # after precompute, no new trees are built on demand for reachable pos
    n = len(tc.trees)
    for pos in list(tc.trees):
        tc.tree(pos)
    assert len(tc.trees) == n


def test_vocab_trie():
    trie = VocabTrie.build([b"ab", b"a", b"abc", None, b""])
    assert trie.children[ord("a")].token_ids == [1]
    assert trie.children[ord("a")].children[ord("b")].token_ids == [0]
    assert trie.count_nodes() == 4
