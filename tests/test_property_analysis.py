"""Property tests tying the STATIC analyzer to RUNTIME decoding
(hypothesis):

1. certification soundness — when the analyzer certifies a random grammar
   trap-free with a finite closure, randomized legal decoding never
   dead-ends;
2. witness validity — every trap the analyzer reports on a seeded-trap
   variant of the grammar reproduces a dead end when its token path is
   replayed through a fresh DominoDecoder;
3. fan-out bound — the runtime hypothesis-set size along any legal decode
   never exceeds the analyzer's reported max fan-out (both measured on
   concrete decoders over the same quotient).
"""
import random

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.analysis import analyze
from repro.core.domino import DominoDecoder
from repro.core.grammar import parse_grammar

TERM_POOL = [
    ('NUM', r'/[0-9]+/'),
    ('ID', r'/[a-z]+/'),
    ('STR', r'/"[a-z]*"/'),
    ('OPA', '"+"'),
    ('LP', '"("'),
    ('RP', '")"'),
    ('COMMA', '","'),
]

VOCAB = [bytes([i]) for i in range(33, 127)] + [
    b"ab", b'("', b'")', b"1,", b",,", b'+(', b"12", b'"a"', b"a1",
    b"((", b"))", None]
EOS = len(VOCAB) - 1


@st.composite
def random_grammar(draw):
    n_terms = draw(st.integers(3, len(TERM_POOL)))
    terms = TERM_POOL[:n_terms]
    lines = [f"{n}: {p}" for n, p in terms]
    names = [n for n, _ in terms]
    shape = draw(st.integers(0, 2))
    a = draw(st.sampled_from(names))
    b = draw(st.sampled_from(names))
    if shape == 0:
        lines.insert(0, f"start: {a} ({b} {a})*")
    elif shape == 1:
        lines.insert(0, f"start: e\ne: {a} | LP e RP" if "LP" in names
                     and "RP" in names else f"start: {a} {b}?")
    else:
        lines.insert(0, f"start: ({a} | {b})+")
    return "\n".join(lines)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_grammar(), st.integers(0, 10000))
def test_certified_trap_free_never_dead_ends(gtext, seed):
    g = parse_grammar(gtext)
    rep = analyze(g, VOCAB, EOS, name="rand", max_states=512)
    if not rep.closure.finite:
        return                          # no certificate claimed: skip
    if rep.n_mask_conflicts:
        # the quotient conflated states with differing masks (e.g. deep
        # center-nesting): the analyzer must DOWNGRADE its own
        # certificate rather than claim trap-freedom
        assert not rep.ok(), gtext
        return
    assert not rep.traps, (gtext, [str(w) for w in rep.traps])
    # the certificate must hold at runtime: randomized legal decoding
    # from the start state never reaches an empty mask
    rng = random.Random(seed)
    d = DominoDecoder(g, VOCAB, EOS)
    for _ in range(16):
        m = d.mask()
        assert m.any(), (gtext, "runtime dead end on certified grammar")
        t = int(rng.choice(np.where(m)[0]))
        assert d.advance(t)
        if t == EOS:
            break


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_grammar(), st.integers(0, 10000))
def test_seeded_trap_witnesses_reproduce(gtext, seed):
    """Append a trap arm to a random grammar: after the normal body, an
    OPEN token leads into a terminal no byte string matches.  The
    analyzer must find reachable traps, and every witness must replay to
    a concrete dead end."""
    trapped = gtext + '\nDEADT: /[^\\x00-\\xff]/\n'
    trapped = trapped.replace("start:", "start: OPEN DEADT |", 1) \
        + 'OPEN: "{"\n'
    g = parse_grammar(trapped)
    rep = analyze(g, VOCAB, EOS, name="trapped", max_states=512)
    assert not rep.ok(), trapped
    assert rep.traps, trapped           # "{" then stuck is reachable
    for w in rep.traps:
        assert w.confirmed, (trapped, str(w))
        d = DominoDecoder(g, VOCAB, EOS)
        for t in w.token_ids:
            assert d.advance(t), (trapped, w.token_ids)
        assert not d.mask_bits().any(), (trapped, w.token_ids)


@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_grammar(), st.integers(0, 10000))
def test_runtime_fanout_within_analyzer_bound(gtext, seed):
    g = parse_grammar(gtext)
    rep = analyze(g, VOCAB, EOS, name="rand", max_states=512)
    if not rep.closure.finite or rep.n_mask_conflicts:
        return      # bound only claimed for clean finite certificates
    rng = random.Random(seed)
    d = DominoDecoder(g, VOCAB, EOS)
    for _ in range(12):
        assert len(d.hyps) <= rep.max_abstract_fanout, gtext
        m = d.mask()
        if not m.any():
            break
        t = int(rng.choice(np.where(m)[0]))
        assert d.advance(t)
        if t == EOS:
            break
    assert d.n_hyp_truncations == 0, gtext
