"""DOMINO decoder: Fig.-3 semantics, lookahead, minimal invasiveness,
equality with the online full-vocab baseline, opportunistic checks."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import grammars
from repro.core.baselines import OnlineParserDecoder, naive_greedy_decoder
from repro.core.domino import DominoDecoder
from repro.core.grammar import parse_grammar
from repro.core.retokenize import greedy_tokenize
from repro.core.sampling import GrammarSampler
from repro.core.scanner import Scanner
from repro.core.trees import TreeCache

FIG3 = parse_grammar(r'''
start: e
e: INT | "(" e ")" | e "+" e
INT: /[1-9][0-9]*|0+/
''')
VOCAB = [b"1", b"2", b"12", b"(", b")", b"+", b"+1", b"1(", b"((", b"))",
         None]
EOS = 10


def names(mask):
    return [VOCAB[i] if VOCAB[i] else b"<EOS>" for i in np.where(mask)[0]]


def test_fig3_start_mask():
    d = DominoDecoder(FIG3, VOCAB, eos_id=EOS)
    assert names(d.mask()) == [b"1", b"2", b"12", b"(", b"(("]
    m0 = d.mask(k=0)
    assert not m0[8], "'((' is a depth-2 bridge, needs k>=1"


def test_fig3_bridge_token_lookahead():
    d = DominoDecoder(FIG3, VOCAB, eos_id=EOS)
    assert d.advance(3) and d.advance(2)        # "(12"
    m, m0, m1 = d.mask(), d.mask(k=0), d.mask(k=1)
    assert m[6] and m1[6] and not m0[6], "'+1' included from k=1 (paper §3.4)"
    for i in (0, 1, 2, 4, 5):                   # digits, ')', '+' at k=0
        assert m0[i]
    assert not m[7] and not m[9] and not m[EOS]


def test_fig3_eos_and_continue():
    d = DominoDecoder(FIG3, VOCAB, eos_id=EOS)
    for t in (3, 2, 4):                          # "(12)"
        assert d.advance(t)
    m = d.mask()
    assert m[EOS] and m[5] and m[6] and not m[0]
    d2 = d.clone()
    assert d.advance(EOS) and d.finished
    assert d2.advance(6) and d2.advance(1)       # "(12)+11"
    assert d2.mask()[EOS]


def test_illegal_token_rejected():
    d = DominoDecoder(FIG3, VOCAB, eos_id=EOS)
    assert not d.advance(4)          # ")" at start
    assert not d.advance(EOS)
    assert d.advance(0)              # "1"
    assert not d.advance(3)          # "1(" illegal


def test_opportunistic_check_matches_mask():
    d = DominoDecoder(FIG3, VOCAB, eos_id=EOS)
    d.advance(3), d.advance(2)
    m = d.mask()
    for tok in range(len(VOCAB)):
        assert d.check_token(tok) == bool(m[tok]), VOCAB[tok]


@pytest.mark.parametrize("gname", ["json", "json_gsm8k", "xml_schema"])
def test_online_baseline_mask_equality(gname, small_tokenizer):
    """DOMINO(k=inf) masks == full-vocabulary online parser masks."""
    tok = small_tokenizer
    g = grammars.load(gname)
    d1 = DominoDecoder(g, tok.vocab, eos_id=tok.eos_id)
    d2 = OnlineParserDecoder(g, tok.vocab, eos_id=tok.eos_id)
    sampler = GrammarSampler(g, seed=5)
    text = sampler.sample()
    ids = greedy_tokenize(text, tok.vocab)[:12]
    for t in ids:
        m1, m2 = d1.mask(), d2.mask()
        assert (m1 == m2).all(), \
            [tok.vocab[i] for i in np.where(m1 != m2)[0]]
        assert m1[t]
        assert d1.advance(t) and d2.advance(t)


@pytest.mark.parametrize("gname", ["json", "json_gsm8k", "c", "xml_schema"])
def test_minimal_invasiveness(gname, small_tokenizer, rng):
    """Def 2.1 core property: any tokenization of any valid string is
    accepted token-by-token by DOMINO(k=inf), and EOS is legal at the end."""
    tok = small_tokenizer
    g = grammars.load(gname)
    cache = TreeCache(Scanner(g), list(tok.vocab))
    sampler = GrammarSampler(g, seed=23)
    for trial in range(4):
        text = sampler.sample()
        ids = (greedy_tokenize(text, tok.vocab) if trial % 2 == 0
               else _random_tokenize(text, tok, rng))
        d = DominoDecoder(g, tok.vocab, eos_id=tok.eos_id, tree_cache=cache)
        for t in ids:
            assert d.mask()[t], (gname, text, tok.vocab[t])
            assert d.advance(t)
        assert d.eos_legal(), (gname, text)


def _random_tokenize(text, tok, rng):
    """A random (non-canonical) segmentation of text into vocab tokens."""
    from repro.core.retokenize import prefix_tokens
    from repro.core.trees import VocabTrie
    trie = VocabTrie.build(list(tok.vocab))
    out, rest = [], text
    while rest:
        cands = prefix_tokens(trie, rest)
        t = rng.choice(cands)
        out.append(t)
        rest = rest[len(tok.vocab[t]):]
    return out


def test_naive_equals_k0(small_tokenizer):
    tok = small_tokenizer
    g = grammars.load("json")
    d = naive_greedy_decoder(g, tok.vocab, tok.eos_id)
    ref = DominoDecoder(g, tok.vocab, tok.eos_id, k=0)
    assert (d.mask() == ref.mask()).all()


def test_k_monotonicity(small_tokenizer):
    """Larger lookahead can only ADD legal tokens."""
    tok = small_tokenizer
    g = grammars.load("json_gsm8k")
    d = DominoDecoder(g, tok.vocab, eos_id=tok.eos_id)
    ids = greedy_tokenize(b'{"thoughts": [{"step": "a"', tok.vocab)
    for t in ids:
        prev = None
        for k in (0, 1, 2, None):
            m = d.mask(k=k)
            if prev is not None:
                assert (m | prev == m).all(), "mask must grow with k"
            prev = m
        assert d.advance(t)


def test_intervention_forces_eos_only_when_nothing_else():
    g = parse_grammar('start: "ab"\n')
    vocab = [b"a", b"b", b"ab", b"x", None]
    d = DominoDecoder(g, vocab, eos_id=4)
    m = d.mask()
    assert m[0] and m[2] and not m[1] and not m[3] and not m[4]
    d.advance(2)
    m = d.mask()
    assert list(np.where(m)[0]) == [4], "only EOS after full parse"
