"""Continuous-batching constrained scheduler.

Acceptance: concurrent grammar-constrained requests with different prompt
lengths — more requests than slots, so the waiting queue and slot reuse are
exercised — complete through the batched path with per-request outputs
matching single-request ``generate`` token-for-token at temperature 0, on
both a full-attention and an SSM/hybrid architecture.  Also covers the
speculative rollback-vs-refeed split and per-request stats attribution.
"""
import dataclasses

import jax
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.core import grammars
from repro.models import build_model
from repro.serving import (ContinuousBatchingScheduler, EngineConfig,
                           ServingEngine)

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            dtype="float32", max_seq_len=512)

PROMPTS = ["a: ", "some much longer json prompt here: ", "x",
           "record -> "]


def _build(arch: str, vocab_size: int):
    if arch == "attn":
        cfg = ModelConfig(arch_id="s-attn", family="dense",
                          vocab_size=vocab_size, **BASE)
    elif arch == "swa":
        cfg = ModelConfig(arch_id="s-swa", family="dense",
                          group=("swa", "attn"), sliding_window=16,
                          vocab_size=vocab_size, **BASE)
    elif arch == "ssm":
        cfg = ModelConfig(arch_id="s-ssm", family="ssm", group=("mamba1",),
                          vocab_size=vocab_size,
                          ssm=SSMConfig(d_state=8, version=1), **BASE)
    else:
        raise ValueError(arch)
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ["attn", "ssm"])
def test_scheduler_matches_single_under_slot_reuse(small_tokenizer,
                                                   json_grammar, arch):
    """4 requests through 2 slots: admission queue + slot reuse on EOS."""
    tok = small_tokenizer
    m, params = _build(arch, tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=10),
                        max_len=256)
    singles = [eng.generate(p) for p in PROMPTS]
    sched = ContinuousBatchingScheduler(eng, capacity=2)
    sessions = [sched.submit(p) for p in PROMPTS]
    results = sched.run()
    assert len(results) == len(PROMPTS)
    for sess, single in zip(sessions, singles):
        assert sess.result.token_ids == single.token_ids
        assert sess.result.finished == single.finished
        # per-request stats are attributed per session, not batch-averaged
        assert sess.result.n_forward_passes >= 1
        assert sess.result.wall_time_s > 0.0


def test_scheduler_swa_arch(small_tokenizer, json_grammar):
    """Ring-buffer rows carry per-row ring state through the batch."""
    tok = small_tokenizer
    m, params = _build("swa", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=10),
                        max_len=256)
    prompts = PROMPTS[:3]
    singles = [eng.generate(p) for p in prompts]
    batch = eng.generate_batch(prompts, max_batch=2)
    for s, b in zip(singles, batch):
        assert s.token_ids == b.token_ids


@pytest.mark.parametrize("arch", ["attn", "ssm"])
def test_speculative_rollback_vs_refeed_same_output(small_tokenizer, arch):
    """§3.6: speculation must be output-invariant on BOTH rollback
    (full-attention) and refeed (SSM/hybrid) architectures."""
    tok = small_tokenizer
    m, params = _build(arch, tok.vocab_size)
    g = grammars.load("json_gsm8k")     # schema-heavy => predictable
    plain = ServingEngine(m, params, tok, g,
                          EngineConfig(mode="domino", max_tokens=20),
                          max_len=256)
    r0 = plain.generate("A: ")
    spec = ServingEngine(m, params, tok, g,
                         EngineConfig(mode="domino", speculative=True,
                                      spec_s=4, spec_threshold=0.4,
                                      max_tokens=20), max_len=256)
    assert spec._needs_refeed == (arch == "ssm")
    spec.generate("A: ")                # warm the count model
    r1 = spec.generate("A: ")
    assert r1.token_ids == r0.token_ids
    if arch == "attn":
        assert r1.n_forward_passes <= r0.n_forward_passes


@pytest.mark.parametrize("arch", ["attn", "ssm"])
def test_scheduler_speculative_matches_plain(small_tokenizer, arch):
    """Batched speculation (one (B, 1+s) verify decode, per-row
    rollback/refeed) is output-invariant vs the plain scheduler."""
    tok = small_tokenizer
    m, params = _build(arch, tok.vocab_size)
    g = grammars.load("json_gsm8k")
    prompts = ["A: ", "Q: compute 1 + 2\nA: "]
    plain = ServingEngine(m, params, tok, g,
                          EngineConfig(mode="domino", max_tokens=16),
                          max_len=256)
    base = plain.generate_batch(prompts)
    spec = ServingEngine(m, params, tok, g,
                         EngineConfig(mode="domino", speculative=True,
                                      spec_s=4, spec_threshold=0.4,
                                      max_tokens=16), max_len=256)
    spec.generate(prompts[0])           # warm the shared count model
    batch = spec.generate_batch(prompts)
    for b0, b1 in zip(base, batch):
        assert b0.token_ids == b1.token_ids
    assert sum(r.n_spec_proposed for r in batch) > 0


def test_scheduler_shares_tree_cache_and_warm_path(small_tokenizer,
                                                   json_grammar):
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=6),
                        max_len=256)
    sched = ContinuousBatchingScheduler(eng, capacity=2)
    stats = sched.warm()
    assert stats["positions"] >= 1
    built = len(eng.tree_cache.trees)
    s1 = sched.submit("a: ")
    s2 = sched.submit("b: ")
    sched.run()
    # sessions reused the precomputed trees (shared TreeCache, no growth)
    assert len(eng.tree_cache.trees) == built
    assert s1.checker.trees is eng.tree_cache
    assert s2.checker.trees is eng.tree_cache


def test_per_request_mask_time_attribution(small_tokenizer, json_grammar):
    """Satellite: mask_time_s / wall_time_s are per-request values, not a
    batch-wide split."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=4),
                        max_len=256)
    long_cfg = dataclasses.replace(eng.cfg, max_tokens=16)
    eng_long = ServingEngine(m, params, tok, json_grammar, long_cfg,
                             max_len=256, tree_cache=eng.tree_cache)
    rs = eng_long.generate_batch(["a: ", "b: "])
    assert all(r.mask_time_s > 0.0 for r in rs)
    assert all(r.wall_time_s > 0.0 for r in rs)
    # a request generating more tokens accrues its own (larger) mask time
    short = eng.generate_batch(["a: "])[0]
    assert short.mask_time_s > 0.0


def test_dead_end_surfaced_not_silent(small_tokenizer):
    """Satellite: an empty mask surfaces dead_end=True instead of forcing
    EOS into grammar-violating output."""
    tok = small_tokenizer

    class DeadEndChecker:
        """Checker stub that dead-ends after two tokens."""

        def __init__(self, inner):
            self.inner = inner
            self.steps = 0

        def mask(self):
            m = self.inner.mask()
            if self.steps >= 2:
                m[:] = False
            return m

        def check_token(self, t):
            return bool(self.mask()[t])

        def advance(self, t):
            self.steps += 1
            return self.inner.advance(t)

    m, params = _build("attn", tok.vocab_size)
    g = grammars.load("json")
    eng = ServingEngine(m, params, tok, g,
                        EngineConfig(mode="domino", max_tokens=8),
                        max_len=256)
    real_make = eng._make_checker
    eng._make_checker = lambda heal_prefix="": DeadEndChecker(real_make())
    r = eng.generate("a: ")
    assert r.dead_end and not r.finished
    assert len(r.token_ids) == 2
    # batched path surfaces it too
    rb = eng.generate_batch(["a: "])[0]
    assert rb.dead_end and not rb.finished
