"""Continuous-batching constrained scheduler.

Acceptance: concurrent grammar-constrained requests with different prompt
lengths — more requests than slots, so the waiting queue and slot reuse are
exercised — complete through the batched path with per-request outputs
matching single-request ``generate`` token-for-token at temperature 0, on
both a full-attention and an SSM/hybrid architecture.  Also covers the
speculative rollback-vs-refeed split and per-request stats attribution.
"""
import dataclasses

import jax
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.core import grammars
from repro.models import build_model
from repro.serving import (ContinuousBatchingScheduler, EngineConfig,
                           ServingEngine)

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            dtype="float32", max_seq_len=512)

PROMPTS = ["a: ", "some much longer json prompt here: ", "x",
           "record -> "]


def _build(arch: str, vocab_size: int):
    if arch == "attn":
        cfg = ModelConfig(arch_id="s-attn", family="dense",
                          vocab_size=vocab_size, **BASE)
    elif arch == "swa":
        cfg = ModelConfig(arch_id="s-swa", family="dense",
                          group=("swa", "attn"), sliding_window=16,
                          vocab_size=vocab_size, **BASE)
    elif arch == "ssm":
        cfg = ModelConfig(arch_id="s-ssm", family="ssm", group=("mamba1",),
                          vocab_size=vocab_size,
                          ssm=SSMConfig(d_state=8, version=1), **BASE)
    else:
        raise ValueError(arch)
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ["attn", "ssm"])
def test_scheduler_matches_single_under_slot_reuse(small_tokenizer,
                                                   json_grammar, arch):
    """4 requests through 2 slots: admission queue + slot reuse on EOS."""
    tok = small_tokenizer
    m, params = _build(arch, tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=10),
                        max_len=256)
    singles = [eng.generate(p) for p in PROMPTS]
    sched = ContinuousBatchingScheduler(eng, capacity=2)
    sessions = [sched.submit(p) for p in PROMPTS]
    results = sched.run()
    assert len(results) == len(PROMPTS)
    for sess, single in zip(sessions, singles):
        assert sess.result.token_ids == single.token_ids
        assert sess.result.finished == single.finished
        # per-request stats are attributed per session, not batch-averaged
        assert sess.result.n_forward_passes >= 1
        assert sess.result.wall_time_s > 0.0


def test_scheduler_swa_arch(small_tokenizer, json_grammar):
    """Ring-buffer rows carry per-row ring state through the batch."""
    tok = small_tokenizer
    m, params = _build("swa", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=10),
                        max_len=256)
    prompts = PROMPTS[:3]
    singles = [eng.generate(p) for p in prompts]
    batch = eng.generate_batch(prompts, max_batch=2)
    for s, b in zip(singles, batch):
        assert s.token_ids == b.token_ids


@pytest.mark.parametrize("arch", ["attn", "ssm"])
def test_speculative_rollback_vs_refeed_same_output(small_tokenizer, arch):
    """§3.6: speculation must be output-invariant on BOTH rollback
    (full-attention) and refeed (SSM/hybrid) architectures."""
    tok = small_tokenizer
    m, params = _build(arch, tok.vocab_size)
    g = grammars.load("json_gsm8k")     # schema-heavy => predictable
    plain = ServingEngine(m, params, tok, g,
                          EngineConfig(mode="domino", max_tokens=20),
                          max_len=256)
    r0 = plain.generate("A: ")
    spec = ServingEngine(m, params, tok, g,
                         EngineConfig(mode="domino", speculative=True,
                                      spec_s=4, spec_threshold=0.4,
                                      max_tokens=20), max_len=256)
    assert spec._needs_refeed == (arch == "ssm")
    spec.generate("A: ")                # warm the count model
    r1 = spec.generate("A: ")
    assert r1.token_ids == r0.token_ids
    if arch == "attn":
        assert r1.n_forward_passes <= r0.n_forward_passes


@pytest.mark.parametrize("arch", ["attn", "ssm"])
def test_scheduler_speculative_matches_plain(small_tokenizer, arch):
    """Batched speculation (one (B, 1+s) verify decode, per-row
    rollback/refeed) is output-invariant vs the plain scheduler."""
    tok = small_tokenizer
    m, params = _build(arch, tok.vocab_size)
    g = grammars.load("json_gsm8k")
    prompts = ["A: ", "Q: compute 1 + 2\nA: "]
    plain = ServingEngine(m, params, tok, g,
                          EngineConfig(mode="domino", max_tokens=16),
                          max_len=256)
    base = plain.generate_batch(prompts)
    spec = ServingEngine(m, params, tok, g,
                         EngineConfig(mode="domino", speculative=True,
                                      spec_s=4, spec_threshold=0.4,
                                      max_tokens=16), max_len=256)
    spec.generate(prompts[0])           # warm the shared count model
    batch = spec.generate_batch(prompts)
    for b0, b1 in zip(base, batch):
        assert b0.token_ids == b1.token_ids
    assert sum(r.n_spec_proposed for r in batch) > 0


def test_scheduler_shares_tree_cache_and_warm_path(small_tokenizer,
                                                   json_grammar):
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=6),
                        max_len=256)
    sched = ContinuousBatchingScheduler(eng, capacity=2)
    stats = sched.warm()
    assert stats["positions"] >= 1
    built = len(eng.tree_cache.trees)
    s1 = sched.submit("a: ")
    s2 = sched.submit("b: ")
    sched.run()
    # sessions reused the precomputed trees (shared TreeCache, no growth)
    assert len(eng.tree_cache.trees) == built
    assert s1.checker.trees is eng.tree_cache
    assert s2.checker.trees is eng.tree_cache


def test_per_request_mask_time_attribution(small_tokenizer, json_grammar):
    """Satellite: mask_time_s / wall_time_s are per-request values, not a
    batch-wide split."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=4),
                        max_len=256)
    long_cfg = dataclasses.replace(eng.cfg, max_tokens=16)
    eng_long = ServingEngine(m, params, tok, json_grammar, long_cfg,
                             max_len=256, tree_cache=eng.tree_cache)
    rs = eng_long.generate_batch(["a: ", "b: "])
    assert all(r.mask_time_s > 0.0 for r in rs)
    assert all(r.wall_time_s > 0.0 for r in rs)
    # a request generating more tokens accrues its own (larger) mask time
    short = eng.generate_batch(["a: "])[0]
    assert short.mask_time_s > 0.0


def test_dead_end_surfaced_not_silent(small_tokenizer):
    """Satellite: an empty mask surfaces dead_end=True instead of forcing
    EOS into grammar-violating output."""
    tok = small_tokenizer

    class DeadEndChecker:
        """Checker stub that dead-ends after two tokens."""

        def __init__(self, inner):
            self.inner = inner
            self.steps = 0

        def mask(self):
            m = self.inner.mask()
            if self.steps >= 2:
                m[:] = False
            return m

        def check_token(self, t):
            return bool(self.mask()[t])

        def advance(self, t):
            self.steps += 1
            return self.inner.advance(t)

    m, params = _build("attn", tok.vocab_size)
    g = grammars.load("json")
    eng = ServingEngine(m, params, tok, g,
                        EngineConfig(mode="domino", max_tokens=8),
                        max_len=256)
    real_make = eng._make_checker
    eng._make_checker = lambda heal_prefix="": DeadEndChecker(real_make())
    r = eng.generate("a: ")
    assert r.dead_end and not r.finished
    assert len(r.token_ids) == 2
    # batched path surfaces it too
    rb = eng.generate_batch(["a: "])[0]
    assert rb.dead_end and not rb.finished


def test_batched_decode_routes_through_fused_kernel(small_tokenizer,
                                                    json_grammar,
                                                    monkeypatch):
    """ISSUE 2 tentpole: with use_pallas_kernels the ragged batched decode
    must hit kernels/decode_attention (no dense fallback), and outputs
    must match the non-kernel scheduler token-for-token."""
    import repro.kernels.decode_attention.ops as dec_ops

    tok = small_tokenizer
    cfg = ModelConfig(arch_id="s-attn-pk", family="dense",
                      vocab_size=tok.vocab_size, use_pallas_kernels=True,
                      **BASE)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    calls = {"n": 0, "ragged": 0}
    real = dec_ops.decode_attention

    def spy(q, k, v, lengths, **kw):
        calls["n"] += 1
        if getattr(lengths, "ndim", 0) == 1:
            calls["ragged"] += 1
        return real(q, k, v, lengths, **kw)

    monkeypatch.setattr(dec_ops, "decode_attention", spy)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=8),
                        max_len=256)
    batch = eng.generate_batch(PROMPTS[:2], max_batch=2)
    assert calls["n"] > 0          # traced through the fused kernel
    assert calls["ragged"] > 0     # ... on the per-row-length path
    # parity vs the dense-fallback scheduler (same params, kernels off)
    cfg0 = ModelConfig(arch_id="s-attn-nk", family="dense",
                       vocab_size=tok.vocab_size, **BASE)
    eng0 = ServingEngine(build_model(cfg0), params, tok, json_grammar,
                         EngineConfig(mode="domino", max_tokens=8),
                         max_len=256)
    base = eng0.generate_batch(PROMPTS[:2], max_batch=2)
    for r0, r1 in zip(base, batch):
        assert r0.token_ids == r1.token_ids


def test_prefill_bucketing_bounds_compiles(small_tokenizer, json_grammar):
    """Satellite: admission prefills are padded to power-of-two buckets —
    distinct prompt lengths collapse onto O(log max_len) shapes, and the
    outputs stay token-for-token identical to unbucketed serving."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=6),
                        max_len=256)
    widths = []
    real_prefill = eng._prefill

    def spy(params, inputs, cache):
        widths.append(int(inputs["tokens"].shape[1]))
        assert "length" in inputs     # true length rides along
        return real_prefill(params, inputs, cache)

    eng._prefill = spy
    sched = ContinuousBatchingScheduler(eng, capacity=2)
    prompts = ["a: ", "some much longer json prompt here: ",
               "a medium prompt: ", "x: "]
    sessions = [sched.submit(p) for p in prompts]
    assert len({len(s.prompt_ids) for s in sessions}) >= 3
    sched.run()
    assert all(w & (w - 1) == 0 for w in widths)   # powers of two
    assert len(set(widths)) < len({len(s.prompt_ids) for s in sessions}) + 1
    # parity vs unbucketed admission
    eng._prefill = real_prefill
    plain = ContinuousBatchingScheduler(eng, capacity=2,
                                        bucket_prefill=False)
    sess0 = [plain.submit(p) for p in prompts]
    plain.run()
    for s_b, s_p in zip(sessions, sess0):
        assert s_b.result.token_ids == s_p.result.token_ids


def test_bucketing_skipped_on_refeed_archs(small_tokenizer, json_grammar):
    """Ring/recurrent state must never see pad tokens: SSM admission
    stays exact-length."""
    tok = small_tokenizer
    m, params = _build("ssm", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=4),
                        max_len=256)
    seen = []
    real_prefill = eng._prefill

    def spy(params, inputs, cache):
        seen.append(inputs)
        return real_prefill(params, inputs, cache)

    eng._prefill = spy
    eng.generate_batch(["some much longer json prompt here: "])
    assert all("length" not in i for i in seen)


def test_mask_overlap_accounting(small_tokenizer, json_grammar):
    """ISSUE 2 tentpole: host mask construction for step t+1 runs while
    the device executes step t.  The overlapped share is reported per
    session and bounded by total mask time; outputs are unchanged."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=10),
                        max_len=256)
    on = ContinuousBatchingScheduler(eng, capacity=2, overlap=True)
    s_on = [on.submit(p) for p in PROMPTS]
    on.run()
    off = ContinuousBatchingScheduler(eng, capacity=2, overlap=False)
    s_off = [off.submit(p) for p in PROMPTS]
    off.run()
    for a, b in zip(s_on, s_off):
        assert a.result.token_ids == b.result.token_ids
    # the pipeline actually served selections from prebuilt masks...
    assert on.premask_hits > 0
    assert off.premask_hits == 0
    # ...and the overlap credit (granted only when the device provably
    # outlasted the build) stays within total mask time
    for s in s_on:
        assert s.result.mask_overlap_s <= s.result.mask_time_s + 1e-9
    assert all(s.result.mask_overlap_s == 0.0 for s in s_off)


def test_gather_scatter_rows_roundtrip(small_tokenizer):
    """Grouped refeed surgery: gathering rows [2, 0] into a B=2 ragged
    cache and scattering them back is the identity."""
    import jax.numpy as jnp
    import numpy as np

    from repro.serving.scheduler import _gather_rows, _scatter_rows

    tok = small_tokenizer
    m, params = _build("swa", tok.vocab_size)
    cache = m.init_cache(4, 64)
    cache["len"] = jnp.asarray([5, 3, 9, 0], jnp.int32)
    leaves = jax.tree_util.tree_leaves(cache)
    cache = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache),
        [l + (i + 1) for i, l in enumerate(leaves)])
    idx = jnp.asarray([2, 0], jnp.int32)
    rows = _gather_rows(cache, idx)
    assert rows["len"].shape == (2,)     # stays ragged for the refeed
    back = _scatter_rows(cache, rows, idx)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scheduler_speculative_matches_plain_swa(small_tokenizer):
    """Grouped refeed on a ring-buffer arch: batched speculation remains
    output-invariant (exercises the K>1 gather/decode/scatter path)."""
    tok = small_tokenizer
    m, params = _build("swa", tok.vocab_size)
    g = grammars.load("json_gsm8k")
    prompts = ["A: ", "Q: compute 1 + 2\nA: ", "A: [", ]
    plain = ServingEngine(m, params, tok, g,
                          EngineConfig(mode="domino", max_tokens=12),
                          max_len=256)
    base = plain.generate_batch(prompts)
    spec = ServingEngine(m, params, tok, g,
                         EngineConfig(mode="domino", speculative=True,
                                      spec_s=4, spec_threshold=0.4,
                                      max_tokens=12), max_len=256)
    assert spec._needs_refeed
    spec.generate(prompts[0])           # warm the shared count model
    batch = spec.generate_batch(prompts)
    for b0, b1 in zip(base, batch):
        assert b0.token_ids == b1.token_ids


def test_vacant_slot_lengths_pinned_to_zero(small_tokenizer, json_grammar):
    """Freed slots must not keep accumulating ragged cache length — the
    fused kernel's early-exit depends on vacant rows staying at len 0."""
    import numpy as np

    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=6),
                        max_len=256)
    sched = ContinuousBatchingScheduler(eng, capacity=3)
    sched.submit("a: ")                 # 2 slots stay vacant throughout
    sched.run()
    assert np.all(np.asarray(sched.cache["len"]) == 0)
