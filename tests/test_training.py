"""Training substrate: optimizer, schedules, data pipeline, checkpointing,
cross-entropy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.model import cross_entropy
from repro.training import checkpoint, optimizer as opt
from repro.training.data import (GrammarLMDataset, TaskDataset,
                                 evaluate_answer, make_task_example)


def test_adamw_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, schedule="constant",
                          warmup_steps=1, total_steps=100)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert int(state["step"]) == 150


@pytest.mark.parametrize("sched", ["constant", "cosine", "wsd"])
def test_schedules(sched):
    cfg = opt.AdamWConfig(lr=1.0, schedule=sched, warmup_steps=10,
                          total_steps=100, lr_min_frac=0.1)
    f = opt.schedule_fn(cfg)
    lrs = [float(f(jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6           # warmup
    if sched == "wsd":
        assert abs(lrs[50] - 1.0) < 1e-6            # stable phase
        assert lrs[99] < 0.2                        # decay phase
    if sched == "cosine":
        assert lrs[99] < lrs[50] < lrs[15]


def test_grad_clip():
    cfg = opt.AdamWConfig(lr=1.0, grad_clip=1e-3, schedule="constant",
                          warmup_steps=1)
    params = {"w": jnp.zeros(3)}
    state = opt.init_state(params)
    _, _, m = opt.apply_updates(params, {"w": jnp.full(3, 1e6)}, state, cfg)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_task_examples():
    import random
    rng = random.Random(0)
    for _ in range(20):
        ex = make_task_example(rng)
        assert evaluate_answer(ex.answer_json) == ex.answer_value
    assert evaluate_answer("not json") is None
    assert evaluate_answer('{"answer": "x"}') is None


def test_task_dataset(small_tokenizer):
    ds = TaskDataset(small_tokenizer, seq_len=96, few_shot=1)
    batch = next(ds.batches(3))
    assert batch["tokens"].shape == (3, 97)
    assert batch["labels"].shape == (3, 96)
    assert (batch["labels"] >= -1).all()


def test_lm_dataset(small_tokenizer, json_grammar):
    ds = GrammarLMDataset(small_tokenizer, "json", seq_len=64)
    b = next(ds.batches(2))
    assert b["tokens"].shape == (2, 65)
    assert (b["tokens"] < small_tokenizer.vocab_size).all()


def test_checkpoint_roundtrip(tmp_path):
    params = {"a": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
              "b": [jnp.ones(4), jnp.zeros((2, 2))]}
    state = opt.init_state(params)
    checkpoint.save(tmp_path / "ck", params, state, {"note": "hi"})
    p2, s2, meta = checkpoint.load(tmp_path / "ck", params, state)
    assert meta["note"] == "hi"
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_cross_entropy_matches_naive():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 5, 11)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 11, size=(2, 5)), dtype=jnp.int32)
    labels = labels.at[0, 0].set(-1)  # mask one
    got = float(cross_entropy(logits, labels))
    lp = jax.nn.log_softmax(logits, -1)
    want = 0.0
    n = 0
    for b in range(2):
        for s in range(5):
            if int(labels[b, s]) >= 0:
                want -= float(lp[b, s, int(labels[b, s])])
                n += 1
    assert abs(got - want / n) < 1e-5
