"""Device-resident fused decode loop (ISSUE 8).

Acceptance: (1) a cleanly-certified grammar's ``DeviceGrammarTable``
reproduces the concrete checker's masks and transitions state-for-state;
(2) an all-certified greedy batch decodes through the fused loop —
``n_device_tokens > 0``, host syncs per token well under 1 — with output
token-for-token identical to the host path AND to single-request
``generate``; (3) a mixed batch (certified JSON + online-checked + healed
rows) under ``device_loop=True`` is bitwise-identical to the all-host
scheduler; (4) a grammar whose certificate is downgraded (mask conflict)
provably never enters the device path; (5) an injected NaN mid-fused-block
quarantines exactly the planned row with the same ``internal_error`` the
host path raises, while batch-mates finish ``ok``; (6) the device sampler
matches host ``select_token`` in distribution; (7) the speculative verify
path never widens packed masks to bool (runtime check backing the
hot-path linter).
ISSUE 9 adds the durability/degradation satellites: corrupted device
table rows (real bit flips and the ``table_corrupt`` injection site) are
caught by the block-boundary audit and demote the row with a journaled
reason; a ``device_error`` mid-block discards the block wholesale and
recovers bitwise-identically; a ``device_timeout`` storm walks the
fused->host ladder down and back; the deadline clamp bounds a fused
block to the nearest resident deadline; and a cancel that lands while a
block is in flight is honored at the block boundary without committing
the block's tokens.
"""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import bitmask
from repro.core.analysis import OFF_FRONTIER, analyze
from repro.core.domino import DominoDecoder
from repro.core.sampling import GrammarSampler
from repro.kernels.masked_sample.ops import masked_sample_packed
from repro.models import build_model
from repro.serving import (ConstraintSpec, ContinuousBatchingScheduler,
                           DecodeParams, DegradationSupervisor,
                           EngineConfig, Request, ServingEngine,
                           TokenJournal, read_records)
from repro.serving.faults import FaultInjector
from repro.serving.request import select_token
from repro.tokenizer import train_bpe

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            dtype="float32", max_seq_len=512)

PROMPTS = ["a: ", "some much longer json prompt here: ", "x",
           "record -> "]


@pytest.fixture(scope="module")
def setup(json_grammar):
    """Byte-level tokenizer: the JSON zoo grammar certifies CLEAN against
    a byte-complete vocabulary (344 abstract states, zero conflicts), so
    the engine can build a device table for it."""
    corpus = GrammarSampler(json_grammar, seed=7).corpus(80)
    tok = train_bpe(corpus, vocab_size=257)
    cfg = ModelConfig(arch_id="dev-attn", family="dense",
                      vocab_size=tok.vocab_size, **BASE)
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0)), tok


@pytest.fixture(scope="module")
def engine(setup, json_grammar):
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=16),
                        max_len=256, device_tables=True)
    eng.register_grammar("json", json_grammar)
    stats = eng.precompute()
    assert stats.get("device_table_seconds", 0) > 0
    assert "json" in eng.device_tables
    return eng


def test_device_table_walk_and_quotient_escape_audit(setup, json_grammar,
                                                     engine):
    """Walk a nested-JSON token sequence through a concrete DominoDecoder
    and the table side by side.  JSON is context-free, so the finite
    abstract-key quotient CANNOT be a bisimulation: a deep walk is
    expected to eventually escape the quotient.  The contract under test
    is the one the scheduler enforces: (1) the table is faithful (mask
    rows equal, transitions on-frontier) for a long prefix; (2) the
    FIRST unfaithful step is detected by exactly the audit predicate the
    scheduler applies — mask-row equality; (3) past the escape, a token
    the stale table row admits but the checker rejects is caught by
    ``advance`` returning False with state unchanged (grammar validity
    stays unconditional)."""
    _m, _params, tok = setup
    table = engine.device_tables["json"]
    assert table.n_bytes == table.mask_table.nbytes + table.trans.nbytes
    v = len(tok.vocab)
    d = DominoDecoder(json_grammar, list(tok.vocab), tok.eos_id)
    sid = table.sid_for(d)
    assert sid >= 0
    # entry audit (what _sid_for runs at admission) passes at the root
    assert np.array_equal(table.mask_table[sid], d.mask_bits())
    text = b'{"key": [1, 2.5, "str", {"nested": true}], "other": null}'
    ids = tok.encode_bytes(text)
    faithful = 0
    escape_sid = None
    for tok_id in ids:
        if not np.array_equal(table.mask_table[sid], d.mask_bits()):
            escape_sid = sid           # audit predicate fires HERE
            break
        if not bitmask.get_bit(d.mask_bits(), tok_id):
            break                      # text ended mid-token
        nxt = int(table.trans[sid, tok_id])
        assert nxt >= 0, "mask-legal token transitioned off-frontier"
        assert d.advance(tok_id)
        sid = nxt
        faithful += 1
    assert faithful >= 20, \
        f"table diverged from the checker after only {faithful} steps"
    if escape_sid is not None:
        # safety net past the escape: any token the stale row admits
        # but the concrete checker forbids must be REJECTED by advance
        # (state unchanged) — the scheduler turns that into a
        # recompute-preemption, never a corrupt output
        tbl_legal = bitmask.unpack(table.mask_table[escape_sid], v)
        ch_legal = bitmask.unpack(d.mask_bits(), v)
        before = d.mask_bits().copy()
        for t in np.nonzero(tbl_legal & ~ch_legal)[0][:4]:
            assert not d.advance(int(t))
            assert np.array_equal(d.mask_bits(), before)


def test_all_certified_batch_runs_fused(engine):
    """Every row certified + greedy => the fused loop commits (nearly)
    every token; outputs identical to the host scheduler AND to
    single-request generate; host syncs per token ~1/sync_n, not ~1."""
    eng = engine
    singles = [eng.generate(p) for p in PROMPTS]
    host = ContinuousBatchingScheduler(eng, capacity=2,
                                       debug_invariants=True)
    for p in PROMPTS:
        host.submit(p)
    host_res = host.run()
    dev = ContinuousBatchingScheduler(eng, capacity=2, device_loop=True,
                                      sync_n=8, debug_invariants=True)
    for p in PROMPTS:
        dev.submit(p)
    dev_res = dev.run()
    for s, h, d in zip(singles, host_res, dev_res):
        assert d.token_ids == h.token_ids == s.token_ids
        assert d.status == h.status
        assert d.finished == h.finished
    n_tok = sum(r.n_tokens for r in dev_res)
    assert dev.n_device_tokens == n_tok > 0
    assert all(r.n_device_tokens == r.n_tokens for r in dev_res)
    # the whole point: way fewer than one host sync per committed token
    assert dev.n_host_syncs < host.n_host_syncs
    assert dev.n_host_syncs / n_tok <= 1 / 8 + 0.1
    # host path never consulted the fused loop; it syncs once per TICK
    # (capacity rows each), so at least once per token of the longest row
    assert host.n_device_tokens == 0
    assert host.n_host_syncs >= max(r.n_tokens for r in host_res)


def test_mixed_batch_identical_to_all_host(engine):
    """Certified JSON + online-checked + token-healed rows in ONE batch:
    device_loop=True must be token-for-token identical to the all-host
    scheduler (healed/online rows are never device-eligible; their
    presence forces mixed ticks onto the per-token path where certified
    rows still gather table masks — stage 1)."""
    eng = engine
    reqs = [
        Request("a json: ", ConstraintSpec(grammar="json", mode="domino"),
                DecodeParams(max_tokens=10)),
        Request("a json: ", ConstraintSpec(grammar="json", mode="online"),
                DecodeParams(max_tokens=8)),
        Request('{"k": 1', ConstraintSpec(grammar="json", mode="domino",
                                          heal=1),
                DecodeParams(max_tokens=8)),
        Request("free text: ", ConstraintSpec(),
                DecodeParams(max_tokens=6)),
    ]
    host = eng.generate_batch(list(reqs), max_batch=3, device_loop=False)
    dev = eng.generate_batch(list(reqs), max_batch=3, device_loop=True)
    for h, d in zip(host, dev):
        assert d.token_ids == h.token_ids
        assert d.status == h.status
        assert d.n_interventions == h.n_interventions


def test_downgraded_certificate_never_enters_device(setup, json_grammar):
    """A grammar whose analysis report carries a mask conflict must not
    get a device table — and a device_loop run over it must commit zero
    device tokens while producing the host path's exact output."""
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=10),
                        max_len=256, device_tables=True)
    name = "default"               # ctor grammar registers under this
    rep = analyze(json_grammar, list(tok.vocab), tok.eos_id, name=name)
    eng.analysis_reports[name] = dataclasses.replace(
        rep, n_mask_conflicts=1)
    eng.precompute()
    assert name not in eng.device_tables
    assert eng.device_table_set is None
    sched = ContinuousBatchingScheduler(eng, capacity=2, device_loop=True,
                                        sync_n=8, debug_invariants=True)
    for p in PROMPTS[:2]:
        sched.submit(p)
    res = sched.run()
    assert sched.n_device_tokens == 0
    assert all(int(s) == OFF_FRONTIER for s in sched._dev_state)
    host = [eng.generate(p) for p in PROMPTS[:2]]
    for h, d in zip(host, res):
        assert d.token_ids == h.token_ids


def test_nan_fault_mid_fused_block_quarantines_one_row(engine):
    """decode_nan targeted at one rid fires INSIDE a fused block: on
    resync that row alone terminates internal_error with the host path's
    exact error string; batch-mates keep decoding and finish ok."""
    eng = engine
    inj = FaultInjector(seed=0, rates={"decode_nan": 1.0}, targets={1})
    sched = ContinuousBatchingScheduler(eng, capacity=2, device_loop=True,
                                        sync_n=8, fault_injector=inj,
                                        debug_invariants=True)
    sessions = [sched.submit(p) for p in PROMPTS[:2]]
    results = sched.run()
    doomed = sessions[1].result
    assert doomed.status == "internal_error"
    assert "non-finite logits from device step" in doomed.error
    survivor = sessions[0].result
    assert survivor.status == "ok"
    assert survivor.token_ids == eng.generate(PROMPTS[0]).token_ids


def test_device_sampler_matches_host_distribution():
    """Gumbel-max over the packed legal set == softmax(logits/T)
    restricted to the mask: compare empirical frequencies against the
    host select_token path (statistical, NOT bitwise — different PRNG
    streams by design)."""
    rng = np.random.default_rng(0)
    v = 70
    logits = rng.normal(size=v).astype(np.float32) * 2.0
    legal = np.zeros(v, bool)
    legal[rng.choice(v, size=9, replace=False)] = True
    bits = bitmask.pack_bool(legal)
    temp = 0.8
    n = 4000
    keys = np.stack([np.asarray(jax.random.fold_in(jax.random.PRNGKey(5), i))
                     for i in range(n)]).astype(np.uint32)
    dev = np.asarray(masked_sample_packed(
        jax.numpy.asarray(np.tile(logits, (n, 1))),
        jax.numpy.asarray(np.tile(bits, (n, 1))),
        jax.numpy.full((n,), temp, np.float32),
        jax.numpy.asarray(keys)))
    assert legal[dev].all(), "device sampler drew an illegal token"
    host_rng = np.random.default_rng(5)
    host = np.asarray([select_token(logits, legal, temp, host_rng)
                       for _ in range(n)])
    dev_freq = np.bincount(dev, minlength=v)[legal] / n
    host_freq = np.bincount(host, minlength=v)[legal] / n
    tv = 0.5 * np.abs(dev_freq - host_freq).sum()
    assert tv < 0.06, f"TV distance {tv:.3f} between device/host samplers"
    # t <= 0 degenerates to the masked argmax
    greedy = np.asarray(masked_sample_packed(
        jax.numpy.asarray(logits[None]), jax.numpy.asarray(bits[None]),
        jax.numpy.zeros((1,), np.float32), jax.numpy.asarray(keys[:1])))
    masked = np.where(legal, logits, -np.inf)
    assert int(greedy[0]) == int(masked.argmax())


# -- durability + degradation satellites (ISSUE 9) -----------------------------


def _host_baseline(eng, prompts):
    sched = ContinuousBatchingScheduler(eng, capacity=2)
    sessions = [sched.submit(p) for p in prompts]
    sched.run()
    return [s.result for s in sessions]


def test_corrupted_table_row_caught_by_audit_and_journaled(
        engine, json_grammar, tmp_path):
    """Flip bits in every HOST-side audit mask row except the entry
    state: rows enter the fused path (the device-side tables are
    untouched, so selection stays correct), and the first block-boundary
    audit sees the corruption, demotes the row to the host path with a
    journaled reason — output still bitwise-identical."""
    eng = engine
    base = _host_baseline(eng, PROMPTS[:2])
    path = str(tmp_path / "j")
    sched = ContinuousBatchingScheduler(eng, capacity=2, device_loop=True,
                                        sync_n=4, debug_invariants=True,
                                        journal=TokenJournal(path))
    dts = sched._dts
    d = DominoDecoder(json_grammar, list(eng.tok.vocab), eng.tok.eos_id)
    root = dts.sid_for("default", d)
    assert root >= 0
    save = dts.mask_host.copy()
    dts.mask_host[np.arange(len(dts.mask_host)) != root] ^= np.uint32(1)
    try:
        for p in PROMPTS[:2]:
            sched.submit(p)
        res = sched.run()
    finally:
        dts.mask_host[:] = save
    assert sched.n_quotient_escapes >= 1
    assert sched.n_device_tokens > 0          # the block DID run fused
    for b, r in zip(base, res):
        assert r.status == "ok"
        assert r.token_ids == b.token_ids
    demotes = [r for r in read_records(path) if r["kind"] == "demote"]
    assert demotes and all("mismatch" in r["reason"] for r in demotes)


def test_table_corrupt_injection_demotes_with_journal_reason(
        engine, tmp_path):
    eng = engine
    base = _host_baseline(eng, PROMPTS[:2])
    inj = FaultInjector(seed=0, rates={"table_corrupt": 1.0},
                        max_faults=2)
    path = str(tmp_path / "j")
    sched = ContinuousBatchingScheduler(eng, capacity=2, device_loop=True,
                                        sync_n=4, fault_injector=inj,
                                        debug_invariants=True,
                                        journal=TokenJournal(path))
    for p in PROMPTS[:2]:
        sched.submit(p)
    res = sched.run()
    assert inj.n_fired("table_corrupt") >= 1
    assert sched.n_quotient_escapes >= 1
    for b, r in zip(base, res):
        assert r.status == "ok" and r.token_ids == b.token_ids
    demotes = [r for r in read_records(path) if r["kind"] == "demote"]
    assert any("injected table corruption" in r["reason"]
               for r in demotes)


def test_device_error_mid_block_discards_block_and_recovers(engine):
    """An injected device_error at the fused-block readback: nothing
    from the block can be trusted, so it is discarded wholesale (engine
    reset + recompute-preempt) — the validated prefix survives and every
    request completes bitwise-identical to the fault-free run."""
    eng = engine
    base = _host_baseline(eng, PROMPTS[:2])
    inj = FaultInjector(seed=0, rates={"device_error": 1.0}, max_faults=1)
    sched = ContinuousBatchingScheduler(eng, capacity=2, device_loop=True,
                                        sync_n=8, fault_injector=inj,
                                        debug_invariants=True)
    for p in PROMPTS[:2]:
        sched.submit(p)
    res = sched.run()
    assert inj.n_fired("device_error") == 1
    assert sched.n_engine_resets == 1
    assert sched.sup.n_degrades >= 1
    for b, r in zip(base, res):
        assert r.status == "ok"
        assert r.token_ids == b.token_ids
    assert all(s is None for s in sched.slots)
    if sched.paged:
        assert sched.pool.available == sched.n_pages - 1


def test_device_timeout_storm_walks_ladder_down_and_back(engine):
    """The acceptance storm: seeded device_timeout faults degrade the
    fused loop to the host path; clean ticks climb back; MTTR is
    recorded; no invariant violations, no leaks, outputs bitwise-equal,
    and the fused path is re-entered after recovery."""
    eng = engine
    base = _host_baseline(eng, PROMPTS)
    inj = FaultInjector(seed=1, rates={"device_timeout": 1.0},
                        max_faults=6)
    sup = DegradationSupervisor(max_retries=1, backoff_s=0.0,
                                recover_after=2)
    sched = ContinuousBatchingScheduler(eng, capacity=2, device_loop=True,
                                        sync_n=4, fault_injector=inj,
                                        supervisor=sup,
                                        debug_invariants=True)
    for p in PROMPTS:
        sched.submit(p)
    res = sched.run()
    assert inj.n_fired("device_timeout") >= 2
    assert sup.n_degrades >= 1
    assert sup.n_recovers >= 1
    for b, r in zip(base, res):
        assert r.status == "ok"
        assert r.token_ids == b.token_ids
    # the storm exhausted early in the run; the ladder climbed back to
    # the fused path and committed device tokens again
    assert sup.level == 0 and sup.mttr_s is not None
    assert sched.n_device_tokens > 0
    assert all(s is None for s in sched.slots)
    if sched.paged:
        assert sched.pool.available == sched.n_pages - 1
    stats = sched.stats()
    assert stats["mttr_s"] == sup.mttr_s
    assert stats["n_degrades"] == sup.n_degrades


def test_deadline_clamp_bounds_fused_block(engine):
    """Satellite: a resident with little deadline budget left must not
    get a full sync_n block — the EWMA-priced clamp stops the block
    early (>= 1 step so lifecycle checks still run at a boundary)."""
    eng = engine
    sched = ContinuousBatchingScheduler(eng, capacity=1, device_loop=True,
                                        sync_n=8, debug_invariants=True)
    s = sched.submit(Request(
        PROMPTS[1], ConstraintSpec(grammar="default", mode="domino"),
        DecodeParams(max_tokens=64, deadline_s=30.0)))
    # run until the EWMA is primed by a full block
    for _ in range(8):
        if sched._tok_s_ema > 0.0 or s.result is not None:
            break
        sched.step()
    assert s.result is None and sched._tok_s_ema > 0.0
    assert sched.n_deadline_clamps == 0       # plenty of budget so far
    # back-date the submission so ~10ms of deadline remains: the next
    # block must clamp well below sync_n
    s.t_submit = time.perf_counter() - (30.0 - 0.01)
    sched.step()
    assert sched.n_deadline_clamps >= 1
    assert 1 <= sched._last_block_steps < 8
    sched.run()                               # overdue: reaped next tick
    assert s.result.status == "deadline_exceeded"
    assert all(x is None for x in sched.slots)
    if sched.paged:
        assert sched.pool.available == sched.n_pages - 1


def test_cancel_honored_at_block_boundary(engine):
    """Satellite: a cancel that lands while a fused block is in flight
    commits NONE of that block's tokens for the row and terminates it
    `cancelled` at the next boundary — a cancel never trails by more
    than one block."""
    eng = engine
    sched = ContinuousBatchingScheduler(eng, capacity=1, device_loop=True,
                                        sync_n=4, debug_invariants=True)
    s = sched.submit(Request(
        PROMPTS[1], ConstraintSpec(grammar="default", mode="domino"),
        DecodeParams(max_tokens=64)))
    for _ in range(8):
        if s.n_device_tokens > 0 or s.result is not None:
            break
        sched.step()
    assert s.result is None and s.n_device_tokens > 0
    n_before = len(s.out_ids)
    # cancellation "arrives" while the next block is in flight: set the
    # flag and drive the block directly (bypassing the tick's reap sweep,
    # which would otherwise terminate the row before the block runs)
    s.cancel_requested = True
    sched._device_step()
    assert len(s.out_ids) == n_before         # block tokens NOT committed
    assert s.result is None
    sched.step()                              # boundary: reap honors it
    assert s.result.status == "cancelled"
    assert s.result.n_tokens == n_before
    assert all(x is None for x in sched.slots)
    if sched.paged:
        assert sched.pool.available == sched.n_pages - 1


def test_verify_row_stays_packed(engine, monkeypatch):
    """Speculative greedy verification must never unpack a mask to bool:
    poison bitmask.unpack and run a speculative batch end to end (the
    runtime counterpart of the hot-path linter's R2 check)."""
    eng = engine
    import repro.serving.engine as engine_mod
    import repro.serving.scheduler as sched_mod

    def _boom(*a, **k):
        raise AssertionError("bitmask.unpack called on the greedy "
                             "verify path")

    monkeypatch.setattr(engine_mod.bitmask, "unpack", _boom)
    assert sched_mod.bitmask.unpack is _boom      # same module object
    req = Request("a: ", ConstraintSpec(grammar="json", mode="domino"),
                  DecodeParams(max_tokens=10, speculative=True, spec_s=3,
                               spec_threshold=0.0))
    res = eng.generate_batch([req], device_loop=True)
    assert res[0].status in ("ok", "dead_end")
