"""Engine token healing + RegexDecoder (Outlines baseline) integration."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import grammars
from repro.core.baselines import RegexDecoder
from repro.core.domino import DominoDecoder
from repro.models import build_model
from repro.serving import EngineConfig, ServingEngine


@pytest.fixture(scope="module")
def setup(request):
    tok = request.getfixturevalue("small_tokenizer")
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32",
                      max_seq_len=512)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return m, params, tok


def test_engine_healing_regenerates_boundary(setup, json_grammar):
    m, params, tok = setup
    # prompt deliberately ends mid-JSON: '{"' — healing strips it and the
    # model may re-emit it with its preferred (bridge) tokenization
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", heal=2, max_tokens=24),
                        max_len=512)
    r = eng.generate('data: {"')
    # output (which now INCLUDES the healed prefix, possibly with the
    # stripped leading whitespace) must start with it and be grammar-valid
    assert r.text.lstrip().startswith("{")
    d = DominoDecoder(json_grammar, list(tok.vocab), tok.eos_id)
    for t in r.token_ids:
        assert d.advance(t), tok.vocab[t]


def test_engine_healing_speculative(setup):
    m, params, tok = setup
    g = grammars.load("json_gsm8k")
    eng = ServingEngine(m, params, tok, g,
                        EngineConfig(mode="domino", heal=1, speculative=True,
                                     spec_s=4, spec_threshold=0.4,
                                     max_tokens=16), max_len=512)
    r1 = eng.generate('A: {')
    r2 = eng.generate('A: {')
    assert r2.n_tokens > 0


def test_batched_healing_matches_single(setup, json_grammar):
    """Scheduler sessions heal prompt boundaries exactly like the
    single-request path."""
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", heal=2, max_tokens=12),
                        max_len=512)
    prompts = ['data: {"', 'obj: {"']
    singles = [eng.generate(p) for p in prompts]
    batch = eng.generate_batch(prompts)
    for s, b in zip(singles, batch):
        assert s.token_ids == b.token_ids
        assert b.text.lstrip().startswith("{")


def test_regex_decoder_outlines_baseline(small_tokenizer):
    tok = small_tokenizer
    rd = RegexDecoder(r"[1-9][0-9]*\.[0-9]+", list(tok.vocab), tok.eos_id)
    text = b"31.415"
    from repro.core.retokenize import greedy_tokenize
    for t in greedy_tokenize(text, tok.vocab):
        assert rd.mask()[t], tok.vocab[t]
        assert rd.advance(t)
    assert rd.mask()[tok.eos_id]
    assert rd.advance(tok.eos_id) and rd.finished
    # illegal continuation rejected
    rd2 = RegexDecoder(r"[0-9]+", list(tok.vocab), tok.eos_id)
    assert not rd2.advance(greedy_tokenize(b"x", tok.vocab)[0])
