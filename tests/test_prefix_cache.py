"""Radix prefix cache: COW paged KV + shared checker state (ISSUE 10).

Acceptance: shared-prefix serving is observationally pure — with the
cache enabled, mixed-grammar batches over prompts forking a shared
prefix at random token offsets (greedy AND sampled, with speculative
rollback crossing the fork page) are token-for-token identical to a
cold-cache scheduler, including the crash/restore and device-loop
paths; the pool drains leak-free after all evictions; every tick passes
the COW partition audit (refcounts = table refs + node refs, no shared
page writable, free ∩ referenced = ∅); restored sessions adopt
fork-point checker snapshots instead of replaying ``advance()``.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import grammars
from repro.core.domino import DominoDecoder
from repro.core.sampling import GrammarSampler
from repro.models import build_model
from repro.serving import (ConstraintSpec, ContinuousBatchingScheduler,
                           DecodeParams, PrefixCache, Request,
                           ServingEngine, TokenJournal, check_invariants,
                           replay_journal)
from repro.serving.scheduler import PagePool
from repro.tokenizer import train_bpe

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            dtype="float32", max_seq_len=512)


@pytest.fixture(scope="module")
def setup(request):
    tok = request.getfixturevalue("small_tokenizer")
    cfg = ModelConfig(arch_id="pfx", family="dense",
                      vocab_size=tok.vocab_size, **BASE)
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0)), tok


@pytest.fixture(scope="module")
def engine(setup, json_grammar):
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, max_len=256)
    eng.register_grammar("json", json_grammar)
    eng.register_grammar("c", grammars.load("c"))
    eng.precompute()
    return eng


# -- PagePool refcounts --------------------------------------------------------


def test_pool_refcounts_alloc_retain_release():
    pool = PagePool(8)
    got = pool.alloc(3)
    assert got is not None and all(pool.refcount(p) == 1 for p in got)
    pool.retain(got[:2])
    assert pool.refcount(got[0]) == 2 and pool.refcount(got[2]) == 1
    avail = pool.available
    pool.release(got)              # drops table refs
    assert pool.available == avail + 1      # only got[2] hit zero
    pool.release(got[:2])          # drops the retained refs
    assert pool.available == 7 == pool.n_pages - 1
    assert all(pool.refcount(p) == 0 for p in got)


def test_pool_free_is_release_alias_and_asserts():
    pool = PagePool(4)
    got = pool.alloc(2)
    pool.free(got)                 # historical name, same semantics
    assert pool.available == 3
    with pytest.raises(AssertionError):
        pool.release([got[0]])     # double release


# -- radix tree unit -----------------------------------------------------------


def _ids(n, base=0):
    return list(range(base, base + n))


def test_radix_insert_lookup_page_granular():
    pool = PagePool(32)
    pc = PrefixCache(pool, page_size=4)
    pages = pool.alloc(3)
    ids = _ids(12)
    assert pc.insert(ids, pages) == 3
    # owner releases; nodes keep the pages alive
    pool.release(pages)
    assert pool.available == 32 - 1 - 3 and pc.n_pages == 3
    # full match capped one token short of the sequence
    got = pc.lookup(ids, max_pages=(len(ids) - 1) // 4)
    assert got == pages[:2]        # 2 pages: the cap excludes page 3
    assert all(pool.refcount(p) == 2 for p in got)
    pool.release(got)
    # divergence mid-page matches only whole shared pages
    fork = ids[:6] + [99] * 6
    got = pc.lookup(fork, max_pages=2)
    assert got == pages[:1]
    pool.release(got)
    # no match at all
    assert pc.lookup([7] * 12, max_pages=2) == []


def test_radix_graft_keeps_incumbent_page():
    pool = PagePool(32)
    pc = PrefixCache(pool, page_size=4)
    a = pool.alloc(2)
    pc.insert(_ids(8), a)
    b = pool.alloc(2)              # same tokens, different pages
    assert pc.insert(_ids(8), b) == 0      # both depths already present
    pool.release(b)                # b unadopted -> freed
    assert pc.n_pages == 2 and pc.owns(a[0]) and not pc.owns(b[0])
    got = pc.lookup(_ids(8), max_pages=1)
    assert got == [a[0]]           # incumbent survives
    pool.release(got)
    pool.release(a)


def test_radix_eviction_lru_leaf_only_respects_refs_and_pins():
    pool = PagePool(32)
    pc = PrefixCache(pool, page_size=2)
    chain = pool.alloc(3)          # one 3-deep chain
    pc.insert([1, 2, 3, 4, 5, 6], chain)
    pool.release(chain)
    other = pool.alloc(1)          # a sibling leaf, older access time
    pc.insert([9, 9], other)
    pool.release(other)
    got = pc.lookup([1, 2, 3, 4, 5, 6], max_pages=3)  # refresh chain LRU
    pool.release(got)              # drop the lookup refs again
    # interior nodes are not evictable while children exist: evict(1)
    # must take the LRU *leaf* — the sibling, not the chain interior
    assert pc.evict(1) == 1
    assert not pc.owns(other[0]) and pc.owns(chain[0])
    # a table-referenced leaf is never evicted
    got = pc.lookup([1, 2, 3, 4, 5, 6], max_pages=3)
    assert got == chain
    assert pc.evict(10) == 0       # every node refcount >= 2
    pool.release(got)
    # pinned nodes survive eviction pressure
    pinned = pool.alloc(1)
    pc.insert([7, 7], pinned, pin=True)
    pool.release(pinned)
    n = pc.evict(10)
    assert pc.owns(pinned[0]) and n == 3     # chain fully cascaded
    assert pool.available == 32 - 1 - 1      # only the pin remains
    pc.reset()
    assert pool.available == 32 - 1 and pc.n_pages == 0


def test_evictable_counts_transitively():
    pool = PagePool(32)
    pc = PrefixCache(pool, page_size=2)
    chain = pool.alloc(3)
    pc.insert([1, 2, 3, 4, 5, 6], chain)
    pool.release(chain)
    assert pc.evictable() == 3     # leaf exposes parent exposes root
    got = pc.lookup([1, 2], max_pages=1)     # table ref on the TOP node
    assert pc.evictable() == 2     # children still reclaimable
    pool.release(got)
    assert pc.evictable() == 3


# -- checker snapshot store ----------------------------------------------------


def test_checker_snapshots_keyed_by_prompt_split(json_grammar,
                                                 small_tokenizer):
    tok = small_tokenizer
    pc = PrefixCache(PagePool(4), page_size=4)
    d = DominoDecoder(json_grammar, list(tok.vocab), tok.eos_id)
    toks = []
    for _ in range(3):
        legal = np.flatnonzero(d.mask())
        t = int(next(x for x in legal if x != tok.eos_id))
        assert d.advance(t)
        toks.append(t)
    sig = ("json", "domino", None, tok.eos_id)
    prompt = [5, 6, 7]
    pc.put_checker(sig, len(prompt), prompt + toks, d)
    # exact hit at full length; clone is pristine and independent
    n, clone = pc.get_checker(sig, len(prompt), prompt + toks)
    assert n == len(prompt) + len(toks)
    assert clone.n_mask_memo_hits == 0       # counters reset on snapshot
    assert np.array_equal(clone.mask_bits(), d.mask_bits())
    # longest-prefix: extra generated tokens fall back to the stored cut
    n2, _ = pc.get_checker(sig, len(prompt), prompt + toks + [1, 2])
    assert n2 == len(prompt) + len(toks)
    # SAME token sequence but a different prompt/generated split is a
    # DIFFERENT state (prompts never advance the checker) -> miss
    assert pc.get_checker(sig, len(prompt) - 1, prompt + toks) is None
    assert pc.get_checker(("c",) + sig[1:], len(prompt),
                          prompt + toks) is None


# -- serving: observational purity --------------------------------------------


def _fork_requests(seed=11):
    """Mixed-grammar requests forking a shared preamble at random token
    offsets: greedy + sampled + speculative rows."""
    rng = np.random.default_rng(seed)
    pre = "shared system preamble with many common tokens in front: "
    reqs = []
    for i in range(10):
        cut = int(rng.integers(10, len(pre)))
        prompt = pre[:cut] if i % 3 else pre
        prompt += f"req {i}: "
        if i % 4 == 3:
            spec = ConstraintSpec()                      # unconstrained
        elif i % 2:
            spec = ConstraintSpec(grammar="c", mode="domino")
        else:
            spec = ConstraintSpec(grammar="json", mode="domino")
        dec = DecodeParams(max_tokens=8,
                           temperature=(0.8 if i % 5 == 4 else 0.0),
                           seed=100 + i,
                           speculative=(i % 6 == 2), spec_s=4,
                           spec_threshold=0.0)
        reqs.append(Request(prompt, spec, dec))
    return reqs


def _drive(eng, reqs, prefix_cache, n_pages=220, capacity=3,
           **kw):
    sched = ContinuousBatchingScheduler(
        eng, capacity=capacity, paged=True, page_size=8,
        n_pages=n_pages, prefix_cache=prefix_cache,
        debug_invariants=True, **kw)
    sessions = [sched.submit(r) for r in reqs]
    sched.run()
    return sched, [s.result for s in sessions]


def test_warm_cache_bitwise_identical_to_cold(engine):
    reqs = _fork_requests()
    _, cold = _drive(engine, reqs, prefix_cache=False)
    sched, warm = _drive(engine, reqs, prefix_cache=True)
    for c, w in zip(cold, warm):
        assert w.token_ids == c.token_ids
        assert w.status == c.status
        assert w.finished == c.finished and w.dead_end == c.dead_end
    assert sched.n_prefix_hits > 0 and sched.n_prefix_tokens > 0
    assert any(w.n_cached_prefix_tokens > 0 for w in warm)
    assert all(c.n_cached_prefix_tokens == 0 for c in cold)
    # leak-free drain: all pages back once the cache lets go
    assert check_invariants(sched) == []
    held = sched.prefix_cache.n_pages
    assert sched.pool.available == sched.n_pages - 1 - held
    sched.prefix_cache.reset()
    assert sched.pool.available == sched.n_pages - 1


def test_speculative_rollback_crossing_fork_page(engine):
    """Speculative rows whose rollback rewinds INTO the first private
    page after the fork: the shared boundary is never crossed (the
    frontier floor is one past the shared prefix) and outputs stay
    identical."""
    pre = "shared system preamble with many common tokens in front: "
    reqs = [Request(pre + f"s{i} ",
                    ConstraintSpec(grammar="json", mode="domino"),
                    DecodeParams(max_tokens=10, speculative=True,
                                 spec_s=6, spec_threshold=0.0, seed=i))
            for i in range(4)]
    _, cold = _drive(engine, reqs, prefix_cache=False, capacity=4)
    sched, warm = _drive(engine, reqs, prefix_cache=True, capacity=4)
    for c, w in zip(cold, warm):
        assert w.token_ids == c.token_ids and w.status == c.status
    assert sched.n_prefix_hits > 0
    assert any(r.n_spec_proposed > 0 for r in warm)
    sched.prefix_cache.reset()
    assert sched.pool.available == sched.n_pages - 1


def test_tiny_pool_eviction_and_preemption_pressure(engine):
    """An undersized pool forces cache evictions AND recompute
    preemptions; preempted rows re-acquire their own donated pages
    through the cache on re-admission; outputs stay identical and the
    pool drains leak-free."""
    reqs = _fork_requests(seed=23)
    _, cold = _drive(engine, reqs, prefix_cache=False, n_pages=16,
                     capacity=3)
    sched, warm = _drive(engine, reqs, prefix_cache=True, n_pages=16,
                         capacity=3)
    for c, w in zip(cold, warm):
        assert w.token_ids == c.token_ids and w.status == c.status
    assert sched.prefix_cache.n_evicted > 0
    sched.prefix_cache.reset()
    assert sched.pool.available == sched.n_pages - 1


def test_pinned_prompt_first_request_hits(engine):
    pre = "shared system preamble with many common tokens in front: "
    engine.pin_prompt(pre)
    try:
        sched = ContinuousBatchingScheduler(
            engine, capacity=2, paged=True, page_size=8, n_pages=220,
            prefix_cache=True, debug_invariants=True)
        sched._pin_prompts()
        assert sched.prefix_cache.n_pages > 0
        pinned_pages = sched.prefix_cache.n_pages
        sess = sched.submit(Request(
            pre + "x", ConstraintSpec(grammar="json", mode="domino"),
            DecodeParams(max_tokens=4)))
        sched.run()
        assert sess.result.status == "ok"
        assert sess.result.n_cached_prefix_tokens > 0   # very first request
        assert sched.n_prefix_hits >= 1
        # pinned nodes survive maximal eviction pressure
        sched.prefix_cache.evict(10 ** 6)
        assert sched.prefix_cache.n_pages >= pinned_pages
    finally:
        engine.pinned_prompts.clear()


def test_cache_requires_paged(engine):
    with pytest.raises(ValueError, match="prefix_cache"):
        ContinuousBatchingScheduler(engine, capacity=1, paged=False,
                                    prefix_cache=True)


# -- device-resident fused loop interop ----------------------------------------


@pytest.fixture(scope="module")
def byte_engine(json_grammar):
    """Byte-level tokenizer so the JSON grammar certifies clean and the
    engine builds a device table (the test_device_loop idiom)."""
    corpus = GrammarSampler(json_grammar, seed=7).corpus(80)
    tok = train_bpe(corpus, vocab_size=257)
    cfg = ModelConfig(arch_id="pfx-dev", family="dense",
                      vocab_size=tok.vocab_size, **BASE)
    m = build_model(cfg)
    eng = ServingEngine(m, m.init(jax.random.PRNGKey(0)), tok,
                        max_len=256, device_tables=True)
    eng.register_grammar("json", json_grammar)
    eng.precompute()
    assert "json" in eng.device_tables
    return eng


def test_device_loop_warm_vs_cold(byte_engine):
    """Certified greedy rows riding the fused device loop admit through
    the cache (shared pages block-mapped, tail re-prefilled) and stay
    bitwise-identical to a cold cache, with tokens still committed on
    device."""
    pre = "shared device preamble common to every request: "
    reqs = [Request(pre + f"d{i} ",
                    ConstraintSpec(grammar="json", mode="domino"),
                    DecodeParams(max_tokens=12))
            for i in range(4)]

    def drive(pc):
        sched = ContinuousBatchingScheduler(
            byte_engine, capacity=2, paged=True, page_size=8,
            n_pages=128, prefix_cache=pc, device_loop=True, sync_n=4,
            debug_invariants=True)
        sessions = [sched.submit(r) for r in reqs]
        sched.run()
        return sched, [s.result for s in sessions]

    _, cold = drive(False)
    sched, warm = drive(True)
    for c, w in zip(cold, warm):
        assert w.token_ids == c.token_ids and w.status == c.status
    assert sched.n_prefix_hits > 0
    assert any(w.n_device_tokens > 0 for w in warm)
    sched.prefix_cache.reset()
    assert sched.pool.available == sched.n_pages - 1


# -- crash/restore interop -----------------------------------------------------


def test_restore_adopts_checker_snapshots_bitwise_identical(
        engine, tmp_path):
    """Crash mid-run, restore with the cache enabled: live entries whose
    journaled prefix shares (grammar, prompt, tokens) adopt a cloned
    fork-point snapshot (n_checker_clones > 0), admissions re-acquire
    pages through the cache, and the journal's admit records say so —
    with every restored row bitwise-identical to an uninterrupted run."""
    pre = "shared system preamble with many common tokens in front: "
    reqs = [Request(pre, ConstraintSpec(grammar="json", mode="domino"),
                    DecodeParams(max_tokens=12))
            for _ in range(3)]     # identical prompts -> identical prefixes
    _, ref = _drive(engine, reqs, prefix_cache=True)

    path = os.fspath(tmp_path / "crash.journal")
    journal = TokenJournal(path)
    sched = ContinuousBatchingScheduler(
        engine, capacity=2, paged=True, page_size=8, n_pages=220,
        prefix_cache=True, journal=journal, debug_invariants=True)
    sessions = [sched.submit(r) for r in reqs]
    for _ in range(5):             # part-way: live entries in the journal
        sched.step()
    assert any(s.result is None for s in sessions)
    del sched                      # simulated crash: no drain, no close

    restored = engine.restore(path, max_batch=2, paged=True, page_size=8,
                              n_pages=220, prefix_cache=True,
                              debug_invariants=True)
    assert restored.n_checker_clones > 0
    assert any(s.cached_checker for s in
               list(restored.waiting) + restored.finished)
    restored.run()
    by_rid = {s.rid: s.result for s in restored.finished}
    for rid, want in enumerate(ref):
        assert by_rid[rid].token_ids == want.token_ids
        assert by_rid[rid].status == want.status
    # admit records carry cache adoption for observability
    entries = replay_journal(path)
    assert any(e.n_cached_pages > 0 for e in entries.values())


def test_restore_cold_cache_falls_back_to_full_prefill(engine, tmp_path):
    """The same crash journal restores bitwise-identically WITHOUT the
    cache (full re-prefill fallback)."""
    reqs = [Request("A json value follows: ",
                    ConstraintSpec(grammar="json", mode="domino"),
                    DecodeParams(max_tokens=10)) for _ in range(2)]
    _, ref = _drive(engine, reqs, prefix_cache=True)
    path = os.fspath(tmp_path / "cold.journal")
    sched = ContinuousBatchingScheduler(
        engine, capacity=1, paged=True, page_size=8, n_pages=220,
        prefix_cache=True, journal=TokenJournal(path),
        debug_invariants=True)
    for r in reqs:
        sched.submit(r)
    for _ in range(4):
        sched.step()
    del sched
    restored = engine.restore(path, max_batch=1, paged=True, page_size=8,
                              n_pages=220, debug_invariants=True)
    restored.run()
    by_rid = {s.rid: s.result for s in restored.finished}
    for rid, want in enumerate(ref):
        assert by_rid[rid].token_ids == want.token_ids
