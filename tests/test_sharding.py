"""Sharding rules: specs match param trees, divisibility guard, cache specs."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as shr
from repro.models import build_model


class _FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)


def setup_module():
    shr._AXIS_SIZES = {"data": 16, "model": 16}


def test_param_specs_structure():
    cfg = get_config("gemma3-27b")
    m = build_model(cfg)
    pshape = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = shr.param_specs(cfg, pshape)
    flat_p = jax.tree.leaves(pshape)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is not None:
                assert dim % shr._axis_size(ax) == 0, (leaf.shape, spec)


def test_stacked_group_not_sharded_on_reps():
    cfg = get_config("yi-34b")
    m = build_model(cfg)
    pshape = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = shr.param_specs(cfg, pshape)
    wq_spec = specs["stack"]["group"]["b0"]["attn"]["wq"]
    assert tuple(wq_spec)[0] is None  # reps axis replicated
    assert "model" in tuple(wq_spec)


def test_expert_specs():
    cfg = get_config("deepseek-v3-671b")
    m = build_model(cfg)
    pshape = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    specs = shr.param_specs(cfg, pshape)
    wg = specs["stack"]["group"]["b0"]["moe"]["w_gate"]
    assert tuple(wg)[1] == "model"  # experts axis (after reps)


def test_divisibility_guard():
    # 56 heads * 128 = 7168 columns divides 16; a 6-head dim must not shard
    spec = shr._guard(P("model"), (6,))
    assert tuple(spec) == (None,)
    spec = shr._guard(P(None, "model"), (10, 32))
    assert tuple(spec) == (None, "model")


def test_cache_specs_kv_heads_vs_seq():
    cfg = get_config("yi-34b")           # kv=8, not divisible by 16
    m = build_model(cfg)
    cshape = m.cache_spec(128, 1024)
    specs = shr.cache_specs(cfg, cshape, 128, ("data",))
    kspec = specs["group"]["b0"]["k"]
    # stacked: (None, batch, T:'model', heads None, None)
    assert tuple(kspec)[2] == "model" and tuple(kspec)[3] is None

    cfg2 = get_config("stablelm-1.6b")   # kv=32, divisible
    m2 = build_model(cfg2)
    cshape2 = m2.cache_spec(128, 1024)
    specs2 = shr.cache_specs(cfg2, cshape2, 128, ("data",))
    kspec2 = specs2["group"]["b0"]["k"]
    assert tuple(kspec2)[3] == "model"


def test_batch1_replicated():
    cfg = get_config("falcon-mamba-7b")
    m = build_model(cfg)
    cshape = m.cache_spec(1, 64)
    specs = shr.cache_specs(cfg, cshape, 1, ("data",))
    sspec = specs["group"]["b0"]["ssm"]
    assert tuple(sspec)[1] is None       # batch=1 cannot shard over data=16
    assert tuple(sspec)[2] == "model"    # d_inner sharded
