"""Static grammar x vocabulary analysis (repro.core.analysis).

Positive direction: the shipped zoo grammars certify clean against a
byte-complete vocabulary.  Negative direction: grammars seeded with an
empty-language terminal, a vocabulary alignment gap, or a
never-terminating recursion are each detected with a CONCRETE witness
that reproduces the failure on a real DominoDecoder.
"""
import json as jsonlib
import warnings

import numpy as np
import pytest

from repro.core import grammars
from repro.core.analysis import (AnalysisError, analyze, analyze_static,
                                 dfa_subset, empty_terminals, enforce,
                                 explore_decoder)
from repro.core.domino import DominoDecoder
from repro.core.grammar import parse_grammar
from repro.core.regex import compile_pattern, literal_dfa


def bytes_vocab():
    return [bytes([i]) for i in range(256)] + [None]


EOS = 256


# -- layer 1 -----------------------------------------------------------------


def test_empty_language_terminal_detected():
    g = parse_grammar('start: "a" DEAD\nDEAD: /[^\\x00-\\xff]/\n')
    dead = empty_terminals(g)
    assert len(dead) == 1
    issues = analyze_static(g)
    kinds = {i.kind for i in issues}
    assert "empty-terminal" in kinds
    # a rule requiring an unmatched terminal also kills productivity
    assert "unproductive-nonterminal" in kinds
    assert any(i.severity == "error" for i in issues)


def test_unreachable_and_unproductive():
    g = parse_grammar('''
start: "a"
orphan: "b"
loop: "c" loop
''')
    issues = analyze_static(g)
    by_kind = {}
    for i in issues:
        by_kind.setdefault(i.kind, []).append(i.symbol)
    assert "orphan" in by_kind["unreachable-nonterminal"]
    assert "loop" in by_kind["unreachable-nonterminal"]
    # `loop` is unproductive but UNREACHABLE, so it must not be an error
    assert "loop" not in by_kind.get("unproductive-nonterminal", [])


def test_ignore_shadowing_flagged():
    g = parse_grammar('''
start: WORD SPACE2
WORD: /[a-z]+/
SPACE2: "  "
WS: / +/
%ignore WS
''')
    issues = analyze_static(g)
    shadowed = [i for i in issues if i.kind == "ignore-shadowed-terminal"]
    assert [i.symbol for i in shadowed] == ["SPACE2"]


def test_left_recursion_and_nullable_cycle():
    g = parse_grammar('''
start: e
e: e "+" t | t
t: "x"
''')
    kinds = {(i.kind, i.symbol) for i in analyze_static(g)}
    assert ("left-recursion", "e") in kinds
    g2 = parse_grammar('''
start: a "x"
a: b |
b: a
''')
    kinds2 = {i.kind for i in analyze_static(g2)}
    assert "nullable-cycle" in kinds2


def test_dfa_subset():
    a = literal_dfa("  ")
    b = compile_pattern(" +")
    assert dfa_subset(a, b)
    assert not dfa_subset(b, a)


# -- layer 2: traps, liveness, closure ---------------------------------------


def test_trap_grammar_yields_confirmed_witness():
    g = parse_grammar('start: "a" DEAD "b"\nDEAD: /[^\\x00-\\xff]/\n')
    rep = analyze(g, bytes_vocab(), EOS, name="trapdoor")
    assert not rep.ok()
    assert rep.traps and all(w.confirmed for w in rep.traps)
    # the witness must reproduce a runtime dead end on a FRESH decoder
    w = rep.traps[0]
    d = DominoDecoder(g, bytes_vocab(), EOS)
    for t in w.token_ids:
        assert d.advance(t)
    assert not d.mask_bits().any()     # empty mask, EOS bit included


def test_non_eos_live_detected_with_finite_closure():
    g = parse_grammar('start: "a" loop\nloop: "b" loop\n')
    rep = analyze(g, bytes_vocab(), EOS, name="nolive")
    assert rep.closure.finite
    assert rep.non_eos_live           # every state is a liveness hole
    assert not rep.ok()
    # but none of them is an (empty-mask) trap: decode runs forever
    assert not rep.traps


def test_json_zoo_certifies_clean():
    g = grammars.load("json")
    rep = analyze(g, bytes_vocab(), EOS, name="json")
    assert rep.ok()
    assert rep.closure.finite
    assert not rep.traps and not rep.non_eos_live
    assert not rep.alignment_gaps
    assert rep.n_mask_conflicts == 0
    c = rep.closure
    assert c.table_words == c.n_states * c.mask_words
    assert c.mask_words == (257 + 31) // 32
    # report serializes to JSON (the CI artifact path)
    jsonlib.dumps(rep.to_dict())


def test_exploration_graph_consistency():
    g = grammars.load("arith")
    ex = explore_decoder(g, bytes_vocab(), EOS)
    assert ex.finite
    assert ex.n_states == len(ex.eos_ok) == len(ex.empty_mask)
    # BFS shortest-witness invariant: some state at depth >= 1 exists and
    # the root's path is empty
    assert ex.paths[0] == []
    assert ex.max_fanout >= 1


# -- alignment gaps ----------------------------------------------------------


def test_alignment_gap_against_crippled_vocab():
    # vocabulary has no token containing byte 'q'; QQ is unspellable
    vocab = [bytes([i]) if i != 0x71 else b"#" for i in range(256)]
    vocab.append(None)
    g = parse_grammar('start: "a" QQ\nQQ: "qq"\n')
    rep = analyze(g, vocab, EOS, name="gap")
    gaps = [i.symbol for i in rep.alignment_gaps]
    assert gaps == ["QQ"]
    assert not rep.ok()
    # the same grammar against a byte-complete vocab has no gap
    rep2 = analyze(g, bytes_vocab(), EOS, name="nogap")
    assert not rep2.alignment_gaps
    assert rep2.ok()


def test_multibyte_tokens_can_close_gaps():
    # no single 'q' byte token, but a multi-byte "qq" token spells QQ
    vocab = [bytes([i]) if i != 0x71 else b"#" for i in range(256)]
    vocab.append(b"qq")
    vocab.append(None)                 # EOS = 257
    g = parse_grammar('start: "a" QQ\nQQ: "qq"\n')
    rep = analyze(g, vocab, 257, name="bridged")
    assert not rep.alignment_gaps
    assert rep.ok()


# -- policy enforcement ------------------------------------------------------


def test_enforce_policies():
    g = parse_grammar('start: "a" DEAD\nDEAD: /[^\\x00-\\xff]/\n')
    rep = analyze(g, bytes_vocab(), EOS, name="bad")
    assert enforce(rep, "off") is rep
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        enforce(rep, "warn")
    assert len(w) == 1 and issubclass(w[0].category, RuntimeWarning)
    with pytest.raises(AnalysisError) as ei:
        enforce(rep, "strict")
    assert ei.value.report is rep
    with pytest.raises(ValueError):
        enforce(rep, "nonsense")


def test_enforce_clean_report_is_silent():
    g = grammars.load("arith")
    rep = analyze(g, bytes_vocab(), EOS, name="arith")
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # any warning -> test failure
        enforce(rep, "strict")


# -- CLI ---------------------------------------------------------------------


def test_cli_gate(tmp_path, capsys):
    from repro.analysis.cli import main
    out = tmp_path / "rep.json"
    assert main(["arith", "--strict", "--quiet",
                 "--json", str(out)]) == 0
    payload = jsonlib.loads(out.read_text())
    assert payload["ok"] and "arith" in payload["reports"]
    bad = tmp_path / "bad.lark"
    bad.write_text('start: "a" DEAD\nDEAD: /[^\\x00-\\xff]/\n')
    assert main([str(bad), "--strict", "--quiet"]) == 1
    assert main([str(bad), "--quiet"]) == 0    # non-strict: report only
    assert main(["no-such-grammar"]) == 2


# -- truncation counter (satellite: domino soundness) ------------------------


def test_truncation_counter_surfaces_in_session_result():
    from repro.serving.session import Session

    class _StubChecker:
        n_mask_memo_hits = 3
        n_hyp_truncations = 2
        max_hyp_fanout = 64

    s = Session(rid=0, prompt="p", prompt_ids=[1], checker=_StubChecker(),
                budget=4)
    r = s.finish(lambda ids: "")
    assert r.n_hyp_truncations == 2
    assert r.max_hyp_fanout == 64
    assert r.mask_cache_hits == 3


def test_analyzer_fanout_bounds_runtime_fanout():
    """The analyzer's max_abstract_fanout is measured on real decoders,
    so replaying any explored path can never exceed it."""
    g = grammars.load("json")
    vocab = bytes_vocab()
    rep = analyze(g, vocab, EOS, name="json")
    d = DominoDecoder(g, vocab, EOS)
    text = b'{"k": [1, 2]}'
    for b in text:
        assert d.advance(b)
        assert len(d.hyps) <= rep.max_abstract_fanout + 1
    assert d.n_hyp_truncations == 0
