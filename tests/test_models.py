"""Model substrate: train/prefill/decode equivalence for every family,
flash-attention correctness (fwd + custom_vjp bwd), SSM chunking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models import build_model
from repro.models.flash import blocked_attention, naive_attention

BASE = dict(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=128, dtype="float32", max_seq_len=64)

CONFIGS = {
    "dense": ModelConfig(arch_id="t-dense", family="dense", **BASE),
    "swa": ModelConfig(arch_id="t-swa", family="dense",
                       group=("swa", "attn"), sliding_window=8, **BASE),
    "moe": ModelConfig(arch_id="t-moe", family="moe", group=("moe",),
                       moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                                     n_shared_experts=1,
                                     dense_residual_d_ff=32,
                                     capacity_factor=2.0), **BASE),
    "mamba1": ModelConfig(arch_id="t-m1", family="ssm", group=("mamba1",),
                          ssm=SSMConfig(d_state=8, version=1), **BASE),
    "hybrid": ModelConfig(arch_id="t-m2", family="hybrid",
                          group=("mamba2", "mamba2", "shared_attn"),
                          ssm=SSMConfig(d_state=8, version=2, head_dim=16),
                          **BASE),
    "mla-moe": ModelConfig(arch_id="t-mla", family="moe", group=("moe",),
                           mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                         qk_nope_head_dim=16,
                                         qk_rope_head_dim=8, v_head_dim=16),
                           moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                                         n_shared_experts=1,
                                         capacity_factor=2.0), **BASE),
    "whisper": ModelConfig(arch_id="t-wh", family="audio", group=("xattn",),
                           is_encoder_decoder=True, n_encoder_layers=2,
                           encoder_seq_len=12, **BASE),
    "vlm": ModelConfig(arch_id="t-vlm", family="vlm", group=("swa",),
                       sliding_window=8, n_prefix_tokens=4, **BASE),
}


@pytest.mark.parametrize("family", list(CONFIGS))
def test_decode_matches_train(family):
    cfg = CONFIGS[family]
    m = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)
    B, S = 2, 16
    batch = m.example_batch(B, S, rng)
    train_in = {k: (v[:, :-1] if k == "tokens" else v)
                for k, v in batch.items()}
    logits, aux = m.train_logits(params, train_in)
    assert not np.any(np.isnan(np.asarray(logits, dtype=np.float32)))
    assert float(aux) >= 0.0
    toks = train_in["tokens"]
    n_pre = 8
    cache = m.init_cache(B, toks.shape[1] + 8)
    pre = {k: (v[:, :n_pre] if k == "tokens" else v)
           for k, v in train_in.items()}
    lg, cache = m.prefill(params, pre, cache)
    off = logits.shape[1] - toks.shape[1]
    np.testing.assert_allclose(np.asarray(lg[:, -1]),
                               np.asarray(logits[:, off + n_pre - 1]),
                               atol=2e-2, rtol=1e-2)
    # single-token decode
    for i in range(n_pre, n_pre + 3):
        lg, cache = m.decode_step(params, cache, toks[:, i:i + 1])
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits[:, off + i]),
                                   atol=2e-2, rtol=1e-2)
    # multi-token speculative verification step
    j0 = n_pre + 3
    width = min(3, toks.shape[1] - j0)
    if width > 1:
        lgm, _ = m.decode_step(params, cache, toks[:, j0:j0 + width])
        for j in range(width):
            np.testing.assert_allclose(np.asarray(lgm[:, j]),
                                       np.asarray(logits[:, off + j0 + j]),
                                       atol=2e-2, rtol=1e-2)


def test_loss_decreases_one_step():
    cfg = CONFIGS["dense"]
    m = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = m.init(rng)
    batch = {"tokens": jax.random.randint(rng, (4, 17), 0, 128, jnp.int32)}
    loss0, _ = m.loss(params, batch)
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    params2 = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    loss1, _ = m.loss(params2, batch)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_blocked_vs_naive_attention(window, dtype):
    rng = np.random.default_rng(0)
    b, s, g, qh, d = 2, 64, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, g, qh, d)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(b, s, g, d)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(b, s, g, d)), dtype=dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    o1 = blocked_attention(q, k, v, pos, pos, window, None, 16, 32)
    o2 = naive_attention(q, k, v, pos, pos, window)
    atol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol,
                               rtol=1e-2)


def test_flash_custom_vjp_grads():
    rng = np.random.default_rng(3)
    b, s, g, qh, d = 2, 64, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, g, qh, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, g, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, g, d)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    for window in (None, 16):
        f1 = lambda q, k, v: (blocked_attention(
            q, k, v, pos, pos, window, None, 16, 32) ** 2).sum()
        f2 = lambda q, k, v: (naive_attention(
            q, k, v, pos, pos, window) ** 2).sum()
        g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
        for a, bb in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                       atol=2e-4, rtol=1e-3)


def test_mamba_chunking_invariance():
    """The chunked scan must not depend on chunk size."""
    import repro.models.ssm as ssm_mod
    cfg = CONFIGS["mamba1"]
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 33),
                                          0, 128, jnp.int32)}
    orig = ssm_mod.CHUNK
    try:
        ssm_mod.CHUNK = 8
        l8, _ = m.train_logits(params, {"tokens": batch["tokens"][:, :-1]})
        ssm_mod.CHUNK = 16
        l16, _ = m.train_logits(params, {"tokens": batch["tokens"][:, :-1]})
    finally:
        ssm_mod.CHUNK = orig
    np.testing.assert_allclose(np.asarray(l8), np.asarray(l16), atol=2e-4,
                               rtol=1e-4)


def test_rollback_full_attention():
    cfg = CONFIGS["dense"]
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, 128,
                              jnp.int32)
    cache = m.init_cache(1, 20)
    lg, cache = m.prefill(params, {"tokens": toks[:, :6]}, cache)
    # speculate 3, reject all, rollback, decode the true token
    _, cache_spec = m.decode_step(params, cache, toks[:, 6:9])
    cache_rb = m.rollback(cache_spec, 3)
    lg1, _ = m.decode_step(params, cache_rb, toks[:, 6:7])
    lg2, _ = m.decode_step(params, cache, toks[:, 6:7])
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=1e-5)
