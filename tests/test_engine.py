"""Serving engine integration: every constraint mode emits grammar-valid
output; speculation reduces forward passes on schema-heavy grammars."""
import dataclasses

import jax
import pytest

from repro.configs.base import ModelConfig
from repro.core import grammars
from repro.core.baselines import Fixed, Gen
from repro.core.domino import DominoDecoder
from repro.models import build_model
from repro.serving import EngineConfig, ServingEngine


@pytest.fixture(scope="module")
def setup(request):
    tok = request.getfixturevalue("small_tokenizer")
    cfg = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32",
                      max_seq_len=512)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return m, params, tok


# make module-scope fixture able to use session fixture
@pytest.fixture(scope="module")
def small_tokenizer_mod(small_tokenizer):
    return small_tokenizer


@pytest.mark.parametrize("mode", ["domino", "naive", "online"])
def test_output_is_grammar_valid(setup, json_grammar, mode):
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode=mode, max_tokens=24), max_len=512)
    r = eng.generate("data: ")
    d = DominoDecoder(json_grammar, list(tok.vocab), tok.eos_id)
    for t in r.token_ids:
        assert d.advance(t), tok.vocab[t]
    if r.finished:
        assert d.eos_legal()


def test_unconstrained_runs(setup, json_grammar):
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, None,
                        EngineConfig(mode="unconstrained", max_tokens=10),
                        max_len=512)
    r = eng.generate("x")
    assert r.n_tokens <= 10 and r.n_forward_passes >= 1


def test_opportunistic_same_output(setup, json_grammar):
    m, params, tok = setup
    r1 = ServingEngine(m, params, tok, json_grammar,
                       EngineConfig(mode="domino", max_tokens=16),
                       max_len=512).generate("q: ")
    r2 = ServingEngine(m, params, tok, json_grammar,
                       EngineConfig(mode="domino", opportunistic=True,
                                    max_tokens=16),
                       max_len=512).generate("q: ")
    assert r1.token_ids == r2.token_ids


def test_speculation_saves_forward_passes(setup):
    m, params, tok = setup
    g = grammars.load("json_gsm8k")  # schema-heavy => predictable
    base = ServingEngine(m, params, tok, g,
                         EngineConfig(mode="domino", max_tokens=24),
                         max_len=512)
    r0 = base.generate("A: ")
    spec_eng = ServingEngine(m, params, tok, g,
                             EngineConfig(mode="domino", speculative=True,
                                          spec_s=6, spec_threshold=0.4,
                                          max_tokens=24), max_len=512)
    spec_eng.generate("A: ")          # warm the count model
    r1 = spec_eng.generate("A: ")
    assert r1.token_ids == r0.token_ids, "speculation must not change output"
    assert r1.n_forward_passes < r0.n_forward_passes
    assert r1.n_spec_accepted > 0


def test_speculation_with_refeed_arch(small_tokenizer):
    """SWA archs use the snapshot+refeed rollback path."""
    tok = small_tokenizer
    cfg = ModelConfig(arch_id="t-swa", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size, dtype="float32",
                      group=("swa",), sliding_window=16, max_seq_len=512)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    g = grammars.load("json_gsm8k")
    base = ServingEngine(m, params, tok, g,
                         EngineConfig(mode="domino", max_tokens=20),
                         max_len=512)
    r0 = base.generate("A: ")
    eng = ServingEngine(m, params, tok, g,
                        EngineConfig(mode="domino", speculative=True,
                                     spec_s=4, spec_threshold=0.4,
                                     max_tokens=20), max_len=512)
    assert eng._needs_refeed
    eng.generate("A: ")
    r1 = eng.generate("A: ")
    assert r1.token_ids == r0.token_ids


def test_template_mode(setup):
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, None,
                        EngineConfig(mode="unconstrained", max_tokens=40),
                        max_len=512)
    parts = [Fixed('{"id": '), Gen(r"[1-9][0-9]*", max_tokens=3),
             Fixed(', "name": "'), Gen(r"[a-z]+", max_tokens=4),
             Fixed('"}')]
    r = eng.generate_template("obj: ", parts)
    text = r.text
    assert text.startswith('{"id": ')
    assert text.endswith('"}')
    assert r.n_interventions > 0  # forced tokens counted
