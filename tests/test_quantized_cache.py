"""int8 KV cache (§Perf pair 3 optimization): close to the bf16 path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import build_model

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=128, dtype="float32", max_seq_len=64)


@pytest.mark.parametrize("group,window", [(("attn",), None), (("swa",), 8)])
def test_int8_cache_close_to_native(group, window):
    cfg = ModelConfig(arch_id="q", family="dense", group=group,
                      sliding_window=window, **BASE)
    cfg_q = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m, mq = build_model(cfg), build_model(cfg_q)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 14), 0, 128,
                              jnp.int32)
    lg_ref, _ = m.train_logits(params, {"tokens": toks})
    c = mq.init_cache(2, 20)
    assert c["group"]["b0"]["k"].dtype == jnp.int8
    lg, c = mq.prefill(params, {"tokens": toks[:, :8]}, c)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(lg_ref[:, 7]), atol=0.25, rtol=0.1)
    for i in range(8, 12):
        lg, c = mq.decode_step(params, c, toks[:, i:i + 1])
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(lg_ref[:, i]), atol=0.25,
                                   rtol=0.1)


def test_int8_cache_memory_shape():
    cfg = ModelConfig(arch_id="q", family="dense",
                      kv_cache_dtype="int8", **BASE)
    m = build_model(cfg)
    spec = m.cache_spec(4, 32)
    blk = spec["group"]["b0"]
    assert blk["k"].dtype == jnp.int8
    assert blk["k_scale"].shape == (2, 4, 32, 2)  # (reps, B, T, nkv)
