"""Grammar parsing + Earley recognizer."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import grammars
from repro.core.earley import EarleyParser, parse_terminals
from repro.core.grammar import GrammarSyntaxError, parse_grammar
from repro.core.sampling import GrammarSampler


def _tid(g, name):
    return {t.name: i for i, t in enumerate(g.terminals)}[name]


def test_json_sequences(json_grammar):
    g = json_grammar
    LB, RB = _tid(g, "'{'"), _tid(g, "'}'")
    CM, CL = _tid(g, "','"), _tid(g, "':'")
    LK, RK = _tid(g, "'['"), _tid(g, "']'")
    S, N = _tid(g, "STRING"), _tid(g, "NUMBER")
    assert parse_terminals(g, [LB, RB])
    assert parse_terminals(g, [LB, S, CL, LK, N, CM, N, RK, RB])
    assert not parse_terminals(g, [LB, S, CL, RB])
    assert not parse_terminals(g, [LB, CM, RB])
    assert not parse_terminals(g, [])


def test_allowed_terminals(json_grammar):
    g = json_grammar
    p = EarleyParser(g)
    names = {g.terminals[t].name for t in p.allowed_terminals()}
    assert names == {"'{'", "'['", "STRING", "NUMBER", "BOOL", "NULL"}
    assert p.advance(_tid(g, "'{'"))
    names = {g.terminals[t].name for t in p.allowed_terminals()}
    assert names == {"'}'", "STRING"}


def test_fork_isolation(json_grammar):
    g = json_grammar
    p = EarleyParser(g)
    p.advance(_tid(g, "'{'"))
    q = p.fork()
    assert q.advance(_tid(g, "'}'"))
    assert q.accepts()
    assert not p.accepts()
    assert p.position == 1 and q.position == 2


def test_ambiguous_grammar():
    g = parse_grammar("""
start: e
e: INT | e "+" e
INT: /[0-9]+/
""")
    i, pl = 0, 1
    tid = {t.name: j for j, t in enumerate(g.terminals)}
    seq = [tid["INT"], tid["'+'"], tid["INT"], tid["'+'"], tid["INT"]]
    assert parse_terminals(g, seq)
    assert not parse_terminals(g, seq[:-1])


def test_nullable_rules():
    g = parse_grammar("""
start: a b a
a: ("x")?
b: "y"
""")
    tid = {t.name: j for j, t in enumerate(g.terminals)}
    X, Y = tid["'x'"], tid["'y'"]
    assert parse_terminals(g, [Y])
    assert parse_terminals(g, [X, Y])
    assert parse_terminals(g, [Y, X])
    assert parse_terminals(g, [X, Y, X])
    assert not parse_terminals(g, [X, X, Y])


def test_syntax_errors():
    with pytest.raises(GrammarSyntaxError):
        parse_grammar("start: UNDEF\n")
    with pytest.raises(GrammarSyntaxError):
        parse_grammar("TERM: /a*/\nstart: TERM\n")  # empty-matching terminal


@pytest.mark.parametrize("name", list(grammars.GRAMMARS))
def test_workload_grammars_load(name):
    g = grammars.load(name)
    assert g.n_terminals > 0 and len(g.rules) > 0
    g.describe()


@pytest.mark.parametrize("name", ["json", "json_gsm8k", "xml_schema", "c"])
def test_sampled_strings_parse_at_terminal_level(name):
    """Property: sampling then re-lexing through DOMINO accepts (end-to-end
    check lives in test_domino); here we check the sampler+grammar agree."""
    from repro.core.domino import DominoDecoder
    g = grammars.load(name)
    vocab = [bytes([i]) for i in range(256)] + [None]
    d0 = DominoDecoder(g, vocab, eos_id=256)
    sampler = GrammarSampler(g, seed=11)
    for _ in range(5):
        s = sampler.sample()
        d = d0.clone()
        for b in s:
            assert d.advance(b), (name, s, bytes([b]))
        assert d.eos_legal(), (name, s)
