"""Property tests over RANDOM small CFGs (hypothesis):

1. completeness/minimal invasiveness — every sampled grammar string, under
   any byte-level tokenization, is accepted token-by-token and ends with
   legal EOS;
2. mask equality — DOMINO(k=inf) == full-vocabulary online checking;
3. soundness — following only-masked tokens never dead-ends.
"""
import random

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.baselines import OnlineParserDecoder
from repro.core.domino import DominoDecoder
from repro.core.grammar import parse_grammar
from repro.core.sampling import GrammarSampler

TERM_POOL = [
    ('NUM', r'/[0-9]+/'),
    ('ID', r'/[a-z]+/'),
    ('STR', r'/"[a-z]*"/'),
    ('OPA', '"+"'),
    ('LP', '"("'),
    ('RP', '")"'),
    ('COMMA', '","'),
]

VOCAB = [bytes([i]) for i in range(33, 127)] + [
    b"ab", b'("', b'")', b"1,", b",,", b'+(', b"12", b'"a"', b"a1",
    b"((", b"))", None]
EOS = len(VOCAB) - 1


@st.composite
def random_grammar(draw):
    n_terms = draw(st.integers(3, len(TERM_POOL)))
    terms = TERM_POOL[:n_terms]
    lines = [f"{n}: {p}" for n, p in terms]
    names = [n for n, _ in terms]
    # start: one of three shapes over random terminals
    shape = draw(st.integers(0, 2))
    a = draw(st.sampled_from(names))
    b = draw(st.sampled_from(names))
    if shape == 0:
        lines.insert(0, f"start: {a} ({b} {a})*")
    elif shape == 1:
        lines.insert(0, f"start: e\ne: {a} | LP e RP" if "LP" in names
                     and "RP" in names else f"start: {a} {b}?")
    else:
        lines.insert(0, f"start: ({a} | {b})+")
    return "\n".join(lines)


def _random_tokenize(text: bytes, rng: random.Random):
    from repro.core.retokenize import prefix_tokens
    from repro.core.trees import VocabTrie
    trie = VocabTrie.build(list(VOCAB))
    out, rest = [], text
    while rest:
        cands = prefix_tokens(trie, rest)
        if not cands:
            return None
        out.append(rng.choice(cands))
        rest = rest[len(VOCAB[out[-1]]):]
    return out


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_grammar(), st.integers(0, 10000))
def test_sampled_strings_accepted_any_tokenization(gtext, seed):
    g = parse_grammar(gtext)
    sampler = GrammarSampler(g, seed=seed, max_depth=8)
    rng = random.Random(seed)
    d0 = DominoDecoder(g, VOCAB, eos_id=EOS)
    for _ in range(2):
        text = sampler.sample(max_ws=0.0)
        ids = _random_tokenize(text, rng)
        if ids is None:
            continue
        d = d0.clone()
        for t in ids:
            assert d.mask()[t], (gtext, text, VOCAB[t])
            assert d.advance(t)
        assert d.eos_legal(), (gtext, text)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_grammar(), st.integers(0, 10000))
def test_domino_equals_online(gtext, seed):
    g = parse_grammar(gtext)
    d1 = DominoDecoder(g, VOCAB, eos_id=EOS)
    d2 = OnlineParserDecoder(g, VOCAB, eos_id=EOS)
    rng = random.Random(seed)
    for _ in range(5):
        m1, m2 = d1.mask(), d2.mask()
        assert (m1 == m2).all(), \
            (gtext, [VOCAB[i] for i in np.where(m1 != m2)[0]])
        legal = [t for t in np.where(m1)[0] if t != EOS]
        if not legal:
            break
        t = rng.choice(legal)
        assert d1.advance(t) and d2.advance(t)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(random_grammar(), st.integers(0, 10000))
def test_soundness_no_dead_ends(gtext, seed):
    """Following masked tokens for 12 steps: the mask never goes empty
    (EOS counts), i.e. constrained decoding cannot paint itself into a
    corner."""
    g = parse_grammar(gtext)
    d = DominoDecoder(g, VOCAB, eos_id=EOS)
    rng = random.Random(seed)
    for _ in range(12):
        m = d.mask()
        assert m.any(), (gtext, "dead end")
        t = int(rng.choice(np.where(m)[0]))
        assert d.advance(t)
        if t == EOS:
            break
