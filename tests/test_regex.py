"""Regex engine: unit tests + hypothesis property vs Python's re."""
import re as stdre

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.regex import RegexSyntaxError, compile_pattern, literal_dfa


CASES = [
    (r"[1-9][0-9]*|0+", ["0", "00", "7", "123"], ["", "012", "1a", "a"]),
    (r"a+b?c*", ["a", "ab", "aacc", "abccc"], ["", "b", "ba", "abab"]),
    (r"(ab|cd)+", ["ab", "abcd", "cdcdab"], ["", "a", "abc"]),
    (r"a{2,4}", ["aa", "aaa", "aaaa"], ["a", "aaaaa", ""]),
    (r"a{3}", ["aaa"], ["aa", "aaaa"]),
    (r"[^x]+", ["abc", " "], ["", "axb"]),
    (r"\d+\.\d+", ["3.14"], ["3.", ".14", "3"]),
    (r'"([^"\\]|\\(["\\/bfnrt]|u[0-9a-fA-F]{4}))*"',
     ['""', '"ab"', '"a\\"b"', '"\\u00Ff"'],
     ['"', '"a', '"\\q"', '"a"b"']),
    (r"(//)[^\n]*\n", ["// hi\n", "//\n"], ["//", "/ x\n"]),
]


@pytest.mark.parametrize("pattern,accepts,rejects", CASES)
def test_cases(pattern, accepts, rejects):
    dfa = compile_pattern(pattern)
    for s in accepts:
        assert dfa.matches(s.encode()), (pattern, s)
    for s in rejects:
        assert not dfa.matches(s.encode()), (pattern, s)


def test_literal():
    d = literal_dfa("while")
    assert d.matches(b"while")
    assert not d.matches(b"whil")
    assert not d.matches(b"whilex")


def test_syntax_errors():
    for bad in ["(", "[", "a|*", "*a"]:
        with pytest.raises(RegexSyntaxError):
            compile_pattern(bad)


def test_dead_state_pruning():
    # every state can reach acceptance -> can_continue is meaningful
    d = compile_pattern(r"ab|ac")
    for s in range(d.n_states):
        assert d.can_continue(s) or d.is_accept(s)


# a conservative pattern subset where our semantics == python re fullmatch
_ATOMS = ["a", "b", "c", "[ab]", "[^a]", "[a-c]", r"\d"]


@st.composite
def _patterns(draw, depth=2):
    if depth == 0:
        return draw(st.sampled_from(_ATOMS))
    kind = draw(st.integers(0, 4))
    if kind == 0:
        return draw(st.sampled_from(_ATOMS))
    if kind == 1:
        return "(" + draw(_patterns(depth=depth - 1)) + ")" + \
            draw(st.sampled_from(["*", "+", "?", ""]))
    if kind == 2:
        return "(" + draw(_patterns(depth=depth - 1)) + "|" + \
            draw(_patterns(depth=depth - 1)) + ")"
    return draw(_patterns(depth=depth - 1)) + draw(_patterns(depth=depth - 1))


@settings(max_examples=60, deadline=None)
@given(_patterns(), st.text(alphabet="abc0", max_size=6))
def test_matches_stdlib(pattern, text):
    ours = compile_pattern(pattern).matches(text.encode())
    theirs = stdre.fullmatch(pattern, text) is not None
    assert ours == theirs, (pattern, text)
