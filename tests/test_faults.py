"""Fault-tolerant serving: status taxonomy, deadlines, cancellation,
graceful degradation, and seeded chaos storms.

Acceptance (ISSUE 7): with seeded faults injected into >= 3 distinct tick
phases, the scheduler leaks no pages or slots (invariant checker clean at
every tick boundary), every affected request reaches an explicit non-`ok`
terminal status, and every unaffected row's output is bitwise-identical
to a fault-free run.

One deliberate carve-out in the storm assertions: a row whose injected
NaN is erased by a recompute preemption BEFORE the selection phase reads
it (the preempted row is re-prefilled from scratch) legitimately
completes `ok` with fault-free output — so storm-affected rows must be
non-ok OR bitwise-equal, while the targeted tests (no page pressure, no
preemption) pin the strict non-ok outcome.
"""
import dataclasses
import importlib.util
import os

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import grammars
from repro.serving import (ConstraintSpec, ContinuousBatchingScheduler,
                           DecodeParams, DegradationSupervisor,
                           EngineConfig, FaultInjector, Request,
                           ServingEngine, check_invariants)
from repro.serving.faults import SITES, FaultRecord, InvariantViolation
from repro.models import build_model

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            dtype="float32", max_seq_len=512)

PROMPTS = ["a: ", "some much longer json prompt here: ", "x",
           "record -> ", "{", "data: "]


@pytest.fixture(scope="module")
def attn(small_tokenizer):
    cfg = ModelConfig(arch_id="f-attn", family="dense",
                      vocab_size=small_tokenizer.vocab_size, **BASE)
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _engine(attn, tok, grammar, max_tokens=10, max_len=256, **cfg_kw):
    m, params = attn
    return ServingEngine(m, params, tok, grammar,
                         EngineConfig(mode="domino", max_tokens=max_tokens,
                                      **cfg_kw),
                         max_len=max_len)


def _by_rid(sessions):
    return {s.rid: s.result for s in sessions}


# -- lifecycle: statuses, cancel, deadlines, queue bounds ----------------------


def test_ok_status_on_normal_completion(attn, small_tokenizer,
                                        json_grammar):
    eng = _engine(attn, small_tokenizer, json_grammar)
    r = eng.generate("a: ")
    assert r.status == "ok" and r.ok and r.error is None


def test_cancel_waiting_and_resident(attn, small_tokenizer, json_grammar):
    eng = _engine(attn, small_tokenizer, json_grammar, max_tokens=50)
    sched = ContinuousBatchingScheduler(eng, capacity=1,
                                       debug_invariants=True)
    s0 = sched.submit("a: ")
    s1 = sched.submit("x")
    sched.step()                       # s0 resident, s1 waiting
    assert s0.slot >= 0 and s1.slot == -1
    assert sched.cancel(s0.rid) is True
    assert sched.cancel(s1.rid) is True
    assert sched.cancel(999) is False   # unknown rid
    sched.step()                       # cancellations honored at boundary
    assert s0.result.status == "cancelled"
    assert s1.result.status == "cancelled"
    assert "decoding" in s0.result.error
    assert "waiting" in s1.result.error
    # slot + pages back for reuse
    assert all(s is None for s in sched.slots)
    if sched.paged:
        assert sched.pool.available == sched.n_pages - 1
    assert sched.cancel(s0.rid) is False   # already terminal
    assert sched.run() == [s0.result, s1.result]   # reported in rid order


def test_deadline_in_queue_and_mid_flight(attn, small_tokenizer,
                                          json_grammar):
    eng = _engine(attn, small_tokenizer, json_grammar, max_tokens=50)
    sched = ContinuousBatchingScheduler(eng, capacity=1,
                                       debug_invariants=True)
    # queued request with an already-expired deadline never runs
    s0 = sched.submit(Request("a: ", ConstraintSpec(grammar="default",
                                                    mode="domino"),
                              DecodeParams(max_tokens=50,
                                           deadline_s=1e-9)))
    sched.step()
    assert s0.result.status == "deadline_exceeded"
    assert s0.result.n_tokens == 0
    # resident request overruns mid-flight: terminated at the next tick
    # boundary with its partial output intact
    s1 = sched.submit(Request("a: ", ConstraintSpec(grammar="default",
                                                    mode="domino"),
                              DecodeParams(max_tokens=50,
                                           deadline_s=30.0)))
    sched.step()
    assert s1.slot >= 0 and s1.result is None
    s1.t_submit -= 100.0               # simulate elapsed wall time
    sched.step()
    assert s1.result.status == "deadline_exceeded"
    assert all(s is None for s in sched.slots)
    if sched.paged:
        assert sched.pool.available == sched.n_pages - 1


def test_default_deadline_applies_when_request_has_none(
        attn, small_tokenizer, json_grammar):
    eng = _engine(attn, small_tokenizer, json_grammar, max_tokens=50)
    sched = ContinuousBatchingScheduler(eng, capacity=1,
                                       default_deadline_s=1e-9)
    s0 = sched.submit("a: ")
    sched.step()
    assert s0.result.status == "deadline_exceeded"


def test_single_request_deadline(attn, small_tokenizer, json_grammar):
    eng = _engine(attn, small_tokenizer, json_grammar, max_tokens=200)
    r = eng.generate(Request("a: ",
                             ConstraintSpec(grammar="default",
                                            mode="domino"),
                             DecodeParams(max_tokens=200,
                                          deadline_s=1e-9)))
    assert r.status == "deadline_exceeded"
    assert not r.ok and r.error


def test_queue_limit_sheds_overflow(attn, small_tokenizer, json_grammar):
    eng = _engine(attn, small_tokenizer, json_grammar)
    sched = ContinuousBatchingScheduler(eng, capacity=1, queue_limit=2)
    sessions = [sched.submit(p) for p in PROMPTS[:5]]
    shed = [s for s in sessions if s.result is not None]
    assert len(shed) == 3              # queue holds 2, rest rejected now
    assert all(s.result.status == "rejected" for s in shed)
    assert all("queue_limit" in s.result.error for s in shed)
    results = sched.run()
    assert len(results) == 5           # rejections are reported too
    ok = [s for s in sessions if s.result.status == "ok"]
    assert len(ok) == 2


def test_queue_wait_timeout(attn, small_tokenizer, json_grammar):
    eng = _engine(attn, small_tokenizer, json_grammar)
    sched = ContinuousBatchingScheduler(eng, capacity=1,
                                       queue_timeout_s=0.0)
    s0 = sched.submit("a: ")
    sched.step()
    assert s0.result.status == "rejected"
    assert "timeout" in s0.result.error


# -- admission: livelock fix ---------------------------------------------------


def test_oversized_prompt_rejected_not_livelocked(attn, small_tokenizer,
                                                  json_grammar):
    """A prompt needing more pages than the POOL holds used to block the
    FIFO head forever; now it is rejected with a reason and the request
    behind it completes normally."""
    eng = _engine(attn, small_tokenizer, json_grammar)
    big = "{\"k\": [" + ", ".join(str(i) for i in range(80)) + "]} "
    sched = ContinuousBatchingScheduler(eng, capacity=2, paged=True,
                                       page_size=16, n_pages=4,
                                       debug_invariants=True)
    n_big = len(small_tokenizer.encode(big))
    assert n_big + 1 > (sched.n_pages - 1) * sched.page_size
    baseline = eng.generate("a: ")
    s_big = sched.submit(big)
    s_ok = sched.submit("a: ")
    results = sched.run()
    assert len(results) == 2
    assert s_big.result.status == "rejected"
    assert "pool" in s_big.result.error
    assert s_ok.result.status == "ok"
    assert s_ok.result.token_ids == baseline.token_ids
    assert sched.pool.available == sched.n_pages - 1


def test_prompt_beyond_max_len_rejected_dense(attn, small_tokenizer,
                                              json_grammar):
    eng = _engine(attn, small_tokenizer, json_grammar, max_len=32)
    big = "{\"k\": [" + ", ".join(str(i) for i in range(80)) + "]} "
    assert len(small_tokenizer.encode(big)) + 1 > 32
    sched = ContinuousBatchingScheduler(eng, capacity=1, paged=False)
    s_big = sched.submit(big)
    s_ok = sched.submit("a: ")
    sched.run()
    assert s_big.result.status == "rejected"
    assert "max_len" in s_big.result.error
    assert s_ok.result.status == "ok"


# -- targeted quarantine: one faulted row, batch-mates bitwise-identical -------


def _quarantine_run(attn, tok, grammar, site, target_rid, **inj_kw):
    """Run PROMPTS[:3] fault-free and with one targeted fault; return
    (baseline rid->result, faulted rid->result, scheduler)."""
    eng = _engine(attn, tok, grammar)
    base = ContinuousBatchingScheduler(eng, capacity=3)
    base_sess = [base.submit(p) for p in PROMPTS[:3]]
    base.run()
    inj = FaultInjector(seed=0, rates={site: 1.0}, targets={target_rid},
                        max_faults=1, **inj_kw)
    sched = ContinuousBatchingScheduler(eng, capacity=3,
                                       fault_injector=inj,
                                       debug_invariants=True)
    sess = [sched.submit(p) for p in PROMPTS[:3]]
    sched.run()
    assert inj.n_fired(site) == 1
    assert inj.faulted_rids() == {target_rid}
    return _by_rid(base_sess), _by_rid(sess), sched


@pytest.mark.parametrize("site,err_frag", [
    ("mask_error", "checker failed"),
    ("decode_nan", "non-finite"),
    ("prefill_nan", "non-finite"),
    ("advance_error", "checker failed"),
])
def test_targeted_fault_quarantined_to_one_row(attn, small_tokenizer,
                                               json_grammar, site,
                                               err_frag):
    """Exactly the targeted row fails (explicit internal_error + reason);
    every batch-mate's output is bitwise-equal to the fault-free run.
    No page pressure here, so no preemption can erase the fault."""
    target = 1
    base, faulted, sched = _quarantine_run(
        attn, small_tokenizer, json_grammar, site, target)
    assert faulted[target].status == "internal_error"
    assert err_frag in faulted[target].error
    # partial output is a prefix of the fault-free output (never junk)
    n = faulted[target].n_tokens
    assert faulted[target].token_ids == base[target].token_ids[:n]
    if site == "prefill_nan":
        assert n == 0                  # corrupted before any commit
    for rid in (0, 2):
        assert faulted[rid].status == "ok"
        assert faulted[rid].token_ids == base[rid].token_ids
    if sched.paged:
        assert sched.pool.available == sched.n_pages - 1
    assert all(s is None for s in sched.slots)


def test_advance_error_during_speculation_quarantined(attn,
                                                      small_tokenizer):
    """Speculative rows: a checker failure inside the verify loop evicts
    only that row; the plain batch-mate is untouched."""
    m, params = attn
    g = grammars.load("json_gsm8k")
    eng = ServingEngine(m, params, small_tokenizer, g,
                        EngineConfig(mode="domino", speculative=True,
                                     spec_s=4, spec_threshold=0.4,
                                     max_tokens=16), max_len=256)
    prompts = ["A: ", "Q: compute 1 + 2\nA: "]
    base = ContinuousBatchingScheduler(eng, capacity=2)
    base_sess = [base.submit(p) for p in prompts]
    base.run()
    inj = FaultInjector(seed=0, rates={"advance_error": 1.0},
                        targets={1}, max_faults=1)
    sched = ContinuousBatchingScheduler(eng, capacity=2,
                                       fault_injector=inj,
                                       debug_invariants=True)
    sess = [sched.submit(p) for p in prompts]
    sched.run()
    assert sess[1].result.status == "internal_error"
    assert sess[0].result.status == "ok"
    assert sess[0].result.token_ids == base_sess[0].result.token_ids
    assert sched.pool.available == sched.n_pages - 1


def test_page_exhaustion_storm_is_output_invariant(attn, small_tokenizer,
                                                   json_grammar):
    """Injected pool exhaustion only drives backpressure and recompute
    preemption — both output-invariant — so EVERY request still completes
    ok with fault-free output, and the pool drains leak-free."""
    eng = _engine(attn, small_tokenizer, json_grammar)
    base = ContinuousBatchingScheduler(eng, capacity=2, paged=True,
                                      page_size=16, n_pages=12)
    base_sess = [base.submit(p) for p in PROMPTS]
    base.run()
    inj = FaultInjector(seed=3, rates={"page_exhaustion": 0.4},
                        max_faults=20)
    sched = ContinuousBatchingScheduler(eng, capacity=2, paged=True,
                                       page_size=16, n_pages=12,
                                       fault_injector=inj,
                                       debug_invariants=True)
    sess = [sched.submit(p) for p in PROMPTS]
    sched.run()
    assert inj.n_fired() > 0
    for b, f in zip(base_sess, sess):
        assert f.result.status == "ok"
        assert f.result.token_ids == b.result.token_ids
    assert sched.pool.available == sched.n_pages - 1
    assert not sched._page_tbl.any()


# -- invariant checker ---------------------------------------------------------


def test_invariant_checker_clean_then_detects_corruption(
        attn, small_tokenizer, json_grammar):
    eng = _engine(attn, small_tokenizer, json_grammar, max_tokens=30)
    sched = ContinuousBatchingScheduler(eng, capacity=2, paged=True,
                                       page_size=16, n_pages=12)
    for p in PROMPTS[:2]:
        sched.submit(p)
    sched.step()
    sched.step()
    assert check_invariants(sched) == []
    # manufactured page leak: a free page vanishes from the free list
    leaked = sched.pool._free.pop()
    problems = check_invariants(sched)
    assert any("leak" in p for p in problems)
    sched.pool._free.append(leaked)
    assert check_invariants(sched) == []
    # manufactured slot corruption: resident session claims wrong slot
    resident = next(s for s in sched.slots if s is not None)
    old = resident.slot
    resident.slot = old + 7
    assert any("slot" in p for p in check_invariants(sched))
    resident.slot = old
    # debug_invariants wiring: a corrupted scheduler raises at the tick
    sched.debug_invariants = True
    sched.pool._free.pop()
    with pytest.raises(InvariantViolation):
        sched.step()


# -- deterministic injector ----------------------------------------------------


def test_injector_is_deterministic_and_validates_sites():
    with pytest.raises(ValueError):
        FaultInjector(rates={"nope": 1.0})
    a = FaultInjector(seed=7, rates={"decode_nan": 0.5})
    b = FaultInjector(seed=7, rates={"decode_nan": 0.5})
    fires_a = [a.fire("decode_nan", rid=i % 3) for i in range(50)]
    fires_b = [b.fire("decode_nan", rid=i % 3) for i in range(50)]
    assert fires_a == fires_b
    assert a.log == b.log
    assert all(isinstance(r, FaultRecord) for r in a.log)
    # max_faults bounds the storm
    c = FaultInjector(seed=7, rates={"decode_nan": 1.0}, max_faults=3)
    assert sum(c.fire("decode_nan", rid=0) for _ in range(10)) == 3


# -- chaos storm ---------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_storm_no_leaks_affected_fail_unaffected_bitwise(
        attn, small_tokenizer, json_grammar, seed):
    """The acceptance storm: faults across >= 3 distinct tick phases,
    invariants audited at every tick boundary, zero page/slot leaks,
    every request reaches SOME terminal status, affected rows are non-ok
    (or provably untouched: bitwise-equal, see module docstring), and
    unaffected rows are bitwise-identical to the fault-free run."""
    eng = _engine(attn, small_tokenizer, json_grammar)
    base = ContinuousBatchingScheduler(eng, capacity=2, paged=True,
                                      page_size=16, n_pages=12)
    base_sess = [base.submit(p) for p in PROMPTS]
    base.run()
    baseline = _by_rid(base_sess)

    inj = FaultInjector(seed=seed, rates={
        "mask_error": 0.08, "decode_nan": 0.08, "advance_error": 0.08,
        "prefill_nan": 0.05, "page_exhaustion": 0.25,
    }, max_faults=25)
    sched = ContinuousBatchingScheduler(eng, capacity=2, paged=True,
                                       page_size=16, n_pages=12,
                                       fault_injector=inj,
                                       debug_invariants=True)
    sess = [sched.submit(p) for p in PROMPTS]
    results = sched.run()               # invariants checked EVERY tick

    # every submission reaches a terminal status
    assert len(results) == len(PROMPTS)
    assert all(s.result is not None for s in sess)
    # the storm covered >= 3 distinct tick phases
    assert len({r.site for r in inj.log}) >= 3, inj.log
    # zero leaks: pool fully drained, all slots free, queue empty
    assert sched.pool.available == sched.n_pages - 1
    assert not sched._page_tbl.any()
    assert all(s is None for s in sched.slots)
    assert not sched.waiting
    # quarantine: unaffected rows bitwise-identical; affected rows carry
    # an explicit non-ok status unless preemption erased the fault before
    # it was observed (then they are bitwise-identical instead)
    affected = inj.faulted_rids("mask_error", "decode_nan",
                                "advance_error", "prefill_nan")
    for s in sess:
        r, b = s.result, baseline[s.rid]
        if s.rid in affected:
            assert (r.status != "ok" and r.error) \
                or r.token_ids == b.token_ids, (s.rid, r.status)
            if r.status != "ok":       # partial output is a valid prefix
                assert r.token_ids == b.token_ids[:r.n_tokens]
        else:
            assert r.status == b.status
            assert r.token_ids == b.token_ids
    # bookkeeping agrees with results
    assert sum(sched.status_counts.values()) == len(PROMPTS)
    assert sched.status_counts["ok"] == \
        len([s for s in sess if s.result.ok])


# -- degradation supervisor (ISSUE 9) ------------------------------------------


def test_durability_fault_sites_registered():
    for site in ("device_timeout", "device_error", "alloc_fail",
                 "table_corrupt", "journal_torn_write", "crash_point"):
        assert site in SITES
        FaultInjector(rates={site: 1.0})   # constructor validates names


def test_supervisor_guard_retries_with_exponential_backoff():
    sleeps = []
    sup = DegradationSupervisor(max_retries=2, backoff_s=0.01,
                                clock=lambda: 0.0, sleep=sleeps.append)
    failures = [RuntimeError("one"), RuntimeError("two")]
    calls = []

    def flaky():
        calls.append(1)
        if failures:
            raise failures.pop(0)
        return 42

    ok, value = sup.guard("op", flaky)
    assert ok and value == 42
    assert len(calls) == 3 and sup.n_retries == 2
    assert sleeps == [0.01, 0.02]          # 2^(attempt-1) backoff

    def hopeless():
        raise RuntimeError("always")

    sup2 = DegradationSupervisor(max_retries=1, backoff_s=0.0,
                                 sleep=lambda s: None)
    ok, err = sup2.guard("op", hopeless)
    assert not ok and isinstance(err, RuntimeError)
    assert sup2.n_retries == 1


def test_supervisor_guard_consults_injection_before_each_attempt():
    fires = [True, True, False]
    sup = DegradationSupervisor(max_retries=2, backoff_s=0.0,
                                sleep=lambda s: None)
    ok, value = sup.guard("op", lambda: 7, inject=lambda: fires.pop(0))
    assert ok and value == 7
    assert sup.n_retries == 2 and not fires


def test_supervisor_watchdog_trip_keeps_the_value():
    t = [0.0]

    def clock():
        t[0] += 1.0                        # every clock() call = +1s
        return t[0]

    sup = DegradationSupervisor(watchdog_s=0.5, clock=clock,
                                sleep=lambda s: None)
    ok, value = sup.guard("slow-op", lambda: "result")
    assert ok and value == "result"        # finished, just slowly
    assert sup.n_watchdog_trips == 1


def test_supervisor_ladder_degrade_recover_and_mttr():
    t = [0.0]
    sup = DegradationSupervisor(recover_after=2, clock=lambda: t[0],
                                sleep=lambda s: None)
    assert sup.level == 0 and sup.level_name == "fused"
    t[0] = 1.0
    assert sup.degrade("device_timeout") == 1
    sup.tick_ok()                          # dirty tick: does NOT count
    assert sup.level == 1
    assert sup.degrade("fused_block") == 2
    assert sup.degrade("again") == 2       # capped at dense
    assert sup.n_degrades == 2 and sup.level_name == "dense"
    sup.tick_ok()                          # dirty reset
    for _ in range(2):
        sup.tick_ok()
    assert sup.level == 1                  # 2 clean ticks -> one climb
    t[0] = 9.0                             # clock at the final climb
    for _ in range(2):
        sup.tick_ok()
    assert sup.level == 0 and sup.n_recovers == 2
    assert sup.mttr_s == pytest.approx(8.0)   # first degrade -> level 0
    s = sup.stats()
    assert s["level"] == 0 and s["n_degrades"] == 2
    assert s["mttr_s"] == pytest.approx(8.0)


def test_alloc_fail_shrinks_capacity_outputs_invariant(
        attn, small_tokenizer, json_grammar):
    """Injected allocation failure is PRESSURE, not a row fault: the
    supervisor shrinks effective capacity and preempts-to-queue, clean
    ticks grow it back, and every output stays bitwise-identical."""
    eng = _engine(attn, small_tokenizer, json_grammar)
    base = ContinuousBatchingScheduler(eng, capacity=3, paged=True,
                                      page_size=16, n_pages=12)
    base_sess = [base.submit(p) for p in PROMPTS]
    base.run()
    # page_size=4 forces page-boundary crossings every few tokens, so the
    # alloc_fail site (consulted only under a real shortfall) is hit
    inj = FaultInjector(seed=5, rates={"alloc_fail": 1.0}, max_faults=2)
    sched = ContinuousBatchingScheduler(eng, capacity=3, paged=True,
                                       page_size=4, n_pages=40,
                                       fault_injector=inj,
                                       debug_invariants=True)
    sess = [sched.submit(p) for p in PROMPTS]
    sched.run()
    assert inj.n_fired("alloc_fail") > 0
    assert sched.n_capacity_shrinks > 0
    assert sched.stats()["n_capacity_shrinks"] == sched.n_capacity_shrinks
    for b, f in zip(base_sess, sess):
        assert f.result.status == "ok"
        assert f.result.token_ids == b.result.token_ids
    assert sched.pool.available == sched.n_pages - 1
    # clean ticks after the storm regrew the admission cap
    assert 1 <= sched._cap_eff <= sched.capacity


def test_device_error_storm_resets_engine_outputs_exact(
        attn, small_tokenizer, json_grammar):
    """A device_error storm on the host tick path: the guarded readback
    retries, then resets the engine surface (recompute-preempt all) and
    steps down the ladder.  Preemption invariance keeps every completed
    request bitwise-identical to the fault-free run."""
    eng = _engine(attn, small_tokenizer, json_grammar)
    base = ContinuousBatchingScheduler(eng, capacity=2)
    base_sess = [base.submit(p) for p in PROMPTS[:4]]
    base.run()
    inj = FaultInjector(seed=2, rates={"device_error": 1.0}, max_faults=8)
    sched = ContinuousBatchingScheduler(eng, capacity=2,
                                       fault_injector=inj,
                                       debug_invariants=True)
    sess = [sched.submit(p) for p in PROMPTS[:4]]
    sched.run()
    assert inj.n_fired("device_error") == 8
    assert sched.n_engine_resets >= 1
    assert sched.sup.n_degrades >= 1
    for b, f in zip(base_sess, sess):
        assert f.result.status == "ok"
        assert f.result.token_ids == b.result.token_ids
    if sched.paged:
        assert sched.pool.available == sched.n_pages - 1
    assert all(s is None for s in sched.slots)
    stats = sched.stats()
    assert stats["n_engine_resets"] == sched.n_engine_resets
    assert stats["level_name"] in ("fused", "host", "dense")


# -- lint: no swallowed exceptions in serving/ ---------------------------------


def test_lint_forbids_swallowed_excepts_in_serving(tmp_path):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "lint_hotpath", os.path.join(root, "tools", "lint_hotpath.py"))
    lint = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(lint)
    bad = tmp_path / "bad.py"
    bad.write_text(
        "def f():\n"
        "    try:\n"
        "        g()\n"
        "    except:\n"                       # R4: bare
        "        h()\n"
        "    try:\n"
        "        g()\n"
        "    except Exception:\n"             # R4: swallowed
        "        pass\n"
        "    try:\n"
        "        g()\n"
        "    except Exception as e:\n"        # fine: mapped to a status
        "        fail(e)\n")
    findings = lint.lint_serving_excepts(str(bad))
    assert len(findings) == 2
    assert all(f.rule == "R4" for f in findings)
    # the serving package itself is clean
    import repro.serving as srv
    pkg = os.path.dirname(os.path.abspath(srv.__file__))
    for fn in sorted(os.listdir(pkg)):
        if fn.endswith(".py"):
            assert lint.lint_serving_excepts(os.path.join(pkg, fn)) == []
