"""Pallas kernels wired into the model path (cfg.use_pallas_kernels):
outputs must match the pure-jnp path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, ModelConfig, SSMConfig
from repro.models import build_model

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=128, dtype="float32", max_seq_len=64)


def _compare(cfg, steps=3, atol=2e-3, ragged=False, width=1):
    cfg_k = dataclasses.replace(cfg, use_pallas_kernels=True)
    m, mk = build_model(cfg), build_model(cfg_k)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 18), 0, 128,
                              jnp.int32)
    # train/prefill path
    lg1, _ = m.train_logits(params, {"tokens": toks})
    lg2, _ = mk.train_logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=atol,
                               rtol=1e-3)
    # decode path
    c1, c2 = m.init_cache(2, 32), mk.init_cache(2, 32)
    _, c1 = m.prefill(params, {"tokens": toks[:, :6]}, c1)
    _, c2 = mk.prefill(params, {"tokens": toks[:, :6]}, c2)
    if ragged:
        # per-row (B,) cache lengths, as the continuous-batching
        # scheduler produces (row 1's tail entries are masked/rewritten)
        c1["len"] = jnp.asarray([6, 4], jnp.int32)
        c2["len"] = jnp.asarray([6, 4], jnp.int32)
    i = 6
    for _ in range(steps):
        d1, c1 = m.decode_step(params, c1, toks[:, i:i + width])
        d2, c2 = mk.decode_step(params, c2, toks[:, i:i + width])
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   atol=atol, rtol=1e-3)
        i += width


def test_dense_decode_kernel():
    _compare(ModelConfig(arch_id="pk-dense", family="dense", **BASE))


def test_dense_decode_kernel_ragged():
    """Per-row cache lengths route through the ragged fused kernel (the
    dense path is the oracle)."""
    _compare(ModelConfig(arch_id="pk-dense-r", family="dense", **BASE),
             ragged=True)


def test_dense_decode_kernel_verify_window():
    """(B, 1+s) speculative verify decode through the fused kernel, on
    both uniform and ragged caches."""
    _compare(ModelConfig(arch_id="pk-dense-w", family="dense", **BASE),
             width=3)
    _compare(ModelConfig(arch_id="pk-dense-wr", family="dense", **BASE),
             ragged=True, width=3)


MLA = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                qk_rope_head_dim=8, v_head_dim=16)


def test_mla_decode_kernel():
    """Absorbed-MLA latent reads through the fused kernel (Dk = r + dr
    keys vs Dv = r values), uniform + ragged + verify window."""
    cfg = ModelConfig(arch_id="pk-mla", family="dense", group=("mla",),
                      mla=MLA, **BASE)
    _compare(cfg)
    _compare(dataclasses.replace(cfg, arch_id="pk-mla-r"), ragged=True)
    _compare(dataclasses.replace(cfg, arch_id="pk-mla-w"), ragged=True,
             width=3)


def test_mamba1_kernel():
    _compare(ModelConfig(arch_id="pk-m1", family="ssm", group=("mamba1",),
                         ssm=SSMConfig(d_state=8, version=1), **BASE))


def test_mamba2_ssd_kernel():
    _compare(ModelConfig(arch_id="pk-m2", family="hybrid",
                         group=("mamba2",),
                         ssm=SSMConfig(d_state=8, version=2, head_dim=16),
                         **BASE))
