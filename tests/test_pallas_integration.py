"""Pallas kernels wired into the model path (cfg.use_pallas_kernels):
outputs must match the pure-jnp path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import build_model

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=128, dtype="float32", max_seq_len=64)


def _compare(cfg, steps=3, atol=2e-3):
    cfg_k = dataclasses.replace(cfg, use_pallas_kernels=True)
    m, mk = build_model(cfg), build_model(cfg_k)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 128,
                              jnp.int32)
    # train/prefill path
    lg1, _ = m.train_logits(params, {"tokens": toks})
    lg2, _ = mk.train_logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=atol,
                               rtol=1e-3)
    # decode path
    c1, c2 = m.init_cache(2, 20), mk.init_cache(2, 20)
    _, c1 = m.prefill(params, {"tokens": toks[:, :6]}, c1)
    _, c2 = mk.prefill(params, {"tokens": toks[:, :6]}, c2)
    for i in range(6, 6 + steps):
        d1, c1 = m.decode_step(params, c1, toks[:, i:i + 1])
        d2, c2 = mk.decode_step(params, c2, toks[:, i:i + 1])
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   atol=atol, rtol=1e-3)


def test_dense_decode_kernel():
    _compare(ModelConfig(arch_id="pk-dense", family="dense", **BASE))


def test_mamba1_kernel():
    _compare(ModelConfig(arch_id="pk-m1", family="ssm", group=("mamba1",),
                         ssm=SSMConfig(d_state=8, version=1), **BASE))


def test_mamba2_ssd_kernel():
    _compare(ModelConfig(arch_id="pk-m2", family="hybrid",
                         group=("mamba2",),
                         ssm=SSMConfig(d_state=8, version=2, head_dim=16),
                         **BASE))
