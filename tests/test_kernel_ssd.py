"""Mamba2 SSD Pallas kernel vs oracle: shape sweeps + chunk invariance +
consistency with the model's own mamba2 chunked math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

RNG = np.random.default_rng(7)


def _inputs(b, s, h, d, n):
    return (jnp.asarray(RNG.normal(size=(b, s, h, d)).astype(np.float32)),
            jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32)),
            jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32)),
            jnp.asarray(-np.abs(RNG.normal(size=(b, s, h))).astype(
                np.float32) * 0.3),
            jnp.asarray(np.abs(RNG.normal(size=(b, s, h))).astype(
                np.float32) * 0.2),
            jnp.asarray(RNG.normal(size=(b, h, d, n)).astype(np.float32)))


@pytest.mark.parametrize("b,s,h,d,n,bh,ck", [
    (2, 128, 8, 16, 8, 4, 32), (1, 64, 4, 32, 16, 4, 64),
    (2, 96, 6, 8, 4, 3, 32), (1, 256, 2, 64, 64, 2, 64)])
def test_vs_ref(b, s, h, d, n, bh, ck):
    x, bm, cm, ld, dt, h0 = _inputs(b, s, h, d, n)
    y1, t1 = ssd_scan(x, bm, cm, ld, dt, h0, block_h=bh, chunk=ck)
    y2, t2 = ssd_scan_ref(x, bm, cm, ld, dt, h0, chunk=ck)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), atol=2e-4,
                               rtol=1e-3)


def test_chunk_invariance():
    x, bm, cm, ld, dt, h0 = _inputs(1, 128, 4, 16, 8)
    y32, t32 = ssd_scan_ref(x, bm, cm, ld, dt, h0, chunk=32)
    y64, t64 = ssd_scan_ref(x, bm, cm, ld, dt, h0, chunk=64)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y64), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(t32), np.asarray(t64), atol=2e-4,
                               rtol=1e-3)


def test_state_continuity():
    """Two half-sequence scans with carried state == one full scan."""
    x, bm, cm, ld, dt, h0 = _inputs(1, 128, 4, 16, 8)
    y_full, t_full = ssd_scan_ref(x, bm, cm, ld, dt, h0, chunk=32)
    y1, t1 = ssd_scan(x[:, :64], bm[:, :64], cm[:, :64], ld[:, :64],
                      dt[:, :64], h0, chunk=32)
    y2, t2 = ssd_scan(x[:, 64:], bm[:, 64:], cm[:, 64:], ld[:, 64:],
                      dt[:, 64:], t1, chunk=32)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1),
                               np.asarray(y_full), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(t2), np.asarray(t_full),
                               atol=2e-4, rtol=1e-3)
