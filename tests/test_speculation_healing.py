"""Speculation (count model, proposals) + token healing + retokenization."""
import numpy as np

from repro.core import grammars
from repro.core.domino import DominoDecoder
from repro.core.healing import HealedDecoder, heal_prompt
from repro.core.retokenize import greedy_tokenize, retokenize
from repro.core.speculation import (CountModel, Speculator, verify_greedy,
                                    verify_stochastic)


def test_count_model():
    cm = CountModel()
    assert cm.predict(("a", 1)) is None
    for _ in range(3):
        cm.observe(("a", 1), 7)
    cm.observe(("a", 1), 8)
    tok, p = cm.predict(("a", 1))
    assert tok == 7 and abs(p - 0.75) < 1e-9


def test_proposals_are_grammar_legal(small_tokenizer):
    tok = small_tokenizer
    g = grammars.load("json_gsm8k")
    d = DominoDecoder(g, tok.vocab, eos_id=tok.eos_id)
    spec = Speculator(s=6, threshold=0.4)
    # teach the model a canonical schema prefix
    text = b'{"thoughts": [{"step": "a", "calculation": "b", "result": 1}], "answer": 1}'
    ids = greedy_tokenize(text, tok.vocab)
    dd = d.clone()
    for t in ids:
        spec.observe(dd.state_key(), t)
        assert dd.advance(t)
    # propose from the start: the chain must be legal
    props = spec.propose(d)
    assert len(props) > 0
    chk = d.clone()
    for t in props:
        assert chk.advance(t), tok.vocab[t]


def test_verify_rules():
    assert verify_greedy([1, 2, 3], [1, 2, 4]) == 2
    assert verify_greedy([1], [1]) == 1
    assert verify_greedy([5], [1]) == 0
    # stochastic: always accept when p_model >= q
    n = verify_stochastic([1, 2], [0.5, 0.5], [0.9, 0.9], [0.5, 0.5])
    assert n == 2
    n = verify_stochastic([1, 2], [0.9, 0.9], [0.1, 0.9], [0.5, 0.1])
    assert n == 0


def test_heal_prompt(small_tokenizer):
    tok = small_tokenizer
    ids = tok.encode('Answer: {"a"')
    kept, stripped = heal_prompt(ids, tok.vocab, n_strip=2)
    assert tok.decode(kept) + stripped == 'Answer: {"a"'


def test_healed_decoder_forces_prefix(small_tokenizer):
    tok = small_tokenizer
    g = grammars.load("json")
    d = HealedDecoder(g, tok.vocab, eos_id=tok.eos_id, prefix_text='{"a')
    # continuations of '{"a' accepted: full output '{"ab": 1}' is in L(G)
    good = greedy_tokenize(b'{"ab": 1}', tok.vocab)
    for t in good:
        assert d.mask()[t], tok.vocab[t]
        assert d.advance(t), tok.vocab[t]
    assert d.eos_legal()
    # deviating from the prefix is rejected
    d2 = HealedDecoder(g, tok.vocab, eos_id=tok.eos_id, prefix_text='{"a')
    bad = greedy_tokenize(b'{"x', tok.vocab)
    ok = True
    for t in bad:
        if not d2.advance(t):
            ok = False
            break
    assert not ok, "prefix not enforced"
    # bridge over the boundary: a token spanning prefix-end + new text
    d3 = HealedDecoder(g, tok.vocab, eos_id=tok.eos_id, prefix_text='{')
    bridge = greedy_tokenize(b'{"k": 2}', tok.vocab)
    for t in bridge:
        assert d3.advance(t), tok.vocab[t]
    assert d3.eos_legal()


def test_retokenize_matches_model_preference(small_tokenizer):
    tok = small_tokenizer
    target = b'{"name": 1}'
    # a fake model that strongly prefers the longest available token
    def model_logits(ids):
        lg = np.zeros(tok.vocab_size, np.float32)
        for i, v in enumerate(tok.vocab):
            if v:
                lg[i] = len(v)
        return lg
    ids = retokenize(model_logits, [], target, tok.vocab)
    assert tok.decode_bytes(ids) == target
    greedy = greedy_tokenize(target, tok.vocab)
    assert ids == greedy  # longest-match preference == greedy tokenization
