"""Per-architecture smoke tests (deliverable f): every assigned arch's
REDUCED config runs one forward + one train step + one decode step on CPU,
asserting shapes and no NaNs.  The FULL configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALIASES, get_config
from repro.models import build_model
from repro.training import optimizer as opt
from repro.training.train_loop import make_train_step

ARCHS = list(ALIASES.keys())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    m = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)
    B, S = 2, 16
    batch = m.example_batch(B, S, rng)
    # forward
    train_in = {k: (v[:, :-1] if k == "tokens" else v)
                for k, v in batch.items()}
    logits, aux = m.train_logits(params, train_in)
    exp_s = S if cfg.family != "vlm" else (
        train_in["tokens"].shape[1] + cfg.n_prefix_tokens)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, dtype=np.float32)))
    # one train step (params/state are donated -> snapshot first)
    p_before = jax.tree.map(lambda x: np.asarray(x).copy(), params)
    step = make_train_step(m, opt.AdamWConfig(lr=1e-3, total_steps=10))
    state = opt.init_state(params)
    params2, state, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(a.astype(np.float32)
                                  - np.asarray(b, np.float32)).max()),
        p_before, params2))
    assert max(delta) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)
    B = 2
    batch = m.example_batch(B, 8, rng)
    cache = m.init_cache(B, 24)
    pre = {k: (v[:, :6] if k == "tokens" else v) for k, v in batch.items()}
    lg, cache = m.prefill(params, pre, cache)
    assert lg.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
    n_pre = pre["tokens"].shape[1] + (cfg.n_prefix_tokens
                                      if cfg.family == "vlm" else 0)
    lg2, cache = m.decode_step(params, cache, tok)
    assert lg2.shape == (B, 1, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(lg2, dtype=np.float32)))
    assert int(cache["len"]) == n_pre + 1


def test_full_configs_validate():
    for arch in ARCHS:
        cfg = get_config(arch)
        cfg.check()
        assert cfg.param_count() > 0
