"""Packed-bitset mask pipeline (ISSUE 4 tentpole).

Covers every layer of the packed flow:
 - pack/unpack round-trips against bool masks (hypothesis property);
 - tree-node bitset segments vs the token-id lists they replace;
 - state-keyed memo hits returning masks identical to fresh tree walks
   (and to the pre-bitset scatter walk, kept as ``mask_dense``);
 - packed-kernel output bitwise-identical to the int8-mask kernel across
   mixed batches (empty / single-bit / dense rows, odd V tail tiles);
 - the scheduler's persistent packed staging buffer: no per-tick dense
   allocation, ``mask_cache_hits`` reported next to ``premask_hits``,
   and batched outputs unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # only the property tests need it —
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                    # everything else must still run
    HAVE_HYPOTHESIS = False

from repro.configs.base import ModelConfig
from repro.core import bitmask, grammars
from repro.core.domino import DominoDecoder
from repro.core.sampling import GrammarSampler
from repro.core.trees import TreeCache
from repro.kernels.masked_sample.kernel import (masked_argmax_pallas,
                                                masked_argmax_pallas_packed)
from repro.kernels.masked_sample.ops import masked_argmax
from repro.kernels.masked_sample.ref import masked_argmax_ref, unpack_bits
from repro.models import build_model
from repro.serving import (ContinuousBatchingScheduler, EngineConfig,
                           ServingEngine)

RNG = np.random.default_rng(7)


# -- bitmask layout -----------------------------------------------------------


def test_pack_bool_roundtrip_basic():
    for v in (1, 31, 32, 33, 420, 512, 1000):
        m = RNG.random(v) < 0.3
        bits = bitmask.pack_bool(m)
        assert bits.shape == (bitmask.n_words(v),)
        assert bits.dtype == np.uint32
        np.testing.assert_array_equal(bitmask.unpack(bits, v), m)


def test_pack_ids_matches_pack_bool():
    v = 420
    ids = RNG.choice(v, size=50, replace=False)
    m = np.zeros(v, bool)
    m[ids] = True
    np.testing.assert_array_equal(bitmask.pack_ids(ids, v),
                                  bitmask.pack_bool(m))
    # duplicate ids in one word must still accumulate, not overwrite
    np.testing.assert_array_equal(
        bitmask.pack_ids([3, 3, 4, 35], v),
        bitmask.pack_bool(np.isin(np.arange(v), [3, 4, 35])))


def test_tail_bits_are_zero():
    v = 33                              # one full word + one bit
    bits = bitmask.pack_bool(np.ones(v, bool))
    assert bits[1] == 1                 # only bit 0 of the tail word


def _prop(f):
    if not HAVE_HYPOTHESIS:
        return pytest.mark.skip(reason="hypothesis not installed")(f)
    return settings(max_examples=40, deadline=None)(
        given(st.integers(1, 260), st.integers(0, 2**32 - 1))(f))


@_prop
def test_pack_unpack_roundtrip_property(v=7, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.random(v) < rng.random()
    bits = bitmask.pack_bool(m)
    np.testing.assert_array_equal(bitmask.unpack(bits, v), m)
    # pack(unpack(bits)) is the identity on canonical (tail-zeroed) rows
    np.testing.assert_array_equal(bitmask.pack_bool(bitmask.unpack(bits, v)),
                                  bits)
    # the jnp unpack used by the oracle agrees with the numpy one
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(jnp.asarray(bits), v)), m)


# -- tree-node segments -------------------------------------------------------


@pytest.fixture(scope="module")
def json_tree_cache(small_tokenizer):
    from repro.core.scanner import Scanner
    tok = small_tokenizer
    g = grammars.load("json")
    cache = TreeCache(Scanner(g), list(tok.vocab))
    cache.precompute()
    return tok, g, cache


def test_tree_node_bits_match_token_lists(json_tree_cache):
    """Every node's packed segments must be exactly the pack of the
    token-id lists they were built from."""
    tok, _g, cache = json_tree_cache
    v = len(tok.vocab)
    n_nodes = n_with_fresh = 0
    for tree in cache.trees.values():
        stack = [tree.root]
        while stack:
            node = stack.pop()
            n_nodes += 1
            if node.tokens_fresh:
                assert node.fresh_bits is not None
                n_with_fresh += 1
                np.testing.assert_array_equal(
                    node.fresh_bits, bitmask.pack_ids(node.tokens_fresh, v))
            else:
                assert node.fresh_bits is None
            assert set(node.partial_bits) == set(node.tokens_partial)
            for tids, toks in node.tokens_partial.items():
                np.testing.assert_array_equal(
                    node.partial_bits[tids], bitmask.pack_ids(toks, v))
            stack.extend(node.children.values())
    assert n_nodes > 0 and n_with_fresh > 0


# -- memoized mask assembly ---------------------------------------------------


def _advance_along(dec, tok, text):
    for t in tok.encode(text):
        assert dec.advance(t)


def test_mask_bits_equals_dense_walk(json_tree_cache):
    """Bitset-OR assembly == the scatter walk it replaced, at every step
    of a sampled generation and at several lookaheads."""
    tok, g, cache = json_tree_cache
    sampler = GrammarSampler(g, seed=5)
    for text in [sampler.sample() for _ in range(5)]:
        if isinstance(text, bytes):
            text = text.decode()
        dec = DominoDecoder(g, list(tok.vocab), tok.eos_id, tree_cache=cache)
        for t in tok.encode(text):
            for k in (None, 0, 1):
                np.testing.assert_array_equal(
                    bitmask.unpack(dec.mask_bits(k), len(tok.vocab)),
                    dec.mask_dense(k))
            assert dec.advance(t), (text, tok.vocab[t])


def test_mask_memo_hit_returns_identical_mask(json_tree_cache):
    """A second decoder reaching the same immutable state gets the SAME
    packed row from the shared memo — and it equals a fresh walk."""
    tok, g, cache = json_tree_cache
    d1 = DominoDecoder(g, list(tok.vocab), tok.eos_id, tree_cache=cache)
    d2 = DominoDecoder(g, list(tok.vocab), tok.eos_id, tree_cache=cache)
    _advance_along(d1, tok, '{"a"')
    m1 = d1.mask_bits()
    hits_before = d2.n_mask_memo_hits
    _advance_along(d2, tok, '{"a"')
    m2 = d2.mask_bits()
    assert d2.n_mask_memo_hits == hits_before + 1
    assert m2 is m1                     # literally the shared memo row
    np.testing.assert_array_equal(bitmask.unpack(m2, len(tok.vocab)),
                                  d2.mask_dense())
    # memo rows are read-only: the serving path must never corrupt them
    with pytest.raises(ValueError):
        m2[0] = 0


def test_mask_memo_fifo_cap(json_tree_cache):
    """The shared memo evicts FIFO past mask_memo_max instead of growing
    without bound on a long-lived server; eviction only costs a rebuild."""
    tok, g, cache = json_tree_cache
    d = DominoDecoder(g, list(tok.vocab), tok.eos_id, tree_cache=cache)
    old_max = cache.mask_memo_max
    try:
        cache.mask_memo.clear()
        cache.mask_memo_max = 2
        m_fresh = d.mask_bits()
        d.mask_bits(0)
        d.mask_bits(1)                  # third entry -> evicts the first
        assert len(cache.mask_memo) == 2
        hits = d.n_mask_memo_hits
        m_rebuilt = d.mask_bits()       # miss again, rebuilt identically
        assert d.n_mask_memo_hits == hits
        np.testing.assert_array_equal(m_rebuilt, m_fresh)
    finally:
        cache.mask_memo_max = old_max
        cache.mask_memo.clear()


def test_mask_memo_distinguishes_lookahead(json_tree_cache):
    tok, g, cache = json_tree_cache
    d = DominoDecoder(g, list(tok.vocab), tok.eos_id, tree_cache=cache)
    m_inf = d.mask_bits()
    m_0 = d.mask_bits(0)
    n0 = int(bitmask.unpack(m_0, len(tok.vocab)).sum())
    ninf = int(bitmask.unpack(m_inf, len(tok.vocab)).sum())
    assert n0 <= ninf                   # k=0 is a subset of k=inf


def test_mask_memo_distinguishes_charts(json_tree_cache):
    """Two states with identical CURRENT parser item sets but different
    histories must not collide: the memo key uses the whole-history
    chart fingerprint, not state_key()."""
    tok, g, cache = json_tree_cache
    d1 = DominoDecoder(g, list(tok.vocab), tok.eos_id, tree_cache=cache)
    d2 = DominoDecoder(g, list(tok.vocab), tok.eos_id, tree_cache=cache)
    _advance_along(d1, tok, '[1')
    _advance_along(d2, tok, '[[1')      # one level deeper
    import math
    assert d1._memo_key(math.inf) != d2._memo_key(math.inf)
    m1 = bitmask.unpack(d1.mask_bits(), len(tok.vocab))
    m2 = bitmask.unpack(d2.mask_bits(), len(tok.vocab))
    np.testing.assert_array_equal(m1, d1.mask_dense())
    np.testing.assert_array_equal(m2, d2.mask_dense())


# -- fused kernel parity ------------------------------------------------------


def _mixed_batch(b, v, rng):
    """Rows exercising every regime: empty, single-bit, sparse, dense."""
    mask = np.zeros((b, v), bool)
    for i in range(b):
        kind = i % 4
        if kind == 1:
            mask[i, rng.integers(v)] = True
        elif kind == 2:
            mask[i] = rng.random(v) < 0.02
        elif kind == 3:
            mask[i] = rng.random(v) < 0.7
    return mask


@pytest.mark.parametrize("b,v,bv", [(4, 512, 128), (5, 1000, 256),
                                    (4, 4100, 2048), (3, 333, 128),
                                    (8, 8192, 2048)])
def test_packed_kernel_bitwise_identical_to_int8(b, v, bv):
    logits = jnp.asarray(RNG.normal(size=(b, v)).astype(np.float32))
    mask = _mixed_batch(b, v, RNG)
    i8 = jnp.asarray(mask.astype(np.int8))
    bits = jnp.asarray(bitmask.pack_bool(mask))
    i1, v1 = masked_argmax_pallas(logits, i8, block_v=bv)
    i2, v2 = masked_argmax_pallas_packed(logits, bits, block_v=bv)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    # and both equal the unfused oracle (packed + dense operands)
    i3, v3 = masked_argmax_ref(logits, i8)
    i4, _ = masked_argmax_ref(logits, bits)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i3))
    np.testing.assert_array_equal(np.asarray(i3), np.asarray(i4))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v3), rtol=1e-6)


def test_ops_dispatch_on_dtype():
    """masked_argmax routes uint32 operands to the packed kernel and
    produces identical selections either way."""
    b, v = 3, 420
    logits = jnp.asarray(RNG.normal(size=(b, v)).astype(np.float32))
    mask = _mixed_batch(b, v, RNG)
    mask[0, 17] = True                  # no fully-empty ambiguity
    i1, _ = masked_argmax(logits, jnp.asarray(mask.astype(np.int8)))
    i2, _ = masked_argmax(logits, jnp.asarray(bitmask.pack_bool(mask)))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_tie_breaking_matches_reference():
    """Equal logits under the mask: both kernels and the oracle must all
    pick the lowest legal index, including across tile boundaries."""
    b, v, bv = 1, 256, 64
    logits = jnp.zeros((b, v), jnp.float32)
    mask = np.zeros((b, v), bool)
    mask[0, [70, 130, 200]] = True      # three tiles, all tied
    for m in (jnp.asarray(mask.astype(np.int8)),
              jnp.asarray(bitmask.pack_bool(mask))):
        i_k, _ = masked_argmax(logits, m, block_v=bv)
        i_r, _ = masked_argmax_ref(logits, m)
        assert int(np.asarray(i_k)[0]) == 70
        assert int(np.asarray(i_r)[0]) == 70


# -- scheduler integration ----------------------------------------------------


BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            dtype="float32", max_seq_len=512)


def test_scheduler_packed_staging_and_memo_hits(small_tokenizer,
                                                json_grammar):
    """The scheduler stages packed rows in ONE persistent uint32 buffer
    (8x fewer mask bytes than the dense int8 layout), reports
    mask_cache_hits next to premask_hits, and outputs still match the
    single-request path token-for-token."""
    tok = small_tokenizer
    cfg = ModelConfig(arch_id="s-attn-mb", family="dense",
                      vocab_size=tok.vocab_size, **BASE)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=8),
                        max_len=256)
    prompts = ["a: ", "b: ", "c: "]
    singles = [eng.generate(p) for p in prompts]
    sched = ContinuousBatchingScheduler(eng, capacity=2)
    assert sched._mask_words.dtype == np.uint32
    assert sched._mask_words.shape == \
        (2, bitmask.n_words(tok.vocab_size))
    assert sched._mask_words.nbytes * 8 >= tok.vocab_size  # covers V
    buf_id = id(sched._mask_words)
    for p in prompts:
        sched.submit(p)
    results = sched.run()
    assert id(sched._mask_words) == buf_id      # never reallocated
    for r, s in zip(results, singles):
        assert r.token_ids == s.token_ids
    # three identical-grammar sessions revisit states: the shared memo
    # must have served some builds, and the per-request results carry it
    assert sched.mask_cache_hits > 0
    assert sum(r.mask_cache_hits for r in results) >= sched.mask_cache_hits
    assert sched._mask_words.nbytes <= -(-tok.vocab_size // 32) * 4 * 2


def test_vacant_slots_keep_sentinel_rows(small_tokenizer, json_grammar):
    tok = small_tokenizer
    cfg = ModelConfig(arch_id="s-attn-mb2", family="dense",
                      vocab_size=tok.vocab_size, **BASE)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=4),
                        max_len=256)
    sched = ContinuousBatchingScheduler(eng, capacity=3)
    sched.submit("a: ")                 # only slot 0 ever occupied
    sched.run()
    for row in sched._mask_words[1:]:
        np.testing.assert_array_equal(row, sched._sentinel_row)
