"""Batched constrained serving through the continuous-batching scheduler:
ragged per-request cache lengths must reproduce single-request outputs
exactly."""
import jax
import pytest

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig
from repro.core import grammars
from repro.core.domino import DominoDecoder
from repro.models import build_model
from repro.serving import EngineConfig, ServingEngine

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            dtype="float32", max_seq_len=512)


@pytest.fixture(scope="module")
def setup(request):
    tok = request.getfixturevalue("small_tokenizer")
    cfg = ModelConfig(arch_id="b", family="dense",
                      vocab_size=tok.vocab_size, **BASE)
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0)), tok


def test_batch_equals_single(setup, json_grammar):
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=14),
                        max_len=256)
    prompts = ["a: ", "some json here: ", "x", "data -> "]
    singles = [eng.generate(p) for p in prompts]
    batch = eng.generate_batch(prompts)
    for s, b in zip(singles, batch):
        assert s.token_ids == b.token_ids
    assert batch[0].n_forward_passes < sum(s.n_forward_passes
                                           for s in singles)


def test_batch_outputs_grammar_valid(setup, json_grammar):
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=20),
                        max_len=256)
    for r in eng.generate_batch(["p1: ", "p2 longer prompt: "]):
        d = DominoDecoder(json_grammar, list(tok.vocab), tok.eos_id)
        for t in r.token_ids:
            assert d.advance(t)


def test_batch_mla_arch(small_tokenizer):
    tok = small_tokenizer
    cfg = ModelConfig(arch_id="b-mla", family="moe", group=("moe",),
                      vocab_size=tok.vocab_size,
                      mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                    qk_nope_head_dim=16, qk_rope_head_dim=8,
                                    v_head_dim=16),
                      moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                                    capacity_factor=2.0), **BASE)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    g = grammars.load("json")
    eng = ServingEngine(m, params, tok, g,
                        EngineConfig(mode="domino", max_tokens=10),
                        max_len=256)
    singles = [eng.generate(p) for p in ["m: ", "longer mla prompt: "]]
    batch = eng.generate_batch(["m: ", "longer mla prompt: "])
    for s, b in zip(singles, batch):
        assert s.token_ids == b.token_ids


def test_batch_recurrent_arch_matches_single(small_tokenizer):
    """Recurrent (SSM) rows are admitted by exact-length prefill, so the
    continuous-batching path now covers them too."""
    from repro.configs.base import SSMConfig
    tok = small_tokenizer
    cfg = ModelConfig(arch_id="b-ssm", family="ssm", group=("mamba1",),
                      vocab_size=tok.vocab_size,
                      ssm=SSMConfig(d_state=8, version=1), **BASE)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    g = grammars.load("json")
    eng = ServingEngine(m, params, tok, g,
                        EngineConfig(mode="domino", max_tokens=8),
                        max_len=128)
    prompts = ["a", "bb longer: "]
    singles = [eng.generate(p) for p in prompts]
    batch = eng.generate_batch(prompts)
    for s, b in zip(singles, batch):
        assert s.token_ids == b.token_ids
