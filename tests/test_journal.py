"""Durability: the crash-consistent token journal and cold-restart
recovery.

Acceptance (ISSUE 9): SIGKILL at an arbitrary point in a batched run,
then ``ServingEngine.restore(journal_path)``, produces bitwise-identical
greedy output to an uninterrupted run; the journal replay fuzz proves a
crash at ANY byte offset never loses an acknowledged (fsynced) commit
and never resurrects an unacknowledged one.  In-process "crashes" here
are a crash_hook that raises — the file state at that instant is exactly
what a real SIGKILL leaves (everything after the last fsync is
untrusted), which tools/restart_smoke.py cross-checks with a real
``kill -9`` subprocess drill.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.serving import (ConstraintSpec, ContinuousBatchingScheduler,
                           DecodeParams, EngineConfig, FaultInjector,
                           Request, ServingEngine, TokenJournal,
                           read_records, replay_journal)
from repro.serving.journal import MAGIC, JournalError, scan_records

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            dtype="float32", max_seq_len=512)

PROMPTS = ["a: ", "some much longer json prompt here: ", "x"]


@pytest.fixture(scope="module")
def attn(small_tokenizer):
    cfg = ModelConfig(arch_id="j-attn", family="dense",
                      vocab_size=small_tokenizer.vocab_size, **BASE)
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _engine(attn, tok, grammar, max_tokens=10, **cfg_kw):
    m, params = attn
    return ServingEngine(m, params, tok, grammar,
                         EngineConfig(mode="domino", max_tokens=max_tokens,
                                      **cfg_kw),
                         max_len=256)


class Boom(Exception):
    """In-process stand-in for SIGKILL: raised by the crash hook, so the
    test regains control while the journal FILE is frozen exactly as a
    real kill would leave it (nothing after the last fsync is written)."""


# -- record format -------------------------------------------------------------


def test_append_buffers_commit_tick_writes(tmp_path):
    path = str(tmp_path / "j")
    j = TokenJournal(path, sync_every=2)
    base = os.path.getsize(path)
    j.append({"kind": "submit", "rid": 0, "prompt": "p"})
    assert os.path.getsize(path) == base     # append NEVER touches the file
    j.commit_tick()                          # tick 1 of 2: write, no fsync
    assert j.n_syncs == 0
    j.append({"kind": "commit", "rid": 0, "off": 0, "toks": [1, 2],
              "n_draws": 0})
    j.commit_tick()                          # tick 2: flush + fsync due
    assert j.n_syncs == 1
    assert os.path.getsize(path) > base
    j.append({"kind": "terminal", "rid": 0, "status": "ok", "error": None})
    j.commit_tick()                          # terminal forces a sync
    assert j.n_syncs == 2
    j.close()
    kinds = [r["kind"] for r in read_records(path)]
    assert kinds == ["submit", "commit", "terminal"]


def test_bad_magic_rejected(tmp_path):
    path = str(tmp_path / "not_a_journal")
    with open(path, "wb") as fh:
        fh.write(b"garbage bytes, definitely not a journal")
    with pytest.raises(JournalError):
        read_records(path)
    with pytest.raises(JournalError):
        TokenJournal(path)


def test_truncation_at_every_byte_offset(tmp_path):
    """Satellite 3, the durability fuzz: truncate the journal at EVERY
    byte offset; replay returns exactly the records whose frames were
    fully on disk before the cut — an acknowledged record is never lost,
    a partial one is never resurrected — and reopening at any cut leaves
    a journal that accepts new records."""
    path = str(tmp_path / "j")
    j = TokenJournal(path)
    ends = [os.path.getsize(path)]           # frame-boundary offsets
    for i in range(6):
        j.append({"kind": "commit", "rid": 0, "off": i,
                  "toks": [i, i + 40], "n_draws": 0})
        j.commit_tick()
        ends.append(os.path.getsize(path))
    j.close()
    with open(path, "rb") as fh:
        full = fh.read()
    assert ends[0] == len(MAGIC) and ends[-1] == len(full)

    t = str(tmp_path / "cut")
    for cut in range(len(full) + 1):
        with open(t, "wb") as fh:
            fh.write(full[:cut])
        if cut < len(MAGIC):
            with pytest.raises(JournalError):
                scan_records(t)
            continue
        recs, valid_end = scan_records(t)
        n_expected = max(i for i, e in enumerate(ends) if e <= cut)
        assert len(recs) == n_expected, cut
        assert valid_end == ends[n_expected], cut
        assert recs == [{"kind": "commit", "rid": 0, "off": i,
                         "toks": [i, i + 40], "n_draws": 0}
                        for i in range(n_expected)]
        # reopen truncates the torn tail and stays appendable
        j2 = TokenJournal(t)
        j2.append({"kind": "terminal", "rid": 0, "status": "ok",
                   "error": None})
        j2.commit_tick()
        j2.close()
        assert len(read_records(t)) == n_expected + 1


def test_crc_corruption_truncates_from_corrupt_record(tmp_path):
    path = str(tmp_path / "j")
    j = TokenJournal(path)
    for i in range(4):
        j.append({"kind": "commit", "rid": 0, "off": i, "toks": [i],
                  "n_draws": 0})
    j.commit_tick()
    j.close()
    _, end = scan_records(path)
    with open(path, "r+b") as fh:
        fh.seek(end - 3)                     # inside the LAST payload
        fh.write(b"\xff")
    recs, valid_end = scan_records(path)
    assert len(recs) == 3 and valid_end < end


def test_torn_write_injection_kills_journal_not_replay(tmp_path):
    path = str(tmp_path / "j")
    inj = FaultInjector(seed=0, rates={"journal_torn_write": 1.0},
                        max_faults=1)
    j = TokenJournal(path, injector=inj)
    j.append({"kind": "submit", "rid": 0, "prompt": "p"})
    j.commit_tick()                          # torn: half a frame lands
    assert j.dead
    j.append({"kind": "commit", "rid": 0, "off": 0, "toks": [1],
              "n_draws": 0})
    j.commit_tick()                          # dead journal: no-op
    j.close()
    assert read_records(path) == []          # half-frame fails CRC
    j2 = TokenJournal(path)                  # reopen truncates the tail
    j2.close()
    assert os.path.getsize(path) == len(MAGIC)


def test_replay_is_idempotent_and_detects_gaps(tmp_path):
    path = str(tmp_path / "j")
    j = TokenJournal(path)
    j.append({"kind": "submit", "rid": 0, "prompt": "p",
              "constraint": None, "decode": None, "recoverable": True,
              "reason": None})
    j.append({"kind": "commit", "rid": 0, "off": 0, "toks": [1, 2, 3],
              "n_draws": 0})
    # duplicated + overlapping deltas (a restored run re-journals): merge
    # by offset, exactly-once
    j.append({"kind": "commit", "rid": 0, "off": 0, "toks": [1, 2, 3],
              "n_draws": 0})
    j.append({"kind": "commit", "rid": 0, "off": 2, "toks": [3, 4],
              "n_draws": 0})
    # a GAP is impossible with in-order fsyncs -> unrecoverable, never
    # guessed at
    j.append({"kind": "submit", "rid": 1, "prompt": "q",
              "constraint": None, "decode": None, "recoverable": True,
              "reason": None})
    j.append({"kind": "commit", "rid": 1, "off": 5, "toks": [9],
              "n_draws": 0})
    j.commit_tick()
    j.close()
    entries = replay_journal(path)
    assert entries[0].toks == [1, 2, 3, 4]
    assert entries[0].recoverable
    assert not entries[1].recoverable
    assert "gap" in entries[1].reason


# -- scheduler lifecycle journaling --------------------------------------------


def test_run_journals_full_lifecycle_and_restore_reports_it(
        attn, small_tokenizer, json_grammar, tmp_path):
    eng = _engine(attn, small_tokenizer, json_grammar)
    path = str(tmp_path / "j")
    baseline = eng.generate_batch(list(PROMPTS), max_batch=2)
    results = eng.generate_batch(list(PROMPTS), max_batch=2,
                                 journal=TokenJournal(path))
    for b, r in zip(baseline, results):
        assert r.token_ids == b.token_ids    # journaling is non-invasive
    entries = replay_journal(path)
    assert sorted(entries) == [0, 1, 2]
    for rid, e in entries.items():
        assert e.terminal is not None
        assert e.toks == results[rid].token_ids
        assert e.terminal["status"] == results[rid].status
        assert e.recoverable
    # restoring a fully-terminal journal re-decodes NOTHING: every result
    # comes back as a journaled shell
    sched = eng.restore(path, debug_invariants=True)
    shells = sched.run()
    assert [r.token_ids for r in shells] == [r.token_ids for r in results]
    assert [r.status for r in shells] == [r.status for r in results]
    assert all(r.n_forward_passes == 0 for r in shells)


@pytest.mark.parametrize("crash_after", [1, 3, 6])
def test_crash_and_restore_is_bitwise_identical(
        attn, small_tokenizer, json_grammar, tmp_path, crash_after):
    """The tentpole acceptance: crash after the K-th fsync (early /
    mid / late in the run), restore from the journal, finish — greedy
    output bitwise-identical to the uninterrupted run, replayed tokens
    accounted."""
    eng = _engine(attn, small_tokenizer, json_grammar, max_tokens=12)
    baseline = eng.generate_batch(list(PROMPTS), max_batch=2)
    path = str(tmp_path / "j")

    def hook():
        raise Boom()

    j = TokenJournal(path, crash_after_syncs=crash_after, crash_hook=hook)
    sched = ContinuousBatchingScheduler(eng, capacity=2, journal=j)
    for p in PROMPTS:
        sched.submit(p)
    with pytest.raises(Boom):
        sched.run()
    j.dead = True                            # the process is "gone"

    sched2 = eng.restore(path, debug_invariants=True)
    restored = sched2.run()
    assert len(restored) == len(PROMPTS)
    assert [r.token_ids for r in restored] == \
        [b.token_ids for b in baseline]
    assert all(r.status == b.status for r, b in zip(restored, baseline))
    n_rep = sum(r.n_replayed_tokens for r in restored)
    assert n_rep == sched2.n_replayed_tokens
    if crash_after >= 3:                     # mid-run: prefixes existed
        assert n_rep > 0
    # leak-free teardown of the restored scheduler
    assert all(s is None for s in sched2.slots)
    if sched2.paged:
        assert sched2.pool.available == sched2.n_pages - 1


def test_crash_restore_resumes_sampled_rng_stream(
        attn, small_tokenizer, json_grammar, tmp_path):
    """A sampled row's journaled RNG state makes its post-restore draws
    continue the exact stream: crash/restore output equals the
    uninterrupted sampled run."""
    eng = _engine(attn, small_tokenizer, json_grammar)
    reqs = [Request(p, ConstraintSpec(grammar="default", mode="domino"),
                    DecodeParams(temperature=0.8, seed=11 + i,
                                 max_tokens=12))
            for i, p in enumerate(PROMPTS)]
    baseline = eng.generate_batch(list(reqs), max_batch=2)
    path = str(tmp_path / "j")

    def hook():
        raise Boom()

    j = TokenJournal(path, crash_after_syncs=4, crash_hook=hook)
    sched = ContinuousBatchingScheduler(eng, capacity=2, journal=j)
    for r in reqs:
        sched.submit(r)
    with pytest.raises(Boom):
        sched.run()
    j.dead = True
    restored = eng.restore(path, debug_invariants=True).run()
    assert [r.token_ids for r in restored] == \
        [b.token_ids for b in baseline]


def test_repeated_crash_restore_cycles_converge(
        attn, small_tokenizer, json_grammar, tmp_path):
    """Crash -> restore (re-journaling into the SAME file) -> crash ->
    restore again: idempotent deltas mean the journal converges on the
    uninterrupted output instead of compounding."""
    eng = _engine(attn, small_tokenizer, json_grammar, max_tokens=12)
    baseline = eng.generate_batch(list(PROMPTS), max_batch=2)
    path = str(tmp_path / "j")

    def hook():
        raise Boom()

    j = TokenJournal(path, crash_after_syncs=2, crash_hook=hook)
    sched = ContinuousBatchingScheduler(eng, capacity=2, journal=j)
    for p in PROMPTS:
        sched.submit(p)
    with pytest.raises(Boom):
        sched.run()
    j.dead = True
    # first restore resumes durably into the same file... and crashes too
    j2 = TokenJournal(path, crash_after_syncs=3, crash_hook=hook)
    sched2 = eng.restore(path, journal=j2)
    with pytest.raises(Boom):
        sched2.run()
    j2.dead = True
    # second restore completes
    final = eng.restore(path, journal=TokenJournal(path),
                        debug_invariants=True).run()
    assert [r.token_ids for r in final] == \
        [b.token_ids for b in baseline]
    # and the journal now holds every terminal, replayable a third time
    entries = replay_journal(path)
    assert all(e.terminal is not None for e in entries.values())
    assert [entries[i].toks for i in range(len(PROMPTS))] == \
        [b.token_ids for b in baseline]


def test_unrecoverable_request_is_reported_not_resurrected(
        attn, small_tokenizer, json_grammar, tmp_path):
    """An ad-hoc Grammar object can't be serialized: after a crash its
    entry restores as an explicit internal_error shell while the
    serializable batch-mate resumes bitwise-identical."""
    eng = _engine(attn, small_tokenizer, json_grammar, max_tokens=12)
    good = Request("a: ", ConstraintSpec(grammar="default", mode="domino"),
                   DecodeParams(max_tokens=12))
    baseline = eng.generate_batch([good], max_batch=1)
    adhoc = Request("x", ConstraintSpec(grammar=json_grammar,
                                        mode="domino"),
                    DecodeParams(max_tokens=12))
    path = str(tmp_path / "j")

    def hook():
        raise Boom()

    j = TokenJournal(path, crash_after_syncs=3, crash_hook=hook)
    sched = ContinuousBatchingScheduler(eng, capacity=2, journal=j)
    s_good = sched.submit(good)
    s_adhoc = sched.submit(adhoc)
    with pytest.raises(Boom):
        sched.run()
    j.dead = True
    entries = replay_journal(path)
    assert entries[s_good.rid].recoverable
    assert not entries[s_adhoc.rid].recoverable
    sched2 = eng.restore(path)
    by_rid = {}
    for r in sched2.run():
        by_rid[len(by_rid)] = r
    assert by_rid[s_good.rid].token_ids == baseline[0].token_ids
    assert by_rid[s_adhoc.rid].status == "internal_error"
    assert "not serializable" in by_rid[s_adhoc.rid].error
