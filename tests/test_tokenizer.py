"""Byte-level BPE tokenizer."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.tokenizer import BPETokenizer, train_bpe


def test_roundtrip_basic(small_tokenizer):
    tok = small_tokenizer
    for s in ['{"a": [1, 2.5], "b": true}', "int f() { return 1; }",
              "hello world", "", "ünïcødé"]:
        assert tok.decode(tok.encode(s)) == s
        assert tok.decode(tok.encode_greedy(s)) == s


@settings(max_examples=50, deadline=None)
@given(st.binary(max_size=40))
def test_roundtrip_arbitrary_bytes(data):
    tok = train_bpe(b"ababab abab cd cd", vocab_size=260)
    assert tok.decode_bytes(tok.encode_bytes(data)) == data


def test_byte_coverage(small_tokenizer):
    tok = small_tokenizer
    for b in range(256):
        assert tok.vocab[b] == bytes([b])


def test_specials(small_tokenizer):
    tok = small_tokenizer
    assert tok.vocab[tok.eos_id] is None
    assert tok.vocab[tok.pad_id] is None
    assert tok.eos_id == tok.vocab_size - 1


def test_merges_learned():
    corpus = b'{"key": 1}\n' * 50
    tok = train_bpe(corpus, vocab_size=300)
    assert tok.vocab_size > 259
    ids = tok.encode('{"key": 1}')
    assert len(ids) < len('{"key": 1}')  # merges actually applied


def test_save_load(tmp_path, small_tokenizer):
    p = tmp_path / "tok.json"
    small_tokenizer.save(p)
    tok2 = BPETokenizer.load(p)
    assert tok2.vocab == small_tokenizer.vocab
    s = '{"x": [true, null]}'
    assert tok2.encode(s) == small_tokenizer.encode(s)
