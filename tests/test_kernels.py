"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.masked_sample.ops import masked_argmax
from repro.kernels.masked_sample.ref import masked_argmax_ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("b,v,bv", [(1, 512, 128), (4, 8192, 2048),
                                    (2, 1000, 2048), (3, 4096, 512)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_masked_argmax(b, v, bv, dtype):
    logits = jnp.asarray(RNG.normal(size=(b, v)), dtype=dtype)
    mask = jnp.asarray((RNG.random((b, v)) < 0.02).astype(np.int8))
    mask = mask.at[:, v // 3].set(1)
    i1, v1 = masked_argmax(logits, mask, block_v=bv)
    i2, v2 = masked_argmax_ref(logits, mask)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


def test_masked_argmax_respects_mask():
    logits = jnp.asarray(RNG.normal(size=(2, 256)).astype(np.float32)) + 10
    mask = jnp.zeros((2, 256), jnp.int8).at[:, 5].set(1)
    i, _ = masked_argmax(logits, mask, block_v=64)
    assert list(np.asarray(i)) == [5, 5]


@pytest.mark.parametrize("b,g,q,d,t,bt", [
    (2, 2, 4, 64, 1024, 256), (1, 8, 1, 128, 2048, 512),
    (2, 1, 8, 32, 100, 512), (1, 2, 2, 128, 4096, 1024)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_decode_attention(b, g, q, d, t, bt, dtype):
    qq = jnp.asarray(RNG.normal(size=(b, g, q, d)), dtype=dtype)
    k = jnp.asarray(RNG.normal(size=(b, t, g, d)), dtype=dtype)
    v = jnp.asarray(RNG.normal(size=(b, t, g, d)), dtype=dtype)
    ln = jnp.int32(max(1, t - 13))
    o1 = decode_attention(qq, k, v, ln, block_t=bt)
    o2 = decode_attention_ref(qq, k, v, ln)
    atol = 3e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol,
                               rtol=1e-3)


def test_decode_attention_length_masking():
    b, g, q, d, t = 1, 1, 1, 16, 64
    qq = jnp.ones((b, g, q, d), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, t, g, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, t, g, d)).astype(np.float32))
    o_5 = decode_attention(qq, k, v, jnp.int32(5), block_t=16)
    # zeroing the cache beyond length must not change the output
    k2 = k.at[:, 5:].set(123.0)
    v2 = v.at[:, 5:].set(-55.0)
    o_5b = decode_attention(qq, k2, v2, jnp.int32(5), block_t=16)
    np.testing.assert_allclose(np.asarray(o_5), np.asarray(o_5b), atol=1e-6)


@pytest.mark.parametrize("b,s,d,n,bd,bs", [
    (2, 64, 32, 8, 16, 16), (1, 128, 512, 16, 512, 128),
    (2, 100, 48, 8, 48, 100), (1, 256, 64, 16, 32, 64)])
def test_mamba_scan(b, s, d, n, bd, bs):
    dt = jnp.asarray(np.abs(RNG.normal(size=(b, s, d))).astype(np.float32)
                     * 0.1)
    x = jnp.asarray(RNG.normal(size=(b, s, d)).astype(np.float32))
    bm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32))
    a = jnp.asarray(-np.abs(RNG.normal(size=(d, n))).astype(np.float32))
    h0 = jnp.asarray(RNG.normal(size=(b, d, n)).astype(np.float32))
    y1, h1 = mamba_scan(dt, x, bm, cm, a, h0, block_d=bd, block_s=bs)
    y2, h2 = mamba_scan_ref(dt, x, bm, cm, a, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4,
                               rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(2, 32))
def test_mamba_scan_property(b, chunks, d):
    """State continuity: scanning in one go == chunked with carried h."""
    s = chunks * 16
    n = 4
    dt = jnp.asarray(np.abs(RNG.normal(size=(b, s, d))).astype(np.float32)
                     * 0.1)
    x = jnp.asarray(RNG.normal(size=(b, s, d)).astype(np.float32))
    bm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32))
    a = jnp.asarray(-np.abs(RNG.normal(size=(d, n))).astype(np.float32))
    h = jnp.zeros((b, d, n), jnp.float32)
    y_full, h_full = mamba_scan_ref(dt, x, bm, cm, a, h)
    ys = []
    for c in range(chunks):
        sl = slice(c * 16, (c + 1) * 16)
        y, h = mamba_scan(dt[:, sl], x[:, sl], bm[:, sl], cm[:, sl], a, h,
                          block_d=d, block_s=16)
        ys.append(np.asarray(y))
    np.testing.assert_allclose(np.concatenate(ys, 1), np.asarray(y_full),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=1e-4,
                               rtol=1e-4)
