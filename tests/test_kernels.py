"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:                                   # only the property test needs it —
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                    # the parity sweeps must still run
    HAVE_HYPOTHESIS = False

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.core import bitmask
from repro.kernels.masked_sample.ops import masked_argmax
from repro.kernels.masked_sample.ref import masked_argmax_ref

RNG = np.random.default_rng(42)


# odd V (tail tiles padded, not collapsed to one whole-V VMEM tile) and
# packed uint32 masks ride the same sweep as the original shapes
@pytest.mark.parametrize("b,v,bv", [(1, 512, 128), (4, 8192, 2048),
                                    (2, 1000, 2048), (3, 4096, 512),
                                    (2, 4100, 2048), (1, 333, 128),
                                    (2, 262144, 2048)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("packed", [False, True])
def test_masked_argmax(b, v, bv, dtype, packed):
    logits = jnp.asarray(RNG.normal(size=(b, v)), dtype=dtype)
    mask_np = RNG.random((b, v)) < 0.02
    mask_np[:, v // 3] = True
    mask = jnp.asarray(bitmask.pack_bool(mask_np)) if packed \
        else jnp.asarray(mask_np.astype(np.int8))
    i1, v1 = masked_argmax(logits, mask, block_v=bv)
    i2, v2 = masked_argmax_ref(logits, jnp.asarray(mask_np.astype(np.int8)))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-6)


@pytest.mark.parametrize("packed", [False, True])
def test_masked_argmax_respects_mask(packed):
    logits = jnp.asarray(RNG.normal(size=(2, 256)).astype(np.float32)) + 10
    mask_np = np.zeros((2, 256), np.int8)
    mask_np[:, 5] = 1
    mask = jnp.asarray(bitmask.pack_bool(mask_np)) if packed \
        else jnp.asarray(mask_np)
    i, _ = masked_argmax(logits, mask, block_v=64)
    assert list(np.asarray(i)) == [5, 5]


@pytest.mark.parametrize("b,g,q,d,t,bt", [
    (2, 2, 4, 64, 1024, 256), (1, 8, 1, 128, 2048, 512),
    (2, 1, 8, 32, 100, 512), (1, 2, 2, 128, 4096, 1024)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_decode_attention(b, g, q, d, t, bt, dtype):
    qq = jnp.asarray(RNG.normal(size=(b, g, q, d)), dtype=dtype)
    k = jnp.asarray(RNG.normal(size=(b, t, g, d)), dtype=dtype)
    v = jnp.asarray(RNG.normal(size=(b, t, g, d)), dtype=dtype)
    ln = jnp.int32(max(1, t - 13))
    o1 = decode_attention(qq, k, v, ln, block_t=bt)
    o2 = decode_attention_ref(qq, k, v, ln)
    atol = 3e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol,
                               rtol=1e-3)


def test_decode_attention_length_masking():
    b, g, q, d, t = 1, 1, 1, 16, 64
    qq = jnp.ones((b, g, q, d), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, t, g, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, t, g, d)).astype(np.float32))
    o_5 = decode_attention(qq, k, v, jnp.int32(5), block_t=16)
    # zeroing the cache beyond length must not change the output
    k2 = k.at[:, 5:].set(123.0)
    v2 = v.at[:, 5:].set(-55.0)
    o_5b = decode_attention(qq, k2, v2, jnp.int32(5), block_t=16)
    np.testing.assert_allclose(np.asarray(o_5), np.asarray(o_5b), atol=1e-6)


@pytest.mark.parametrize("lens", [
    [100, 7], [0, 1], [13, 256], [256, 0], [5, 64]])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_decode_attention_ragged(lens, dtype):
    """Per-row (B,) lengths: rows shorter than one BLOCK_T, full rows and
    length-0 empty slots must all match the oracle."""
    b, g, qh, d, t, bt = 2, 2, 4, 32, 256, 64
    qq = jnp.asarray(RNG.normal(size=(b, g, qh, d)), dtype=dtype)
    k = jnp.asarray(RNG.normal(size=(b, t, g, d)), dtype=dtype)
    v = jnp.asarray(RNG.normal(size=(b, t, g, d)), dtype=dtype)
    ln = jnp.asarray(lens, jnp.int32)
    o1 = decode_attention(qq, k, v, ln, block_t=bt)
    o2 = decode_attention_ref(qq, k, v, ln)
    atol = 3e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol,
                               rtol=1e-3)


def test_decode_attention_empty_row_is_zero():
    """A length-0 row (empty serving slot) yields zeros, not an average
    over garbage cache entries."""
    b, g, qh, d, t = 2, 1, 2, 16, 64
    qq = jnp.asarray(RNG.normal(size=(b, g, qh, d)).astype(np.float32))
    k = jnp.full((b, t, g, d), 3.0, jnp.float32)
    v = jnp.full((b, t, g, d), 7.0, jnp.float32)
    o = decode_attention(qq, k, v, jnp.asarray([0, 4], jnp.int32),
                         block_t=16)
    np.testing.assert_allclose(np.asarray(o[0]), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(o[1]), 7.0, rtol=1e-5)


@pytest.mark.parametrize("s_win,lens", [
    (2, [60, 250]), (4, [0, 17]), (3, [100, 100])])
def test_decode_attention_verify_window(s_win, lens):
    """Q>1 speculative verify windows: window position s of row b attends
    keys t < lengths[b] + s (causal offsets), matching the oracle."""
    b, g, qh, d, t, bt = 2, 2, 2, 32, 256, 64
    qq = jnp.asarray(RNG.normal(size=(b, s_win, g, qh, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, t, g, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, t, g, d)).astype(np.float32))
    ln = jnp.asarray(lens, jnp.int32)
    o1 = decode_attention(qq, k, v, ln, block_t=bt)
    o2 = decode_attention_ref(qq, k, v, ln)
    assert o1.shape == (b, s_win, g, qh, d)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5,
                               rtol=1e-3)
    # window position 0 must agree with a plain S=1 call at the same length
    o_pos0 = decode_attention(qq[:, :1], k, v, ln, block_t=bt)
    np.testing.assert_allclose(np.asarray(o1[:, :1]), np.asarray(o_pos0),
                               atol=3e-5, rtol=1e-3)


def test_decode_attention_mla_layout():
    """Absorbed-MLA shape: one KV group, split latent+rope score
    (q.k^T + q2.k2^T) against Dv = r latent values, explicit softmax
    scale — and the split form must equal the concatenated form."""
    b, h, r, dr, t = 2, 4, 16, 8, 128
    scale = 0.17
    q1 = jnp.asarray(RNG.normal(size=(b, 1, 1, h, r)).astype(np.float32))
    q2 = jnp.asarray(RNG.normal(size=(b, 1, 1, h, dr)).astype(np.float32))
    k1 = jnp.asarray(RNG.normal(size=(b, t, 1, r)).astype(np.float32))
    k2 = jnp.asarray(RNG.normal(size=(b, t, 1, dr)).astype(np.float32))
    v = k1                                 # MLA: values ARE the latents
    ln = jnp.asarray([100, 3], jnp.int32)
    o1 = decode_attention(q1, k1, v, ln, block_t=32, scale=scale,
                          q2=q2, k2=k2)
    o2 = decode_attention_ref(q1, k1, v, ln, scale=scale, q2=q2, k2=k2)
    assert o1.shape == (b, 1, 1, h, r)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5,
                               rtol=1e-3)
    # split == concat
    o3 = decode_attention(jnp.concatenate([q1, q2], -1),
                          jnp.concatenate([k1, k2], -1), v, ln,
                          block_t=32, scale=scale)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=3e-5,
                               rtol=1e-3)


@pytest.mark.parametrize("b,s,d,n,bd,bs", [
    (2, 64, 32, 8, 16, 16), (1, 128, 512, 16, 512, 128),
    (2, 100, 48, 8, 48, 100), (1, 256, 64, 16, 32, 64)])
def test_mamba_scan(b, s, d, n, bd, bs):
    dt = jnp.asarray(np.abs(RNG.normal(size=(b, s, d))).astype(np.float32)
                     * 0.1)
    x = jnp.asarray(RNG.normal(size=(b, s, d)).astype(np.float32))
    bm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32))
    a = jnp.asarray(-np.abs(RNG.normal(size=(d, n))).astype(np.float32))
    h0 = jnp.asarray(RNG.normal(size=(b, d, n)).astype(np.float32))
    y1, h1 = mamba_scan(dt, x, bm, cm, a, h0, block_d=bd, block_s=bs)
    y2, h2 = mamba_scan_ref(dt, x, bm, cm, a, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4,
                               rtol=1e-4)


def _prop_wrap(f):
    if not HAVE_HYPOTHESIS:
        return pytest.mark.skip(reason="hypothesis not installed")(f)
    return settings(max_examples=10, deadline=None)(
        given(st.integers(1, 3), st.integers(1, 4), st.integers(2, 32))(f))


@_prop_wrap
def test_mamba_scan_property(b=1, chunks=1, d=2):
    """State continuity: scanning in one go == chunked with carried h."""
    s = chunks * 16
    n = 4
    dt = jnp.asarray(np.abs(RNG.normal(size=(b, s, d))).astype(np.float32)
                     * 0.1)
    x = jnp.asarray(RNG.normal(size=(b, s, d)).astype(np.float32))
    bm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32))
    a = jnp.asarray(-np.abs(RNG.normal(size=(d, n))).astype(np.float32))
    h = jnp.zeros((b, d, n), jnp.float32)
    y_full, h_full = mamba_scan_ref(dt, x, bm, cm, a, h)
    ys = []
    for c in range(chunks):
        sl = slice(c * 16, (c + 1) * 16)
        y, h = mamba_scan(dt[:, sl], x[:, sl], bm[:, sl], cm[:, sl], a, h,
                          block_d=d, block_s=16)
        ys.append(np.asarray(y))
    np.testing.assert_allclose(np.concatenate(ys, 1), np.asarray(y_full),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=1e-4,
                               rtol=1e-4)
