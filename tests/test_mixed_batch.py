"""Per-request constraint API (ISSUE 5): mixed-mode batches.

Acceptance: one scheduler batch concurrently serves two distinct grammars
(JSON + C) plus online-checked and unconstrained rows, each row
token-for-token identical to the same request served alone on a
single-grammar engine; per-grammar TreeCaches are shared across sessions
(no per-request tree builds); per-row EOS ids, dead-end accounting and
``mask_cache_hits`` attribution; per-request RNG makes sampled output
independent of batch composition; greedy selection on packed premasks
never round-trips through a bool unpack.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import bitmask, grammars
from repro.serving import (ConstraintSpec, ContinuousBatchingScheduler,
                           DecodeParams, EngineConfig, Request,
                           ServingEngine)

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            dtype="float32", max_seq_len=512)


@pytest.fixture(scope="module")
def setup(request):
    tok = request.getfixturevalue("small_tokenizer")
    cfg = ModelConfig(arch_id="mx", family="dense",
                      vocab_size=tok.vocab_size, **BASE)
    from repro.models import build_model
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0)), tok


def test_mixed_grammar_batch_matches_single_grammar_engines(setup,
                                                            json_grammar):
    """{json domino, C domino, json online, unconstrained} in ONE batch
    (fewer slots than requests, so mixed rows also share slots over
    time), bitwise-identical per row to single-grammar engines."""
    m, params, tok = setup
    c_grammar = grammars.load("c")
    eng = ServingEngine(m, params, tok, max_len=256)
    tc_json = eng.register_grammar("json", json_grammar)
    tc_c = eng.register_grammar("c", c_grammar)
    eng.precompute()

    reqs = [
        Request("a json: ", ConstraintSpec(grammar="json", mode="domino"),
                DecodeParams(max_tokens=10)),
        Request("a c program: ", ConstraintSpec(grammar="c", mode="domino"),
                DecodeParams(max_tokens=10)),
        Request("a json: ", ConstraintSpec(grammar="json", mode="online"),
                DecodeParams(max_tokens=8)),
        Request("free text: ", ConstraintSpec(),
                DecodeParams(max_tokens=8)),
        # a second domino row on the SAME prompt: its states replay the
        # first row's, so the shared mask memo must serve hits
        Request("a json: ", ConstraintSpec(grammar="json", mode="domino"),
                DecodeParams(max_tokens=10)),
    ]
    # single-grammar engines (legacy surface), sharing the tree caches so
    # the comparison isolates scheduling, not tree construction
    singles = [
        ServingEngine(m, params, tok, json_grammar,
                      EngineConfig(mode="domino", max_tokens=10),
                      tree_cache=tc_json, max_len=256).generate(reqs[0].prompt),
        ServingEngine(m, params, tok, c_grammar,
                      EngineConfig(mode="domino", max_tokens=10),
                      tree_cache=tc_c, max_len=256).generate(reqs[1].prompt),
        ServingEngine(m, params, tok, json_grammar,
                      EngineConfig(mode="online", max_tokens=8),
                      tree_cache=tc_json, max_len=256).generate(reqs[2].prompt),
        ServingEngine(m, params, tok, None,
                      EngineConfig(mode="unconstrained", max_tokens=8),
                      max_len=256).generate(reqs[3].prompt),
        ServingEngine(m, params, tok, json_grammar,
                      EngineConfig(mode="domino", max_tokens=10),
                      tree_cache=tc_json, max_len=256).generate(reqs[4].prompt),
    ]
    # the singles (sharing the caches) populated every reachable tree;
    # serving the mixed batch must build NONE per request
    trees_before = (len(tc_json.trees), len(tc_c.trees))
    sched = ContinuousBatchingScheduler(eng, capacity=3)
    sessions = [sched.submit(r) for r in reqs]
    sched.run()
    results = [s.result for s in sessions]
    for r, s in zip(results, singles):
        assert r.token_ids == s.token_ids
        assert r.finished == s.finished
        assert r.dead_end == s.dead_end

    # per-grammar TreeCaches are SHARED: sessions reference the registry
    # caches and serving built no new trees after the warm pass
    assert sessions[0].checker.trees is tc_json
    assert sessions[1].checker.trees is tc_c
    assert sessions[4].checker.trees is tc_json
    assert (len(tc_json.trees), len(tc_c.trees)) == trees_before

    # mask_cache_hits is attributed per ROW: the replayed json row hits
    # the shared memo, the unconstrained row cannot
    assert results[4].mask_cache_hits > 0
    assert results[3].mask_cache_hits == 0
    assert sched.mask_cache_hits > 0
    assert sum(r.mask_cache_hits for r in results) >= sched.mask_cache_hits


def test_unregistered_grammar_name_raises(setup):
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, max_len=256)
    req = Request("x", ConstraintSpec(grammar="nope", mode="domino"))
    with pytest.raises(KeyError, match="not registered"):
        eng.generate(req)


def test_per_row_eos_ids(setup):
    """Two unconstrained rows with DIFFERENT EOS ids in one batch: the
    row whose EOS equals the model's first pick finishes with 0 tokens,
    the default-EOS row is unaffected."""
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, max_len=256)
    base = Request("free text: ", ConstraintSpec(),
                   DecodeParams(max_tokens=6))
    single = eng.generate(base)
    assert single.n_tokens > 0
    first_tok = single.token_ids[0]
    early = Request("free text: ", ConstraintSpec(eos_id=first_tok),
                    DecodeParams(max_tokens=6))
    sched = ContinuousBatchingScheduler(eng, capacity=2)
    s_base = sched.submit(base)
    s_early = sched.submit(early)
    sched.run()
    assert s_early.result.finished and s_early.result.n_tokens == 0
    assert s_base.result.token_ids == single.token_ids
    # and the per-row EOS behaves identically on the single-request path
    assert eng.generate(early).n_tokens == 0


class _DeadEndStub:
    """Checker stub that dead-ends after two tokens."""

    def __init__(self, inner):
        self.inner = inner
        self.steps = 0

    def mask(self):
        m = self.inner.mask()
        if self.steps >= 2:
            m[:] = False
        return m

    def check_token(self, t):
        return bool(self.mask()[t])

    def advance(self, t):
        self.steps += 1
        return self.inner.advance(t)


@dataclasses.dataclass(frozen=True)
class _DeadEndSpec(ConstraintSpec):
    """A custom ConstraintSpec: the checker factory is spec-owned, so a
    request can carry a bespoke checker into a mixed batch."""

    def make_checker(self, grammar, vocab, eos_id, tree_cache=None,
                     heal_prefix=""):
        return _DeadEndStub(super().make_checker(
            grammar, vocab, eos_id, tree_cache=tree_cache,
            heal_prefix=heal_prefix))


def test_per_row_dead_end_accounting(setup, json_grammar):
    """One row dead-ends mid-batch; its neighbors are unaffected and the
    dead end is surfaced on that row only."""
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, max_len=256)
    eng.register_grammar("json", json_grammar)
    healthy = Request("a json: ", ConstraintSpec(grammar="json",
                                                 mode="domino"),
                      DecodeParams(max_tokens=8))
    doomed = Request("a json: ", _DeadEndSpec(grammar="json",
                                              mode="domino"),
                     DecodeParams(max_tokens=8))
    single = eng.generate(healthy)
    sched = ContinuousBatchingScheduler(eng, capacity=2)
    s_ok = sched.submit(healthy)
    s_dead = sched.submit(doomed)
    sched.run()
    assert s_dead.result.dead_end and not s_dead.result.finished
    assert len(s_dead.result.token_ids) == 2
    assert not s_ok.result.dead_end
    assert s_ok.result.token_ids == single.token_ids


def test_per_request_rng_is_batch_invariant(setup):
    """Satellite: sampling draws from a per-request Generator seeded by
    DecodeParams.seed, so a sampled request's output no longer depends on
    batch composition or admission order."""
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, max_len=256)
    sampled = Request("free text: ", ConstraintSpec(),
                      DecodeParams(temperature=0.9, seed=123, max_tokens=8))
    other = Request("another: ", ConstraintSpec(),
                    DecodeParams(temperature=0.9, seed=7, max_tokens=8))
    single = eng.generate(sampled)
    # same request, different batch compositions and admission orders
    alone = eng.generate_batch([sampled])[0]
    first = eng.generate_batch([sampled, other])[0]
    last = eng.generate_batch([other, sampled])[1]
    assert single.token_ids == alone.token_ids
    assert single.token_ids == first.token_ids
    assert single.token_ids == last.token_ids
    # different seed, same everything else -> (almost surely) different
    reseeded = Request("free text: ", ConstraintSpec(),
                       DecodeParams(temperature=0.9, seed=321,
                                    max_tokens=8))
    assert eng.generate(reseeded).token_ids != single.token_ids
    # speculative + sampled: speculation is gated off (greedy-verified
    # proposals can't help, and mismatch-dependent RNG consumption would
    # re-couple output to the shared count model / batch composition),
    # so the invariant holds for this combination too
    spec_sampled = Request(
        "free text: ", ConstraintSpec(),
        DecodeParams(temperature=0.9, seed=123, max_tokens=8,
                     speculative=True, spec_s=4, spec_threshold=0.4))
    assert eng._speculator_for(spec_sampled.decode) is None
    assert eng.generate(spec_sampled).token_ids == single.token_ids
    assert eng.generate_batch([other, spec_sampled])[1].token_ids \
        == single.token_ids


def test_mixed_temperatures_in_one_batch(setup, json_grammar):
    """Greedy rows select through the fused kernel while sampled rows
    draw host-side, in the same tick."""
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, max_len=256)
    eng.register_grammar("json", json_grammar)
    greedy = Request("a json: ", ConstraintSpec(grammar="json",
                                                mode="domino"),
                     DecodeParams(max_tokens=8))
    hot = Request("a json: ", ConstraintSpec(grammar="json",
                                             mode="domino"),
                  DecodeParams(temperature=0.8, seed=5, max_tokens=8))
    singles = [eng.generate(greedy), eng.generate(hot)]
    batch = eng.generate_batch([greedy, hot])
    assert batch[0].token_ids == singles[0].token_ids
    assert batch[1].token_ids == singles[1].token_ids


def test_mixed_speculative_and_plain_rows(setup):
    """Per-row speculation: a batch mixing a speculative row with plain
    and unconstrained rows stays output-invariant; only the speculative
    row proposes."""
    m, params, tok = setup
    g = grammars.load("json_gsm8k")
    eng = ServingEngine(m, params, tok, max_len=256)
    eng.register_grammar("gsm8k", g)
    spec = Request("A: ", ConstraintSpec(grammar="gsm8k", mode="domino"),
                   DecodeParams(max_tokens=12, speculative=True, spec_s=4,
                                spec_threshold=0.4))
    plain = Request("Q: compute 1 + 2\nA: ",
                    ConstraintSpec(grammar="gsm8k", mode="domino"),
                    DecodeParams(max_tokens=12))
    free = Request("free: ", ConstraintSpec(), DecodeParams(max_tokens=6))
    eng.generate(spec)                  # warm the shared count model
    singles = [eng.generate(r) for r in (spec, plain, free)]
    sessions_results = eng.generate_batch([spec, plain, free])
    for r, s in zip(sessions_results, singles):
        assert r.token_ids == s.token_ids
    assert sessions_results[0].n_spec_proposed > 0
    assert sessions_results[1].n_spec_proposed == 0
    assert sessions_results[2].n_spec_proposed == 0


def test_pick_keeps_packed_premask_packed(setup, json_grammar,
                                          monkeypatch):
    """Satellite: greedy selection on a uint32 premask tests the
    candidate's bit / runs the packed argmax directly — bitmask.unpack is
    never called.  The bool unpack survives only for temperature>0."""
    m, params, tok = setup
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino"), max_len=256)
    checker = eng._make_checker()
    bits = np.array(checker.mask_bits())        # packed premask row
    rng = np.random.default_rng(0)
    logits = rng.normal(size=tok.vocab_size).astype(np.float32)
    # oracle BEFORE patching: selection over the unpacked bool view
    oracle_mask = bitmask.unpack(bits, tok.vocab_size)
    oracle = int(np.where(oracle_mask,
                          logits.astype(np.float64), -1e30).argmax())

    def boom(*a, **k):
        raise AssertionError("greedy packed premask was unpacked to bool")

    monkeypatch.setattr(bitmask, "unpack", boom)
    tok_id, intervened, _dt = eng._pick(logits, checker, premask=bits)
    assert tok_id == oracle
    # candidate-legal fast path: logits peaked on a legal token
    legal = oracle
    peaked = logits.copy()
    peaked[legal] = 1e9
    tok_id2, intervened2, _ = eng._pick(peaked, checker, premask=bits)
    assert tok_id2 == legal and intervened2 == 0
    monkeypatch.undo()
    # temperature>0 still unpacks (and samples a legal token)
    from repro.serving.request import DecodeParams as DP
    from repro.serving.engine import _RowPolicy
    pol = _RowPolicy(temperature=0.7, opportunistic=False,
                     decode=DP(temperature=0.7, seed=1))
    tok_id3, _, _ = eng._pick(logits, checker, premask=bits, policy=pol)
    assert oracle_mask[tok_id3]
