"""Paged KV cache: block-table slots through the ragged flash-decode
kernel (ISSUE 3 tentpole).

Kernel level: the paged pool + block-table read must match the
dense/contiguous oracle EXACTLY (same tile order, same accumulation) on
mixed ragged lengths, S>1 verify windows, the MLA split layout and rows
spanning non-contiguous pool pages.  Scheduler level: the page allocator
(alloc/free/reuse, exhaustion backpressure, recompute preemption,
rollback shrink) must be observationally pure — token-for-token identical
to single-request generation and to the dense-stripe scheduler.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, ModelConfig, SSMConfig
from repro.core import grammars
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                gather_pages)
from repro.models import build_model, kvcache
from repro.serving import (ContinuousBatchingScheduler, EngineConfig,
                           ServingEngine)
from repro.serving.scheduler import PagePool

RNG = np.random.default_rng(7)

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            dtype="float32", max_seq_len=512)

PROMPTS = ["a: ", "some much longer json prompt here: ", "x",
           "record -> "]


def _build(arch: str, vocab_size: int, **over):
    if arch == "attn":
        cfg = ModelConfig(arch_id="p-attn", family="dense",
                          vocab_size=vocab_size, **BASE, **over)
    elif arch == "mla":
        cfg = ModelConfig(arch_id="p-mla", family="dense", group=("mla",),
                          vocab_size=vocab_size,
                          mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                        qk_nope_head_dim=16,
                                        qk_rope_head_dim=8, v_head_dim=16),
                          **BASE, **over)
    elif arch == "ssm":
        cfg = ModelConfig(arch_id="p-ssm", family="ssm", group=("mamba1",),
                          vocab_size=vocab_size,
                          ssm=SSMConfig(d_state=8, version=1), **BASE,
                          **over)
    else:
        raise ValueError(arch)
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _shuffled_tables(lens, page_size, max_pages, n_pages):
    """Block tables whose pages are deliberately non-contiguous: row i's
    logical tile j maps to a shuffled pool row."""
    perm = list(RNG.permutation(np.arange(1, n_pages)))
    tbl = np.zeros((len(lens), max_pages), np.int32)
    for i, ln in enumerate(lens):
        n_pg = -(-int(ln) // page_size)
        tbl[i, :n_pg] = perm[:n_pg]
        del perm[:n_pg]
    return jnp.asarray(tbl)


# -- kernel ------------------------------------------------------------------


@pytest.mark.parametrize("s_win,lens", [
    (1, [100, 0, 17, 256]), (4, [60, 250, 0, 5]), (3, [31, 32, 33, 1])])
def test_paged_kernel_matches_dense_exactly(s_win, lens):
    """Rows spanning non-contiguous pool pages: the paged kernel must be
    BITWISE identical to the dense kernel on the gathered view (same tile
    sequence, same accumulation order) and match the jnp oracle."""
    b, g, qh, d, ps = 4, 2, 2, 32, 32
    mp = 256 // ps
    n_pages = 1 + sum(-(-max(l, 1) // ps) for l in lens) + 2
    pool_k = jnp.asarray(RNG.normal(size=(n_pages, ps, g, d)),
                         jnp.float32)
    pool_v = jnp.asarray(RNG.normal(size=(n_pages, ps, g, d)),
                         jnp.float32)
    tbl = _shuffled_tables(lens, ps, mp, n_pages)
    ln = jnp.asarray(lens, jnp.int32)
    q = jnp.asarray(RNG.normal(size=(b, s_win, g, qh, d)), jnp.float32)
    qq = q[:, 0] if s_win == 1 else q
    o_paged = decode_attention(qq, pool_k, pool_v, ln, block_tables=tbl)
    k_d, v_d = gather_pages(pool_k, tbl), gather_pages(pool_v, tbl)
    o_dense = decode_attention(qq, k_d, v_d, ln, block_t=ps)
    np.testing.assert_array_equal(np.asarray(o_paged), np.asarray(o_dense))
    o_ref = decode_attention_ref(qq, pool_k, pool_v, ln, block_tables=tbl)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_ref),
                               atol=3e-5, rtol=1e-3)


def test_paged_kernel_mla_split_layout():
    """Absorbed-MLA split score (q.ckv^T + q_rope.krope^T) against paged
    latent + rope pools, Dv = r."""
    b, h, r, dr, ps, mp = 3, 4, 16, 8, 16, 8
    lens = [100, 3, 0]
    n_pages = 16
    scale = 0.23
    q1 = jnp.asarray(RNG.normal(size=(b, 1, 1, h, r)), jnp.float32)
    q2 = jnp.asarray(RNG.normal(size=(b, 1, 1, h, dr)), jnp.float32)
    k1 = jnp.asarray(RNG.normal(size=(n_pages, ps, 1, r)), jnp.float32)
    k2 = jnp.asarray(RNG.normal(size=(n_pages, ps, 1, dr)), jnp.float32)
    tbl = _shuffled_tables(lens, ps, mp, n_pages)
    ln = jnp.asarray(lens, jnp.int32)
    o_paged = decode_attention(q1, k1, k1, ln, scale=scale, q2=q2, k2=k2,
                               block_tables=tbl)
    o_dense = decode_attention(q1, gather_pages(k1, tbl),
                               gather_pages(k1, tbl), ln, block_t=ps,
                               scale=scale, q2=q2,
                               k2=gather_pages(k2, tbl))
    np.testing.assert_array_equal(np.asarray(o_paged), np.asarray(o_dense))
    o_ref = decode_attention_ref(q1, k1, k1, ln, scale=scale, q2=q2,
                                 k2=k2, block_tables=tbl)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_ref),
                               atol=3e-5, rtol=1e-3)


def test_paged_kernel_ignores_garbage_in_foreign_pages():
    """Poisoning pool pages NOT referenced below a row's frontier must
    not change its output (the validity contract through block tables)."""
    b, g, qh, d, ps = 2, 1, 2, 16, 16
    lens = [20, 0]
    n_pages = 8
    pool_k = jnp.asarray(RNG.normal(size=(n_pages, ps, g, d)), jnp.float32)
    pool_v = jnp.asarray(RNG.normal(size=(n_pages, ps, g, d)), jnp.float32)
    tbl = jnp.asarray([[3, 5, 0, 0], [0, 0, 0, 0]], jnp.int32)
    ln = jnp.asarray(lens, jnp.int32)
    qq = jnp.asarray(RNG.normal(size=(b, g, qh, d)), jnp.float32)
    o1 = decode_attention(qq, pool_k, pool_v, ln, block_tables=tbl)
    # poison every pool row except 3 and 5, plus the tail of page 5
    # beyond position 20 (= in-page offset 4)
    keep = np.zeros(n_pages, bool)
    keep[[3, 5]] = True
    pk = np.array(pool_k)
    pv = np.array(pool_v)
    pk[~keep] = 1e6
    pv[~keep] = -1e6
    pk[5, 4:] = 1e6
    pv[5, 4:] = -1e6
    o2 = decode_attention(qq, jnp.asarray(pk), jnp.asarray(pv), ln,
                          block_tables=tbl)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    # the empty row reads nothing at all
    np.testing.assert_allclose(np.asarray(o1[1]), 0.0, atol=1e-6)


# -- allocator ---------------------------------------------------------------


def test_page_pool_alloc_free_reuse():
    pool = PagePool(8)                  # pages 1..7 usable
    assert pool.available == 7
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert len(a) == 3 and len(b) == 2
    assert 0 not in a + b               # trash page never issued
    assert len(set(a + b)) == 5
    assert pool.alloc(3) is None        # all-or-nothing: only 2 left
    assert pool.available == 2          # ... and a refused alloc takes none
    pool.free(a)
    assert pool.available == 5
    c = pool.alloc(5)
    assert set(c) >= set(a)             # freed pages are reused (LIFO)
    pool.free(b + c)
    assert pool.available == 7
    assert pool.alloc(0) == []


def test_paged_cache_layout_and_pageable():
    cfg = ModelConfig(arch_id="p-l", family="dense", vocab_size=64, **BASE)
    assert kvcache.pageable(cfg)
    cache = kvcache.init_cache(cfg, batch=3, max_len=128, page_size=32,
                               n_pages=10)
    assert cache["pages"].shape == (3, 4)
    assert int(cache["pages"].min()) == 0          # init -> trash page
    k = cache["group"]["b0"]["k"]                  # (reps, P, ps, nkv, dh)
    assert k.shape[1:3] == (10, 32)
    assert kvcache.page_size_of(cache) == 32
    # ring/recurrent archs are not pageable
    ssm_cfg = ModelConfig(arch_id="p-s", family="ssm", group=("mamba1",),
                          vocab_size=64, ssm=SSMConfig(d_state=8),
                          **BASE)
    assert not kvcache.pageable(ssm_cfg)
    swa_cfg = ModelConfig(arch_id="p-w", family="dense",
                          group=("swa", "attn"), sliding_window=16,
                          vocab_size=64, **BASE)
    assert not kvcache.pageable(swa_cfg)


def test_scheduler_disables_paging_on_refeed_archs(small_tokenizer,
                                                   json_grammar):
    tok = small_tokenizer
    m, params = _build("ssm", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=4),
                        max_len=256)
    sched = ContinuousBatchingScheduler(eng, capacity=2)
    assert not sched.paged
    assert "pages" not in sched.cache
    # the auto default falls back silently; an EXPLICIT paged=True with
    # its own pool sizing must not quietly allocate dense stripes
    with pytest.raises(ValueError, match="paged KV"):
        ContinuousBatchingScheduler(eng, capacity=2, paged=True,
                                    n_pages=8)


def test_writes_past_max_len_land_on_trash_page(small_tokenizer):
    """A decode at a full row (len == max_len) writes past the block
    table's capacity — the dense layout drops the OOB scatter, so the
    paged layout must route it to the trash page, NOT clamp onto the
    row's newest live page and corrupt accepted KV."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    cache = m.init_cache(1, 32, page_size=8, n_pages=6)
    cache["len"] = jnp.asarray([32], jnp.int32)           # row is full
    cache["pages"] = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    before = jax.tree.map(lambda x: np.array(x), {
        "head": cache["head"], "tail": cache["tail"],
        "group": cache["group"]})
    _, new_cache = m.decode_step(params, cache,
                                 jnp.asarray([[5]], jnp.int32))
    for b0, b1 in zip(before["head"] + before["tail"],
                      new_cache["head"] + new_cache["tail"]):
        for key in b0:
            np.testing.assert_array_equal(b0[key][1:5],
                                          np.asarray(b1[key])[1:5])
    for k in before["group"]:
        for key in before["group"][k]:
            np.testing.assert_array_equal(
                before["group"][k][key][:, 1:5],
                np.asarray(new_cache["group"][k][key])[:, 1:5])


# -- scheduler ---------------------------------------------------------------


def test_paged_scheduler_matches_dense_and_single(small_tokenizer,
                                                  json_grammar):
    """The whole point: per-request pages instead of contiguous stripes,
    token-for-token identical output."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=10),
                        max_len=256)
    singles = [eng.generate(p) for p in PROMPTS]
    dense = ContinuousBatchingScheduler(eng, capacity=2, paged=False)
    s_d = [dense.submit(p) for p in PROMPTS]
    dense.run()
    paged = ContinuousBatchingScheduler(eng, capacity=2, page_size=16)
    s_p = [paged.submit(p) for p in PROMPTS]
    paged.run()
    assert paged.paged and not dense.paged
    for single, d, p in zip(singles, s_d, s_p):
        assert p.result.token_ids == single.token_ids
        assert p.result.token_ids == d.result.token_ids
    # eviction returned every page
    assert paged.pool.available == paged.n_pages - 1
    assert np.all(paged._page_tbl == 0)


def test_paged_decode_routes_block_tables_through_kernel(small_tokenizer,
                                                         json_grammar,
                                                         monkeypatch):
    """With use_pallas_kernels the paged batched decode must hand the
    block table to kernels/decode_attention (no dense gather)."""
    import repro.kernels.decode_attention.ops as dec_ops

    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size, use_pallas_kernels=True)
    calls = {"paged": 0, "total": 0}
    real = dec_ops.decode_attention

    def spy(q, k, v, lengths, **kw):
        calls["total"] += 1
        if kw.get("block_tables") is not None:
            calls["paged"] += 1
            assert k.ndim == 4 and k.shape[1] == 16   # (P, ps, G, D) pool
        return real(q, k, v, lengths, **kw)

    monkeypatch.setattr(dec_ops, "decode_attention", spy)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=8),
                        max_len=256)
    sched = ContinuousBatchingScheduler(eng, capacity=2, page_size=16)
    sessions = [sched.submit(p) for p in PROMPTS[:2]]
    sched.run()
    assert calls["paged"] > 0
    # parity vs the dense-fallback (kernels off) scheduler
    m0, _ = _build("attn", tok.vocab_size)
    eng0 = ServingEngine(m0, params, tok, json_grammar,
                         EngineConfig(mode="domino", max_tokens=8),
                         max_len=256)
    base = eng0.generate_batch(PROMPTS[:2], max_batch=2)
    for r0, s1 in zip(base, sessions):
        assert r0.token_ids == s1.result.token_ids


def test_admission_blocks_on_pool_exhaustion_then_resumes(small_tokenizer,
                                                          json_grammar):
    """Backpressure: with pages for only one resident request, the second
    must wait in the queue (slot free, pool empty) and be admitted only
    after the first finishes and frees its pages — outputs unchanged."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=6),
                        max_len=256)
    singles = [eng.generate(p) for p in PROMPTS[:2]]
    # ONE usable 64-token page: each request fits in it (prompt + budget
    # < 64), but only one can hold it at a time
    for p in PROMPTS[:2]:
        assert len(tok.encode(p)) + 6 < 64
    sched = ContinuousBatchingScheduler(eng, capacity=2, page_size=64,
                                        n_pages=2)
    sessions = [sched.submit(p) for p in PROMPTS[:2]]
    sched.step()
    # one admitted, one blocked on pages despite the free slot
    assert sum(s is not None for s in sched.slots) == 1
    assert len(sched.waiting) == 1
    blocked_while_free_slot = sched.waiting[0] is sessions[1]
    assert blocked_while_free_slot
    sched.run()
    for single, s in zip(singles, sessions):
        assert s.result.token_ids == single.token_ids
    assert sched.pool.available == sched.n_pages - 1


def test_no_stale_reads_after_page_reuse(small_tokenizer, json_grammar):
    """A freed page re-issued to a new session must contribute nothing:
    poison the whole pool between requests and re-serve — the new
    session overwrites every position below its own frontier, so output
    is unchanged."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=6),
                        max_len=256)
    single = eng.generate(PROMPTS[1])
    sched = ContinuousBatchingScheduler(eng, capacity=1, page_size=16)
    first = sched.submit(PROMPTS[0])
    sched.run()
    assert sched.pool.available == sched.n_pages - 1
    # poison every pool page (they are all free now)
    def poison(leaf):
        return jnp.full_like(leaf, 1e6) if leaf.dtype != jnp.int32 else leaf
    cache = dict(sched.cache)
    cache["head"] = [jax.tree.map(poison, c) for c in cache["head"]]
    cache["tail"] = [jax.tree.map(poison, c) for c in cache["tail"]]
    cache["group"] = {k: jax.tree.map(poison, v)
                      for k, v in cache["group"].items()}
    sched.cache = cache
    second = sched.submit(PROMPTS[1])   # reuses first's freed pages (LIFO)
    sched.run()
    assert second.result.token_ids == single.token_ids
    assert first.result is not None


def test_spec_rollback_shrinks_row_page_count(small_tokenizer):
    """Speculative rejection rewinds the frontier; pages wholly beyond it
    must return to the pool while the session is still resident."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    g = grammars.load("json_gsm8k")
    plain = ServingEngine(m, params, tok, g,
                          EngineConfig(mode="domino", max_tokens=16),
                          max_len=256)
    base = plain.generate_batch(["A: ", "Q: compute 1 + 2\nA: "])
    spec = ServingEngine(m, params, tok, g,
                         EngineConfig(mode="domino", speculative=True,
                                      spec_s=4, spec_threshold=0.4,
                                      max_tokens=16), max_len=256)
    spec.generate("A: ")                # warm the count model
    sched = ContinuousBatchingScheduler(spec, capacity=2, page_size=8)
    shrunk = {"pages": 0}
    orig = sched._shrink_pages

    def spy():
        before = sched._n_pages_row.copy()
        orig()
        live = [i for i, s in enumerate(sched.slots) if s is not None]
        shrunk["pages"] += int((before[live]
                                - sched._n_pages_row[live]).sum())

    sched._shrink_pages = spy
    sessions = [sched.submit(p) for p in ["A: ", "Q: compute 1 + 2\nA: "]]
    sched.run()
    assert shrunk["pages"] > 0          # rollback returned pages mid-flight
    for b0, s1 in zip(base, sessions):
        assert s1.result.token_ids == b0.token_ids
    assert sched.pool.available == sched.n_pages - 1


def test_preemption_under_pool_pressure_is_output_invariant(
        small_tokenizer, json_grammar):
    """Mid-flight exhaustion recompute-preempts the youngest row; the
    victim is re-prefilled (prompt + generated prefix) and completes with
    identical output."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=12),
                        max_len=256)
    singles = [eng.generate(p) for p in PROMPTS]
    sched = ContinuousBatchingScheduler(eng, capacity=4, page_size=8,
                                        n_pages=7)   # 6 usable pages
    sessions = [sched.submit(p) for p in PROMPTS]
    sched.run()
    assert sched.n_preempt > 0
    assert sum(s.result.n_preemptions for s in sessions) == sched.n_preempt
    for single, s in zip(singles, sessions):
        assert s.result.token_ids == single.token_ids
    assert sched.pool.available == sched.n_pages - 1


def test_paged_mla_scheduler_parity(small_tokenizer, json_grammar):
    """MLA latent/rope pools through the paged path (dense fallback and
    fused split-score kernel) match single-request generation."""
    tok = small_tokenizer
    m, params = _build("mla", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", max_tokens=8),
                        max_len=256)
    singles = [eng.generate(p) for p in PROMPTS[:3]]
    sched = ContinuousBatchingScheduler(eng, capacity=2, page_size=16)
    sessions = [sched.submit(p) for p in PROMPTS[:3]]
    sched.run()
    for single, s in zip(singles, sessions):
        assert s.result.token_ids == single.token_ids
    mk, _ = _build("mla", tok.vocab_size, use_pallas_kernels=True)
    engk = ServingEngine(mk, params, tok, json_grammar,
                         EngineConfig(mode="domino", max_tokens=8),
                         max_len=256)
    schedk = ContinuousBatchingScheduler(engk, capacity=2, page_size=16)
    sk = [schedk.submit(p) for p in PROMPTS[:3]]
    schedk.run()
    for single, s in zip(singles, sk):
        assert s.result.token_ids == single.token_ids


# -- satellite: opportunistic adaptive prebuild ------------------------------


def test_opportunistic_adaptive_prebuild(small_tokenizer, json_grammar):
    """Under opportunistic checking the overlapped prebuild is skipped
    for slots whose previous tick did not intervene; outputs and the
    overlap-credit invariant are unchanged, and skipped builds add no
    mask time."""
    tok = small_tokenizer
    m, params = _build("attn", tok.vocab_size)
    eng = ServingEngine(m, params, tok, json_grammar,
                        EngineConfig(mode="domino", opportunistic=True,
                                     max_tokens=10), max_len=256)
    singles = [eng.generate(p) for p in PROMPTS]
    ad = ContinuousBatchingScheduler(eng, capacity=2)
    s_ad = [ad.submit(p) for p in PROMPTS]
    ad.run()
    off = ContinuousBatchingScheduler(eng, capacity=2,
                                      adaptive_prebuild=False)
    s_off = [off.submit(p) for p in PROMPTS]
    off.run()
    for single, a, b in zip(singles, s_ad, s_off):
        assert a.result.token_ids == single.token_ids
        assert b.result.token_ids == single.token_ids
    assert ad.premask_skips > 0         # prebuilds actually skipped
    assert off.premask_skips == 0
    for s in s_ad:                      # accounting stays honest
        assert s.result.mask_overlap_s <= s.result.mask_time_s + 1e-9
    # non-opportunistic serving is unaffected by the adaptive flag
    eng2 = ServingEngine(m, params, tok, json_grammar,
                         EngineConfig(mode="domino", max_tokens=6),
                         max_len=256)
    sched2 = ContinuousBatchingScheduler(eng2, capacity=2)
    [sched2.submit(p) for p in PROMPTS[:2]]
    sched2.run()
    assert sched2.premask_skips == 0
