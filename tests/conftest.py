import random

import pytest

from repro.core import grammars
from repro.core.sampling import GrammarSampler
from repro.tokenizer import train_bpe


@pytest.fixture(scope="session")
def json_grammar():
    return grammars.load("json")


@pytest.fixture(scope="session")
def small_tokenizer(json_grammar):
    """A small BPE tokenizer trained on grammar-sampled text (cached for
    the whole session; training is the slow part)."""
    corpus = GrammarSampler(json_grammar, seed=7).corpus(150)
    corpus += GrammarSampler(grammars.load("c"), seed=3).corpus(60)
    return train_bpe(corpus, vocab_size=420)


@pytest.fixture()
def rng():
    return random.Random(1234)
