"""Run one forward + decode step of EVERY assigned architecture (reduced
configs) — the 10-arch zoo as a selectable `--arch` flag, mirroring
src/repro/launch/{train,serve}.py.

  PYTHONPATH=src python examples/multiarch_smoke.py [--arch zamba2-1.2b]
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ALIASES, get_config  # noqa: E402
from repro.models import build_model  # noqa: E402


def run_arch(arch: str) -> None:
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    params = m.init(rng)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    batch = m.example_batch(2, 16, rng)
    logits, aux = m.train_logits(
        params, {k: (v[:, :-1] if k == "tokens" else v)
                 for k, v in batch.items()})
    cache = m.init_cache(2, 32)
    pre = {k: (v[:, :8] if k == "tokens" else v) for k, v in batch.items()}
    lg, cache = m.prefill(params, pre, cache)
    tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    lg2, cache = m.decode_step(params, cache, tok)
    ok = not np.any(np.isnan(np.asarray(lg2, np.float32)))
    print(f"{arch:24s} [{cfg.family:6s}] {n_params/1e6:6.2f}M params "
          f"logits{tuple(logits.shape)} decode ok={ok} "
          f"({time.perf_counter()-t0:.1f}s)", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ALIASES))
    args = ap.parse_args()
    for arch in ([args.arch] if args.arch else list(ALIASES)):
        run_arch(arch)


if __name__ == "__main__":
    main()
