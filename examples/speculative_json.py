"""Speculative constrained decoding (paper §3.6 / Fig. 5): watch the
count-based grammar-state model learn a JSON schema and cut forward passes.

  PYTHONPATH=src python examples/speculative_json.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.core import grammars  # noqa: E402
from repro.core.sampling import GrammarSampler  # noqa: E402
from repro.core.speculation import CountModel  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import EngineConfig, ServingEngine  # noqa: E402
from repro.tokenizer import train_bpe  # noqa: E402

g = grammars.load("json_gsm8k")               # schema-driven == predictable
corpus = GrammarSampler(grammars.load("json"), seed=1).corpus(150)
corpus += GrammarSampler(g, seed=2).corpus(80)
tok = train_bpe(corpus, vocab_size=450)

cfg = ModelConfig(arch_id="spec", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab_size=tok.vocab_size, dtype="float32",
                  max_seq_len=512)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

count_model = CountModel()
for s in (2, 6, 10):
    eng = ServingEngine(model, params, tok, g,
                        EngineConfig(mode="domino", speculative=True,
                                     spec_s=s, spec_threshold=0.4,
                                     max_tokens=48),
                        count_model=count_model, max_len=512)
    # round 1 forms the prior (paper: 10 warmup reps), round 2 measures
    eng.generate("A: ")
    r = eng.generate("A: ")
    print(f"s={s:2d}: {r.n_tokens} tokens in {r.n_forward_passes} forwards "
          f"(tokens/forward={r.n_tokens/max(1, r.n_forward_passes):.2f}, "
          f"accepted {r.n_spec_accepted}/{r.n_spec_proposed} proposals)")
print(f"count model learned {count_model.n_states()} grammar states")
