"""End-to-end driver (deliverable b): TRAIN a small model on the
arithmetic-JSON task, then SERVE batches of requests under the GSM8K-JSON
schema through the continuous-batching scheduler, reporting accuracy and
speculation gains: the paper's Table 2/3 pipeline in one script.

Uses the per-request constraint API throughout: ONE ``ServingEngine`` (one
KV pool, one grammar registry) serves every constraint mode — each mode is
just a different ``ConstraintSpec``/``DecodeParams`` on the ``Request`` —
and the final section submits a MIXED workload (schema-constrained,
plain-JSON-constrained, and unconstrained rows concurrently in the same
batch).

  PYTHONPATH=src python examples/constrained_serving.py [--steps 200]
"""
import argparse
import pathlib
import random
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.core import grammars  # noqa: E402
from repro.core.sampling import GrammarSampler  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import (ConstraintSpec, DecodeParams,  # noqa: E402
                           Request, ServingEngine)
from repro.tokenizer import train_bpe  # noqa: E402
from repro.training import optimizer as opt  # noqa: E402
from repro.training.data import (TaskDataset, evaluate_answer,  # noqa: E402
                                 few_shot_prefix, make_task_example)
from repro.training.train_loop import make_train_step  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--problems", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching decode slots")
    args = ap.parse_args()

    # ---- substrate: tokenizer + model --------------------------------------
    g = grammars.load("json_gsm8k")
    corpus = GrammarSampler(grammars.load("json"), seed=0).corpus(200)
    corpus += few_shot_prefix(random.Random(0), 40).encode()
    tok = train_bpe(corpus, vocab_size=512)
    cfg = ModelConfig(arch_id="e2e", family="dense", n_layers=2, d_model=128,
                      n_heads=4, n_kv_heads=4, d_ff=256,
                      vocab_size=tok.vocab_size, dtype="float32",
                      max_seq_len=1024)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # ---- train (WSD schedule, per minicpm) ----------------------------------
    step = make_train_step(model, opt.AdamWConfig(
        lr=3e-3, schedule="wsd", warmup_steps=10, total_steps=args.steps))
    state = opt.init_state(params)
    data = TaskDataset(tok, seq_len=192, few_shot=1).batches(8)
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, state, metrics = step(params, state, batch)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"train step {i:4d} loss={float(metrics['loss']):.3f} "
                  f"({time.perf_counter()-t0:.0f}s)", flush=True)

    # ---- ONE engine, a grammar registry, per-request constraints -------------
    eng = ServingEngine(model, params, tok, max_len=1024)
    eng.register_grammar("gsm8k", g)
    eng.register_grammar("json", grammars.load("json"))
    # off the timed path: tree precomputation (Algorithm 2) for EVERY
    # registered grammar, jit compiles, and the count model
    eng.precompute()

    rng = random.Random(4)
    problems = [make_task_example(rng, n_steps=1)
                for _ in range(args.problems)]
    shots = few_shot_prefix(random.Random(5), 2)
    prompts = [shots + ex.prompt for ex in problems]

    def serve(reqs):
        reqs = list(reqs)
        eng.generate_batch(reqs, max_batch=args.slots)   # warm compiles
        t0 = time.perf_counter()
        results = eng.generate_batch(reqs, max_batch=args.slots)
        return results, time.perf_counter() - t0

    # every mode is a per-request policy on the SAME engine / KV pool
    for name, spec, dp in [
        ("unconstrained", ConstraintSpec(), DecodeParams(max_tokens=64)),
        ("naive(k=0)", ConstraintSpec(grammar="gsm8k", mode="naive"),
         DecodeParams(max_tokens=64)),
        ("domino(k=inf)", ConstraintSpec(grammar="gsm8k", mode="domino"),
         DecodeParams(max_tokens=64)),
        ("domino+spec(s=8)", ConstraintSpec(grammar="gsm8k", mode="domino"),
         DecodeParams(max_tokens=64, speculative=True, spec_s=8,
                      spec_threshold=0.4)),
    ]:
        results, wall = serve(Request(p, spec, dp) for p in prompts)
        acc = wf = fwd = toks = 0
        for ex, r in zip(problems, results):
            fwd += r.n_forward_passes
            toks += max(1, r.n_tokens)
            v = evaluate_answer(r.text)
            wf += int(v is not None)
            acc += int(v == ex.answer_value)
        print(f"{name:18s} accuracy={acc}/{len(problems)} "
              f"well-formed={wf}/{len(problems)} "
              f"tokens/forward={toks/fwd:.2f} "
              f"{toks/wall:.1f} tok/s ({args.slots} slots)", flush=True)

    # ---- mixed-grammar workload: one batch, three traffic classes ------------
    mixed_specs = [ConstraintSpec(grammar="gsm8k", mode="domino"),
                   ConstraintSpec(grammar="json", mode="domino"),
                   ConstraintSpec()]
    mixed = [Request(p, mixed_specs[i % len(mixed_specs)],
                     DecodeParams(max_tokens=64))
             for i, p in enumerate(prompts)]
    results, wall = serve(mixed)
    toks = sum(max(1, r.n_tokens) for r in results)
    by_class = {}
    for i, r in enumerate(results):
        key = ["gsm8k", "json", "free"][i % len(mixed_specs)]
        by_class.setdefault(key, []).append(r)
    detail = " ".join(
        f"{k}:{sum(int(evaluate_answer(r.text) is not None) for r in rs)}"
        f"/{len(rs)}-wf" for k, rs in by_class.items())
    print(f"{'mixed batch':18s} {toks/wall:.1f} tok/s "
          f"({args.slots} slots; gsm8k+json+unconstrained rows "
          f"concurrently; {detail})", flush=True)


if __name__ == "__main__":
    main()
