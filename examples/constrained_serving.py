"""End-to-end driver (deliverable b): TRAIN a small model on the
arithmetic-JSON task, then SERVE a batch of requests under the GSM8K-JSON
schema with every constraint mode — concurrently, through the
continuous-batching scheduler (slot reuse + device-side masking) —
reporting accuracy and speculation gains: the paper's Table 2/3 pipeline
in one script.

  PYTHONPATH=src python examples/constrained_serving.py [--steps 200]
"""
import argparse
import pathlib
import random
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.core import grammars  # noqa: E402
from repro.core.sampling import GrammarSampler  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import EngineConfig, ServingEngine  # noqa: E402
from repro.tokenizer import train_bpe  # noqa: E402
from repro.training import optimizer as opt  # noqa: E402
from repro.training.data import (TaskDataset, evaluate_answer,  # noqa: E402
                                 few_shot_prefix, make_task_example)
from repro.training.train_loop import make_train_step  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--problems", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching decode slots")
    args = ap.parse_args()

    # ---- substrate: tokenizer + model --------------------------------------
    g = grammars.load("json_gsm8k")
    corpus = GrammarSampler(grammars.load("json"), seed=0).corpus(200)
    corpus += few_shot_prefix(random.Random(0), 40).encode()
    tok = train_bpe(corpus, vocab_size=512)
    cfg = ModelConfig(arch_id="e2e", family="dense", n_layers=2, d_model=128,
                      n_heads=4, n_kv_heads=4, d_ff=256,
                      vocab_size=tok.vocab_size, dtype="float32",
                      max_seq_len=1024)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # ---- train (WSD schedule, per minicpm) ----------------------------------
    step = make_train_step(model, opt.AdamWConfig(
        lr=3e-3, schedule="wsd", warmup_steps=10, total_steps=args.steps))
    state = opt.init_state(params)
    data = TaskDataset(tok, seq_len=192, few_shot=1).batches(8)
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, state, metrics = step(params, state, batch)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"train step {i:4d} loss={float(metrics['loss']):.3f} "
                  f"({time.perf_counter()-t0:.0f}s)", flush=True)

    # ---- serve the requests concurrently under each mode ---------------------
    # the continuous-batching scheduler keeps --slots decode rows busy:
    # finished requests free their slot and the next prompt is admitted
    rng = random.Random(4)
    problems = [make_task_example(rng, n_steps=1)
                for _ in range(args.problems)]
    shots = few_shot_prefix(random.Random(5), 2)
    for mode, ecfg in [
        ("unconstrained", EngineConfig(mode="unconstrained", max_tokens=64)),
        ("naive(k=0)", EngineConfig(mode="naive", max_tokens=64)),
        ("domino(k=inf)", EngineConfig(mode="domino", max_tokens=64)),
        ("domino+spec(s=8)", EngineConfig(mode="domino", speculative=True,
                                          spec_s=8, spec_threshold=0.4,
                                          max_tokens=64)),
    ]:
        eng = ServingEngine(model, params, tok,
                            None if mode == "unconstrained" else g,
                            ecfg, max_len=1024)
        # off the timed path: tree precomputation (Algorithm 2), jit
        # compiles (admission prefill compiles once per distinct prompt
        # length, so warm on the full prompt set), and the count model
        eng.precompute()
        eng.generate_batch([shots + ex.prompt for ex in problems],
                           max_batch=args.slots)
        t0 = time.perf_counter()
        results = eng.generate_batch(
            [shots + ex.prompt for ex in problems], max_batch=args.slots)
        wall = time.perf_counter() - t0
        acc = wf = fwd = toks = 0
        for ex, r in zip(problems, results):
            fwd += r.n_forward_passes
            toks += max(1, r.n_tokens)
            v = evaluate_answer(r.text)
            wf += int(v is not None)
            acc += int(v == ex.answer_value)
        print(f"{mode:18s} accuracy={acc}/{len(problems)} "
              f"well-formed={wf}/{len(problems)} "
              f"tokens/forward={toks/fwd:.2f} "
              f"{toks/wall:.1f} tok/s ({args.slots} slots)", flush=True)


if __name__ == "__main__":
    main()
