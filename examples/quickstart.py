"""Quickstart: DOMINO constrained decoding in ~40 lines.

Builds a grammar, a byte-level BPE tokenizer, a tiny JAX model, and decodes
JSON under the constraint — showing the mask, opportunistic check, and the
minimally-invasive guarantee.

  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.core import grammars  # noqa: E402
from repro.core.domino import DominoDecoder  # noqa: E402
from repro.core.sampling import GrammarSampler  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving import EngineConfig, ServingEngine  # noqa: E402
from repro.tokenizer import train_bpe  # noqa: E402

# 1. a grammar (App. C JSON) and a tokenizer trained on sampled strings
grammar = grammars.load("json")
corpus = GrammarSampler(grammar, seed=0).corpus(150)
tok = train_bpe(corpus, vocab_size=420)
print(f"tokenizer: {tok.vocab_size} tokens")

# 2. inspect DOMINO masks directly
dec = DominoDecoder(grammar, list(tok.vocab), eos_id=tok.eos_id)
mask = dec.mask()
legal = [tok.vocab[i] for i in np.where(mask)[0][:12]]
print(f"legal first tokens ({int(mask.sum())} total): {legal} ...")
assert dec.check_token(tok.encode("{")[0])          # opportunistic check
assert not dec.check_token(tok.encode("}")[0])

# 3. a tiny model + the serving engine
cfg = ModelConfig(arch_id="quickstart", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  vocab_size=tok.vocab_size, dtype="float32",
                  max_seq_len=512)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = ServingEngine(model, params, tok, grammar,
                       EngineConfig(mode="domino", max_tokens=40),
                       max_len=512)
result = engine.generate("A person encoded as a JSON object: ")
print(f"\nconstrained output ({result.n_tokens} tokens, "
      f"{result.n_interventions} interventions):\n  {result.text!r}")

# 4. the guarantee: every emitted token was grammar-legal
check = DominoDecoder(grammar, list(tok.vocab), eos_id=tok.eos_id)
for t in result.token_ids:
    assert check.advance(t)
print("\nall tokens verified grammar-legal — quickstart OK")
