"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout); progress goes to
stderr-ish bracketed lines.  First run trains the small bench model
(~2 min on CPU) and caches it under artifacts/bench/.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table3     # one section
"""
import sys
import time


def main() -> None:
    from benchmarks import (fig2_template, fig5_speculation, kernel_bench,
                            mask_bench, precompute_cost, serving_bench,
                            table2_invasiveness, table2b_ner,
                            table3_throughput, table4_lookahead)
    sections = {
        "precompute": precompute_cost.run,
        "table2": table2_invasiveness.run,
        "table2b": table2b_ner.run,
        "table3": table3_throughput.run,
        "table4": table4_lookahead.run,
        "fig2": fig2_template.run,
        "fig5": fig5_speculation.run,
        "kernels": kernel_bench.run,
        "mask": mask_bench.run,
        "serving": serving_bench.run,
    }
    want = sys.argv[1:] or list(sections)
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    for name in want:
        fn = sections[name]
        print(f"# === {name} ===", flush=True)
        fn()
    print(f"# done in {time.perf_counter()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
