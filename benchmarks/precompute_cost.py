"""§4.3 — offline precomputation cost per grammar (paper: 1-5 s, C ~20 s
at |V|=32k; ours scales with the in-repo vocab)."""
from __future__ import annotations

import time

from benchmarks.common import emit, get_tokenizer
from repro.core import grammars
from repro.core.scanner import Scanner
from repro.core.trees import TreeCache


def run(verbose: bool = True):
    tok = get_tokenizer()
    out = {}
    for name in ("json", "json_gsm8k", "json_conll", "xml_schema",
                 "template_rpg", "c"):
        g = grammars.load(name)
        tc = TreeCache(Scanner(g), list(tok.vocab))
        t0 = time.perf_counter()
        stats = tc.precompute()
        dt = time.perf_counter() - t0
        sizes = sum(t.root.size() for t in tc.trees.values())
        out[name] = {"seconds": dt, "positions": int(stats["positions"]),
                     "total_tree_nodes": sizes}
        if verbose:
            print(f"  [precompute] {name:14s} {dt:6.2f}s "
                  f"{int(stats['positions'])} positions, "
                  f"{sizes} tree nodes", flush=True)
        emit(f"precompute_{name}", 1e6 * dt,
             f"positions={int(stats['positions'])};nodes={sizes}")
    return out


if __name__ == "__main__":
    run()
