"""§4.3 — offline precomputation cost per grammar (paper: 1-5 s, C ~20 s
at |V|=32k; ours scales with the in-repo vocab), plus the static
analyzer's cost and closure certificate on the same caches (the analyzer
shares the grammar's TreeCache, so its tree work is the precompute)."""
from __future__ import annotations

import time

from benchmarks.common import emit, get_tokenizer
from repro.core import grammars
from repro.core.analysis import analyze
from repro.core.scanner import Scanner
from repro.core.trees import TreeCache


def run(verbose: bool = True):
    tok = get_tokenizer()
    out = {}
    for name in ("json", "json_gsm8k", "json_conll", "xml_schema",
                 "template_rpg", "c"):
        g = grammars.load(name)
        tc = TreeCache(Scanner(g), list(tok.vocab))
        t0 = time.perf_counter()
        stats = tc.precompute()
        dt = time.perf_counter() - t0
        sizes = sum(t.root.size() for t in tc.trees.values())
        rep = analyze(g, list(tok.vocab), tok.eos_id, name=name,
                      tree_cache=tc)
        c = rep.closure
        out[name] = {"seconds": dt, "positions": int(stats["positions"]),
                     "total_tree_nodes": sizes,
                     "analysis_seconds": rep.analysis_time_s,
                     "closure_finite": c.finite,
                     "closure_states": c.n_states,
                     "mask_table_bytes": c.table_bytes}
        if verbose:
            print(f"  [precompute] {name:14s} {dt:6.2f}s "
                  f"{int(stats['positions'])} positions, "
                  f"{sizes} tree nodes", flush=True)
            print(f"  [analyze]    {name:14s} {rep.analysis_time_s:6.2f}s "
                  f"{'finite' if c.finite else 'open  '} "
                  f"{c.n_states} states, mask table {c.table_bytes} B, "
                  f"{'OK' if rep.ok() else 'FAIL'}", flush=True)
        emit(f"precompute_{name}", 1e6 * dt,
             f"positions={int(stats['positions'])};nodes={sizes}")
        emit(f"analyze_{name}", 1e6 * rep.analysis_time_s,
             f"states={c.n_states};finite={int(c.finite)};"
             f"table_bytes={c.table_bytes};ok={int(rep.ok())}")
    return out


if __name__ == "__main__":
    run()
