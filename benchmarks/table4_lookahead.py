"""Table 4 — lookahead parameter k ablation.

Paper result: k=0 (naive) and k=1 hurt accuracy badly because bridge
tokens are missing; k=inf recovers unconstrained accuracy.  We sweep
k ∈ {0, 1, 2, inf} on the arithmetic-JSON task and additionally report
the intervention rate (how often the mask rejected the model's argmax) —
the direct invasiveness measurement.
"""
from __future__ import annotations

import random

from benchmarks.common import emit, get_model_and_params
from repro.core import grammars
from repro.serving import EngineConfig, ServingEngine
from repro.training.data import evaluate_answer, few_shot_prefix, \
    make_task_example

N_PROBLEMS = 20
MAX_TOKENS = 72
KS = [0, 1, 2, None]


def run(verbose: bool = True):
    model, params, tok = get_model_and_params()
    g = grammars.load("json_gsm8k")
    rng = random.Random(77)
    problems = [make_task_example(rng, easy=True) for _ in range(N_PROBLEMS)]
    shots = few_shot_prefix(random.Random(5), 2, easy=True)
    out = {}
    for k in KS:
        eng = ServingEngine(model, params, tok, g,
                            EngineConfig(mode="domino", k=k,
                                         max_tokens=MAX_TOKENS),
                            max_len=1024)
        acc = wf = toks = interventions = 0
        for ex in problems:
            r = eng.generate(shots + ex.prompt)
            toks += max(1, r.n_tokens)
            interventions += r.n_interventions
            val = evaluate_answer(r.text)
            if val is not None:
                wf += 1
                if val == ex.answer_value:
                    acc += 1
        kname = "inf" if k is None else str(k)
        row = {"accuracy": acc / N_PROBLEMS, "well_formed": wf / N_PROBLEMS,
               "interventions_per_100tok": 100 * interventions / toks}
        out[kname] = row
        if verbose:
            print(f"  [table4] k={kname:3s} acc={row['accuracy']:.2f} "
                  f"wf={row['well_formed']:.2f} "
                  f"int/100={row['interventions_per_100tok']:.1f}",
                  flush=True)
        emit(f"table4_k{kname}", 0.0,
             f"acc={row['accuracy']:.3f};"
             f"int100={row['interventions_per_100tok']:.1f}")
    return out


if __name__ == "__main__":
    run()
