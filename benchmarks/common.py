"""Shared benchmark setup: a small model trained on the arithmetic-JSON
task (GSM8K analogue) + grammar-sampled LM data, cached under artifacts/.

All benchmarks run the REAL pipeline end-to-end on CPU; absolute wall
times are CPU times, so each table also reports the hardware-independent
quantities (forward passes per token, mask microseconds per token,
intervention and acceptance rates) that determine the paper's TPU/GPU
speedups.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
ART = ROOT / "artifacts" / "bench"

from repro.configs.base import ModelConfig           # noqa: E402
from repro.core import grammars                      # noqa: E402
from repro.core.sampling import GrammarSampler       # noqa: E402
from repro.models import build_model                 # noqa: E402
from repro.tokenizer import BPETokenizer, train_bpe  # noqa: E402
from repro.training import checkpoint, optimizer as opt  # noqa: E402
from repro.training.data import GrammarLMDataset, TaskDataset  # noqa: E402
from repro.training.train_loop import make_train_step  # noqa: E402

MODEL_CFG = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                 dtype="float32", max_seq_len=1024)
TRAIN_STEPS = 500
SEQ_LEN = 192
BATCH = 8


def get_tokenizer() -> BPETokenizer:
    ART.mkdir(parents=True, exist_ok=True)
    path = ART / "tokenizer.json"
    if path.exists():
        return BPETokenizer.load(path)
    corpus = b""
    for name in ("json", "json_gsm8k", "c", "xml_schema"):
        corpus += GrammarSampler(grammars.load(name), seed=13).corpus(250)
        corpus += b"\n"
    # plus task-formatted text so the tokenizer sees prompts
    import random

    from repro.training.data import few_shot_prefix
    corpus += few_shot_prefix(random.Random(0), 60, easy=True).encode()
    tok = train_bpe(corpus, vocab_size=600)
    tok.save(path)
    return tok


def get_model_and_params(retrain: bool = False):
    tok = get_tokenizer()
    cfg = ModelConfig(arch_id="bench-2l", family="dense",
                      vocab_size=tok.vocab_size, **MODEL_CFG)
    model = build_model(cfg)
    ck = ART / "model"
    if (ck / "params.npz").exists() and not retrain:
        params, _, _ = checkpoint.load(
            ck, jax.eval_shape(model.init, jax.random.PRNGKey(0)))
        params = jax.tree.map(jnp.asarray, params)
        return model, params, tok
    params = model.init(jax.random.PRNGKey(0))
    step = make_train_step(model, opt.AdamWConfig(
        lr=3e-3, schedule="wsd", warmup_steps=10, total_steps=TRAIN_STEPS))
    state = opt.init_state(params)
    task = TaskDataset(tok, seq_len=SEQ_LEN, few_shot=1, easy=True).batches(BATCH)
    lm = GrammarLMDataset(tok, "json", seq_len=SEQ_LEN).batches(BATCH)
    t0 = time.perf_counter()
    for i in range(TRAIN_STEPS):
        src = task if i % 3 else lm     # 2/3 task, 1/3 free-form JSON
        batch = {k: jnp.asarray(v) for k, v in next(src).items()}
        params, state, metrics = step(params, state, batch)
        if i % 40 == 0:
            print(f"  [bench-train] step {i} loss={float(metrics['loss']):.3f}"
                  f" ({time.perf_counter()-t0:.0f}s)", file=sys.stderr)
    checkpoint.save(ck, params, meta={"steps": TRAIN_STEPS})
    return model, params, tok


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
