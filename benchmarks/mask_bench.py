"""Mask-pipeline micro-benchmarks (ISSUE 4): packed bitsets end to end.

Three sections, written to ``BENCH_mask.json`` (the CI perf-trajectory
artifact, alongside ``BENCH_decode.json``):

 - **build**: per-step full-mask assembly over real DOMINO states (every
   step of grammar-sampled JSON generations) three ways — the pre-bitset
   scatter walk (`mask_dense`, bool out + per-token fancy-index writes),
   the bitset-OR walk (`mask_bits` on a cold memo), and a state-keyed
   memo hit.  Asserts the memo-hit path is measurably faster than both.
 - **bytes**: host->device mask traffic per scheduler tick — the old
   dense (capacity, V) int8 staging array vs the persistent packed
   (capacity, ceil(V/32)) uint32 buffer, at the bench vocab and at real
   vocab sizes (gemma3 V=262144: 256 KiB -> 32 KiB per row).  Asserts
   the >=8x reduction the tentpole claims.
 - **parity**: the packed kernel is bitwise-identical to the int8-mask
   kernel and the jnp oracle on masks taken from the real decoder
   states (plus empty/single-bit rows), including an odd-V tail tile.

Pure host + interpret-mode work: no model, no training, fast enough for
a CI smoke step.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import bitmask, grammars
from repro.core.domino import DominoDecoder
from repro.core.sampling import GrammarSampler
from repro.kernels.masked_sample.kernel import (masked_argmax_pallas,
                                                masked_argmax_pallas_packed)
from repro.kernels.masked_sample.ref import masked_argmax_ref
from repro.tokenizer import train_bpe

VOCAB_SIZE = 512                 # word-aligned: the exact 8x wire ratio
N_SAMPLES = 12
REAL_VOCABS = {"stablelm": 50304, "yi": 64000, "gemma3": 262144}


def _setup():
    g = grammars.load("json")
    sampler = GrammarSampler(g, seed=11)
    corpus = sampler.corpus(200)
    tok = train_bpe(corpus, vocab_size=VOCAB_SIZE)
    texts = []
    for _ in range(N_SAMPLES):
        t = sampler.sample()
        texts.append(t.decode() if isinstance(t, bytes) else t)
    return g, tok, texts


def _walk_states(g, tok, texts):
    """One decoder per text; yields the decoder at every step state."""
    from repro.core.scanner import Scanner
    from repro.core.trees import TreeCache
    cache = TreeCache(Scanner(g), list(tok.vocab))
    cache.precompute()
    for text in texts:
        dec = DominoDecoder(g, list(tok.vocab), tok.eos_id,
                            tree_cache=cache)
        yield dec
        for t in tok.encode(text):
            if not dec.advance(t):
                break
            yield dec


def run_build(g, tok, texts, verbose: bool = True):
    t_scatter = t_bitset = t_memo = 0.0
    n = 0
    masks = []
    for dec in _walk_states(g, tok, texts):
        memo = dec.trees.mask_memo
        t0 = time.perf_counter()
        dense = dec.mask_dense()
        t_scatter += time.perf_counter() - t0
        memo.clear()                       # force a cold bitset-OR build
        t0 = time.perf_counter()
        bits = dec.mask_bits()
        t_bitset += time.perf_counter() - t0
        t0 = time.perf_counter()
        bits2 = dec.mask_bits()            # state-keyed memo hit
        t_memo += time.perf_counter() - t0
        assert bits2 is bits
        assert (bitmask.unpack(bits, len(tok.vocab)) == dense).all()
        if len(masks) < 64:
            masks.append(np.asarray(bits))
        n += 1
    us = {"scatter_us": 1e6 * t_scatter / n,
          "bitset_or_us": 1e6 * t_bitset / n,
          "memo_hit_us": 1e6 * t_memo / n}
    out = dict(us, steps=n,
               speedup_bitset=us["scatter_us"] / us["bitset_or_us"],
               speedup_memo=us["scatter_us"] / us["memo_hit_us"])
    # the acceptance bar: memo hits must beat a fresh walk of either kind
    assert out["speedup_memo"] > 1.0, out
    assert us["memo_hit_us"] < us["bitset_or_us"], out
    if verbose:
        print(f"  [mask] build ({n} real JSON states): "
              f"scatter {us['scatter_us']:.0f}us, "
              f"bitset-OR {us['bitset_or_us']:.0f}us, "
              f"memo hit {us['memo_hit_us']:.1f}us "
              f"({out['speedup_memo']:.0f}x vs scatter)", flush=True)
    emit("mask_build_scatter", us["scatter_us"], f"steps={n}")
    emit("mask_build_bitset_or", us["bitset_or_us"],
         f"speedup={out['speedup_bitset']:.2f}")
    emit("mask_build_memo_hit", us["memo_hit_us"],
         f"speedup={out['speedup_memo']:.2f}")
    return out, masks


def run_bytes(verbose: bool = True):
    """Per-tick host->device mask traffic, dense int8 vs packed uint32."""
    cap = 8
    out = {}
    for name, v in dict(bench=VOCAB_SIZE, **REAL_VOCABS).items():
        dense = cap * v                              # int8 staging array
        packed = cap * bitmask.n_words(v) * 4        # uint32 rows
        out[name] = {"v": v, "dense_bytes": dense, "packed_bytes": packed,
                     "ratio": dense / packed}
        if verbose:
            print(f"  [mask] bytes/tick {name} V={v} cap={cap}: "
                  f"{dense/1024:.0f}KiB -> {packed/1024:.1f}KiB "
                  f"({dense/packed:.2f}x fewer)", flush=True)
        emit(f"mask_bytes_{name}", packed, f"dense={dense};"
             f"ratio={dense/packed:.3f}")
    # tentpole acceptance: >=8x on word-aligned vocabularies
    assert out["bench"]["ratio"] >= 8.0
    assert out["gemma3"]["ratio"] >= 8.0
    return out


def run_parity(masks, verbose: bool = True):
    """Packed kernel == int8 kernel == oracle on real grammar masks."""
    rng = np.random.default_rng(3)
    out = {}
    for case, v, bv in (("even", VOCAB_SIZE, 128), ("odd_tail", 420, 128)):
        rows = [m if v == VOCAB_SIZE else
                bitmask.pack_bool(bitmask.unpack(m, VOCAB_SIZE)[:v])
                for m in masks[:6]]
        bools = np.stack([bitmask.unpack(r, v) for r in rows])
        bools = np.concatenate([bools, np.zeros((1, v), bool)])  # empty row
        single = np.zeros((1, v), bool)
        single[0, v - 1] = True                      # last-token bit
        bools = np.concatenate([bools, single])
        b = bools.shape[0]
        logits = jnp.asarray(rng.normal(size=(b, v)).astype(np.float32))
        i8 = jnp.asarray(bools.astype(np.int8))
        pk = jnp.asarray(bitmask.pack_bool(bools))
        t0 = time.perf_counter()
        ii, vi = masked_argmax_pallas(logits, i8, block_v=bv)
        ip, vp = masked_argmax_pallas_packed(logits, pk, block_v=bv)
        ir, _ = masked_argmax_ref(logits, i8)
        dt = time.perf_counter() - t0
        exact = bool((np.asarray(ii) == np.asarray(ip)).all()
                     and (np.asarray(vi) == np.asarray(vp)).all()
                     and (np.asarray(ii) == np.asarray(ir)).all())
        assert exact, f"packed/int8/oracle disagree on {case}"
        out[case] = {"b": b, "v": v, "block_v": bv, "bitwise_identical":
                     exact, "wall_us": 1e6 * dt}
        if verbose:
            print(f"  [mask] kernel parity {case} (B={b} V={v}): "
                  f"packed == int8 == oracle", flush=True)
        emit(f"mask_kernel_parity_{case}", 1e6 * dt, f"identical={exact}")
    return out


def run(verbose: bool = True, json_path: str = "BENCH_mask.json"):
    g, tok, texts = _setup()
    build, masks = run_build(g, tok, texts, verbose=verbose)
    record = {
        "config": {"vocab_size": VOCAB_SIZE, "n_samples": N_SAMPLES,
                   "grammar": "json"},
        "build": build,
        "bytes_per_tick": run_bytes(verbose=verbose),
        "kernel_parity": run_parity(masks, verbose=verbose),
    }
    pathlib.Path(json_path).write_text(json.dumps(record, indent=2))
    if verbose:
        print(f"  [mask] wrote {json_path}", flush=True)
    return record


if __name__ == "__main__":
    run()
