"""Sustained-throughput serving benchmark (ISSUE 7) -> BENCH_serving.json.

Drives the continuous-batching scheduler the way a deployment would:
Poisson arrivals over mixed traffic (json grammar, c grammar, and
unconstrained rows in one batch), more requests than slots, paged KV,
tick-boundary invariants audited throughout.  Two passes:

 - **fault_free**: the baseline trajectory — sustained tok/s and p50/p99
   request latency (submission -> terminal status, queue wait included).
 - **faulted**: the same workload under a seeded ~5%-rate fault storm
   (device-step NaNs, checker/mask failures, injected pool exhaustion).
   Reports the same metrics plus the terminal-status mix, so the cost of
   graceful degradation is a number, not a hope.

Assertions are the acceptance bars: the fault-free pass completes every
request `ok`, and BOTH passes drain without leaking a single page.

ISSUE 9 extends the workload and the rows.  The main passes drive a
deterministic **traffic trace** — Poisson arrival offsets plus
Zipf-distributed prompt lengths (most prompts short, a heavy tail of
long ones), all derived from one seed — and the **traffic-replay mode**
re-drives the identical trace and asserts every request's token ids
are bitwise-equal across runs (``--replay`` runs just that check).  A
**faulted-and-recovered** pass crashes a journaled device-loop run
mid-decode under a ``device_timeout`` storm, restores it from the
journal, and reports MTTR and replayed-token counts as a tracked
history row.

This file seeds the ROADMAP's perf-trajectory artifact for the serving
layer: CI uploads ``BENCH_serving.json`` next to ``BENCH_mask.json`` /
``BENCH_decode.json`` so tok/s and tail latency get a tracked history.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.core import grammars
from repro.core.sampling import GrammarSampler
from repro.models import build_model
from repro.serving import (ConstraintSpec, ContinuousBatchingScheduler,
                           DecodeParams, DegradationSupervisor,
                           EngineConfig, FaultInjector, Request,
                           ServingEngine, TokenJournal)
from repro.tokenizer import train_bpe

N_REQUESTS = 24
CAPACITY = 4
MAX_TOKENS = 24
ARRIVAL_RATE_HZ = 40.0           # Poisson arrival intensity
TRACE_SEED = 42                  # one seed -> the whole traffic trace
ZIPF_A = 1.4                     # prompt-length Zipf exponent
ZIPF_CAP = 40                    # prompt length cap in characters
# rates are PER CONSULTATION (every mask build / device row / admission
# draws once), so per-request failure odds compound over ~MAX_TOKENS
# ticks; these values land the storm at roughly a 5%-per-request-phase
# fault intensity rather than killing the whole batch
FAULT_RATES = {"mask_error": 0.005, "decode_nan": 0.005,
               "advance_error": 0.005, "prefill_nan": 0.01,
               "page_exhaustion": 0.05}
MODEL = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
             dtype="float32", max_seq_len=512)

PROMPTS = ["a: ", "record: ", "x = ", "{", "fn: ", "data -> "]

# device-loop vs host-loop comparison (ISSUE 8): certified-JSON-only
# workload on a byte-complete vocabulary (the json grammar certifies
# CLEAN there, so the engine uploads a device table), greedy rows —
# exactly the population the fused loop accelerates
SYNC_N = 8
DEV_N_REQUESTS = 16
DEV_MAX_TOKENS = 32
HISTORY_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_history.jsonl"


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=pathlib.Path(__file__).resolve().parent)
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _setup() -> ServingEngine:
    gj, gc = grammars.load("json"), grammars.load("c")
    corpus = (GrammarSampler(gj, seed=5).corpus(150)
              + GrammarSampler(gc, seed=6).corpus(150))
    tok = train_bpe(corpus, vocab_size=420)
    cfg = ModelConfig(arch_id="serve-bench", family="dense",
                      vocab_size=tok.vocab_size, **MODEL)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, tok, max_len=256)
    eng.register_grammar("json", gj)
    eng.register_grammar("c", gc)
    eng.precompute()               # trees off the serving critical path
    return eng


def _make_trace(seed: int = TRACE_SEED):
    """Deterministic traffic trace: Poisson arrival offsets, Zipf prompt
    lengths (most prompts short, a heavy tail of long ones — the shape
    real traffic has), and a cycling grammar mix, all derived from one
    seed so the identical trace can be replayed bit-for-bit."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / ARRIVAL_RATE_HZ,
                                         N_REQUESTS))
    lens = np.minimum(rng.zipf(ZIPF_A, size=N_REQUESTS), ZIPF_CAP)
    specs = [ConstraintSpec(grammar="json", mode="domino"),
             ConstraintSpec(grammar="c", mode="domino"),
             ConstraintSpec()]    # unconstrained rows ride along
    reqs = []
    for i in range(N_REQUESTS):
        prompt = (f"req {i}: " + "key value " * ZIPF_CAP)[:int(lens[i])]
        reqs.append(Request(prompt, specs[i % len(specs)],
                            DecodeParams(max_tokens=MAX_TOKENS, seed=i)))
    return arrivals, reqs


def _drive(eng: ServingEngine, injector=None, label="fault_free",
           trace=None, verbose=True):
    """One serving pass: Poisson arrivals submitted by wall clock into a
    stepping scheduler; returns the metric record."""
    arrivals, reqs = trace if trace is not None else _make_trace()
    sched = ContinuousBatchingScheduler(eng, capacity=CAPACITY,
                                        page_size=32,
                                        fault_injector=injector,
                                        debug_invariants=True)
    sessions = []
    next_i = 0
    t0 = time.perf_counter()
    while next_i < len(reqs) or sched.waiting \
            or any(s is not None for s in sched.slots):
        now = time.perf_counter() - t0
        while next_i < len(reqs) and arrivals[next_i] <= now:
            sessions.append(sched.submit(reqs[next_i]))
            next_i += 1
        if not sched.waiting and all(s is None for s in sched.slots):
            time.sleep(min(1e-3, max(0.0, arrivals[next_i] - now)))
            continue
        sched.step()                 # invariants audited every tick
    wall = time.perf_counter() - t0

    lat = np.array([s.result.wall_time_s for s in sessions])
    n_tok = sum(s.result.n_tokens for s in sessions)
    plens = np.array([len(r.prompt) for r in reqs])
    statuses = dict(sched.status_counts)
    rec = {
        "prompt_chars_p50": float(np.percentile(plens, 50)),
        "prompt_chars_max": int(plens.max()),
        "_token_ids": [s.result.token_ids for s in sessions],
        "wall_s": wall,
        "n_requests": len(sessions),
        "n_tokens": n_tok,
        "tok_per_s": n_tok / wall,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "n_forward_passes": sched.n_fwd,
        "n_preemptions": sched.n_preempt,
        "n_host_syncs": sched.n_host_syncs,
        "host_syncs_per_token": sched.n_host_syncs / max(n_tok, 1),
        "statuses": statuses,
        "n_faults_fired": 0 if injector is None else injector.n_fired(),
        "fault_sites": {} if injector is None else {
            site: injector.n_fired(site) for site in FAULT_RATES},
    }
    # acceptance bars, not just reporting
    assert len(sessions) == N_REQUESTS
    assert sched.pool.available == sched.n_pages - 1, "page leak"
    assert all(s is None for s in sched.slots), "slot leak"
    if injector is None:
        assert statuses == {"ok": N_REQUESTS}, statuses
    else:
        assert sum(statuses.values()) == N_REQUESTS, statuses
    if verbose:
        print(f"  [serving/{label}] {n_tok} tok in {wall:.2f}s "
              f"({rec['tok_per_s']:.1f} tok/s), "
              f"p50={rec['latency_p50_s'] * 1e3:.0f}ms "
              f"p99={rec['latency_p99_s'] * 1e3:.0f}ms, "
              f"statuses={statuses}", flush=True)
    emit(f"serving_{label}_tok_per_s", 1e6 / max(rec["tok_per_s"], 1e-9),
         f"{rec['tok_per_s']:.1f} tok/s")
    return rec


def _setup_certified() -> ServingEngine:
    """Byte-vocab engine whose json grammar certifies CLEAN, so
    ``device_tables=True`` actually uploads a table."""
    g = grammars.load("json")
    corpus = GrammarSampler(g, seed=5).corpus(80)
    tok = train_bpe(corpus, vocab_size=257)
    cfg = ModelConfig(arch_id="serve-bench-dev", family="dense",
                      vocab_size=tok.vocab_size, **MODEL)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, tok, g,
                        EngineConfig(mode="domino",
                                     max_tokens=DEV_MAX_TOKENS),
                        max_len=256, device_tables=True)
    eng.register_grammar("json", g)
    eng.precompute()
    assert "json" in eng.device_tables, \
        "json failed to certify on the byte vocabulary"
    return eng


def _drive_loop(eng: ServingEngine, device_loop: bool, label: str,
                verbose=True):
    """One drain pass of the certified-JSON workload.  Warm requests run
    through the SAME scheduler first (the fused loop compiles per
    scheduler instance), then counters reset and the measured batch is
    submitted up front — a sustained-throughput drain, no arrival
    process to hide the per-token host syncs behind."""
    sched = ContinuousBatchingScheduler(eng, capacity=CAPACITY,
                                        page_size=32,
                                        device_loop=device_loop,
                                        sync_n=SYNC_N,
                                        debug_invariants=True)
    for p in PROMPTS[:CAPACITY]:
        sched.submit(Request(p, ConstraintSpec(grammar="json",
                                               mode="domino"),
                             DecodeParams(max_tokens=SYNC_N + 2)))
    sched.run()                               # compile warm-up
    sched.n_host_syncs = sched.n_device_tokens = sched.n_fwd = 0
    sessions = [sched.submit(
        Request(PROMPTS[i % len(PROMPTS)],
                ConstraintSpec(grammar="json", mode="domino"),
                DecodeParams(max_tokens=DEV_MAX_TOKENS, seed=i)))
        for i in range(DEV_N_REQUESTS)]
    t0 = time.perf_counter()
    results = sched.run()
    wall = time.perf_counter() - t0
    lat = np.array([s.result.wall_time_s for s in sessions])
    n_tok = sum(r.n_tokens for r in results)
    assert all(r.status == "ok" for r in results), \
        {r.status for r in results}
    rec = {
        "label": label,
        "wall_s": wall,
        "n_requests": len(sessions),
        "n_tokens": n_tok,
        "tok_per_s": n_tok / wall,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "n_host_syncs": sched.n_host_syncs,
        "host_syncs_per_token": sched.n_host_syncs / max(n_tok, 1),
        "n_device_tokens": sched.n_device_tokens,
        "n_quotient_escapes": sched.n_quotient_escapes,
        "n_table_rejects": sched.n_table_rejects,
    }
    if verbose:
        print(f"  [serving/{label}] {n_tok} tok in {wall:.2f}s "
              f"({rec['tok_per_s']:.1f} tok/s), "
              f"syncs/tok={rec['host_syncs_per_token']:.3f}, "
              f"device_tokens={sched.n_device_tokens}", flush=True)
    emit(f"serving_{label}_tok_per_s", 1e6 / max(rec["tok_per_s"], 1e-9),
         f"{rec['tok_per_s']:.1f} tok/s")
    return rec


# radix prefix cache (ISSUE 10): Zipf-shared-preamble traffic — the
# shape structured-output deployments actually have (few long system
# prompts, many short user suffixes) — cold vs warm at the SAME page
# pool size, so the tok/s delta is prefill compute the cache skipped,
# not extra HBM
PFX_N_REQUESTS = 16
PFX_MAX_TOKENS = 4               # prefill-dominated: the cache's target
PFX_PAGE_SIZE = 16
PFX_N_PAGES = 160
PFX_REPS = 3                     # interleaved min-of-N timing
PFX_PREAMBLES = [
    "You are a strict data formatter; always answer with one value and "
    "nothing else. The schema below is authoritative and versioned. " * 2,
    "System: the following conversation extracts configuration records "
    "from logs; keep keys stable across turns and quote every string. ",
    "Common few-shot preamble: {\"a\": 1} {\"b\": [2, 3]} now continue "
    "in exactly the same style for the next record. ",
]


def _prefix_trace():
    """Zipf-weighted choice over a few long preambles + a unique short
    suffix per request: most requests share the hottest preamble."""
    rng = np.random.default_rng(TRACE_SEED)
    picks = np.minimum(rng.zipf(ZIPF_A, size=PFX_N_REQUESTS),
                       len(PFX_PREAMBLES)) - 1
    specs = [ConstraintSpec(grammar="json", mode="domino"),
             ConstraintSpec(grammar="c", mode="domino"),
             ConstraintSpec()]
    return [Request(PFX_PREAMBLES[picks[i]] + f"q{i}: ",
                    specs[i % len(specs)],
                    DecodeParams(max_tokens=PFX_MAX_TOKENS, seed=i))
            for i in range(PFX_N_REQUESTS)]


def _memo_hits(eng: ServingEngine) -> int:
    return sum(tc.n_memo_hits for _, tc in eng.registry.values()
               if tc is not None)


def _shareable_tokens(sessions) -> int:
    """Upper bound the cache can skip: per request, the longest common
    token prefix with ANY earlier request, floored to whole pages."""
    ids = [s.prompt_ids for s in sessions]
    total = 0
    for i in range(1, len(ids)):
        best = 0
        for j in range(i):
            n = 0
            for a, b in zip(ids[i], ids[j]):
                if a != b:
                    break
                n += 1
            best = max(best, n)
        total += (best // PFX_PAGE_SIZE) * PFX_PAGE_SIZE
    return total


def _drive_prefix(eng: ServingEngine, verbose=True):
    """Cold vs warm prefix-cache pass over the identical Zipf trace at an
    equal HBM budget (same pool).  Acceptance: bitwise-identical token
    ids, >= 90% of shareable prefill tokens skipped, and a tok/s gain."""

    def one(prefix_cache: bool, timed: bool):
        sched = ContinuousBatchingScheduler(
            eng, capacity=CAPACITY, page_size=PFX_PAGE_SIZE,
            n_pages=PFX_N_PAGES, prefix_cache=prefix_cache,
            debug_invariants=True)
        sessions = [sched.submit(r) for r in _prefix_trace()]
        t0 = time.perf_counter()
        sched.run()
        wall = time.perf_counter() - t0
        return sched, sessions, wall

    one(False, timed=False)            # compile the PFX-shape cold
    one(True, timed=False)             # buckets and the cached tails
    _, cold_sess, cold_wall = one(False, timed=True)
    memo0 = _memo_hits(eng)
    warm_sched, warm_sess, warm_wall = one(True, timed=True)
    mask_builds_skipped = _memo_hits(eng) - memo0
    # interleaved min-of-N per mode: wall-clock noise, not prefill
    # compute, is the only thing further repetitions can change
    for _ in range(PFX_REPS - 1):
        cold_wall = min(cold_wall, one(False, timed=True)[2])
        warm_wall = min(warm_wall, one(True, timed=True)[2])

    for c, w in zip(cold_sess, warm_sess):
        assert w.result.token_ids == c.result.token_ids, \
            f"prefix cache changed rid {c.rid} output"
        assert w.result.status == c.result.status == "ok"
    shareable = _shareable_tokens(cold_sess)
    skipped = warm_sched.n_prefix_tokens
    assert skipped >= 0.9 * shareable, \
        f"skipped {skipped} of {shareable} shareable prefill tokens"
    # leak-free drain at both ends of the cache's lifetime
    held = warm_sched.prefix_cache.n_pages
    assert warm_sched.pool.available == PFX_N_PAGES - 1 - held
    warm_sched.prefix_cache.reset()
    assert warm_sched.pool.available == PFX_N_PAGES - 1, "page leak"

    n_tok = sum(s.result.n_tokens for s in warm_sess)
    cold_tok_s = n_tok / cold_wall
    warm_tok_s = n_tok / warm_wall
    speedup = warm_tok_s / cold_tok_s
    assert speedup > 1.0, \
        f"warm pass not faster: {warm_tok_s:.1f} vs {cold_tok_s:.1f} tok/s"
    rec = {
        "label": "prefix_zipf",
        "n_requests": PFX_N_REQUESTS,
        "n_tokens": n_tok,
        "tok_per_s": warm_tok_s,
        "cold_tok_per_s": cold_tok_s,
        "prefix_speedup": speedup,
        "prefix_hit_rate":
            warm_sched.n_prefix_hits / PFX_N_REQUESTS,
        "prefill_tokens_skipped": skipped,
        "shareable_tokens": shareable,
        "mask_builds_skipped": mask_builds_skipped,
        "n_evicted": warm_sched.stats()["prefix_n_evicted"],
    }
    if verbose:
        print(f"  [serving/prefix_zipf] {skipped}/{shareable} shareable "
              f"prefill tokens skipped "
              f"({warm_sched.n_prefix_hits}/{PFX_N_REQUESTS} hits), "
              f"{warm_tok_s:.1f} vs {cold_tok_s:.1f} tok/s cold "
              f"({speedup:.2f}x)", flush=True)
    emit("serving_prefix_zipf_tok_per_s", 1e6 / max(warm_tok_s, 1e-9),
         f"{warm_tok_s:.1f} tok/s")
    return rec


class _Crash(Exception):
    """In-process stand-in for SIGKILL in the recovery drill."""


def _drive_recovery(eng: ServingEngine, label="faulted_recovered",
                    verbose=True):
    """Crash + storm recovery drill (ISSUE 9): the certified device-loop
    workload runs with the crash-consistent journal armed while a seeded
    ``device_timeout`` storm walks the degradation ladder down and back;
    the journal's crash hook then kills the run mid-decode after its
    6th fsync, mid-decode.  ``engine.restore`` replays the journal and finishes the
    workload — the row records MTTR (ladder round trip) and how many
    acknowledged tokens were replayed rather than re-decoded."""
    fd, path = tempfile.mkstemp(prefix="bench_recovery_",
                                suffix=".journal")
    os.close(fd)
    os.unlink(path)

    def _boom() -> None:
        raise _Crash

    try:
        journal = TokenJournal(path, crash_after_syncs=6,
                               crash_hook=_boom)
        inj = FaultInjector(seed=3, rates={"device_timeout": 1.0},
                            max_faults=2)
        sup = DegradationSupervisor(max_retries=1, backoff_s=0.0,
                                    recover_after=1)
        sched = ContinuousBatchingScheduler(
            eng, capacity=CAPACITY, page_size=32, device_loop=True,
            sync_n=SYNC_N, journal=journal, fault_injector=inj,
            supervisor=sup, debug_invariants=True)
        for i in range(DEV_N_REQUESTS // 2):
            sched.submit(Request(
                PROMPTS[i % len(PROMPTS)],
                ConstraintSpec(grammar="json", mode="domino"),
                DecodeParams(max_tokens=DEV_MAX_TOKENS, seed=i)))
        t0 = time.perf_counter()
        try:
            sched.run()
            raise AssertionError("recovery drill never crashed — "
                                 "workload too small for 6 syncs")
        except _Crash:
            pass
        journal.dead = True          # freeze the file, as SIGKILL would
        mttr = sched.sup.mttr_s
        assert inj.n_fired("device_timeout") > 0
        assert sched.sup.n_degrades >= 1
        assert mttr is not None, "storm never completed a ladder round trip"

        restored = eng.restore(path, max_batch=CAPACITY,
                               device_loop=True, sync_n=SYNC_N)
        results = restored.run()
        wall = time.perf_counter() - t0
        stats = restored.stats()
        assert all(r.status == "ok" for r in results), \
            {r.status for r in results}
        assert stats["n_replayed_tokens"] > 0, \
            "restore replayed nothing despite a mid-decode crash"
        n_tok = sum(r.n_tokens for r in results)
        rec = {
            "label": label,
            "wall_s": wall,
            "n_requests": len(results),
            "n_tokens": n_tok,
            "tok_per_s": n_tok / wall,
            "mttr_s": mttr,
            "n_replayed_tokens": stats["n_replayed_tokens"],
            "n_degrades": sched.sup.n_degrades,
            "n_recovers": sched.sup.n_recovers,
            "journal_syncs": stats["journal_syncs"],
        }
        if verbose:
            print(f"  [serving/{label}] crash after 6 syncs -> restore: "
                  f"{rec['n_replayed_tokens']} tokens replayed, "
                  f"mttr={mttr * 1e3:.1f}ms, {n_tok} tok total",
                  flush=True)
        return rec
    finally:
        if os.path.exists(path):
            os.unlink(path)


def _replay_check(eng: ServingEngine, baseline, verbose=True):
    """Traffic-replay mode: re-drive the IDENTICAL trace and assert every
    request's token ids are bitwise-equal to the first pass — per-row
    determinism must hold regardless of wall-clock batching jitter."""
    replay = _drive(eng, injector=None, label="traffic_replay",
                    trace=_make_trace(), verbose=verbose)
    mismatches = [i for i, (a, b) in enumerate(
        zip(baseline["_token_ids"], replay["_token_ids"])) if a != b]
    assert not mismatches, \
        f"traffic replay diverged on requests {mismatches}"
    if verbose:
        print(f"  [serving/traffic_replay] {len(replay['_token_ids'])} "
              f"request(s) bitwise-identical across replays", flush=True)
    return replay


def _append_history(rows, path=HISTORY_PATH):
    """Append per-PR benchmark rows to the tracked JSONL history — one
    line per (commit, label), so the perf trajectory across PRs is a
    diffable artifact, not a dashboard."""
    sha = _git_sha()
    ts = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    keep = ("label", "tok_per_s", "latency_p50_s", "latency_p99_s",
            "host_syncs_per_token", "n_tokens", "n_device_tokens",
            "n_quotient_escapes", "n_table_rejects", "mttr_s",
            "n_replayed_tokens", "n_degrades", "n_recovers",
            "prompt_chars_p50", "prompt_chars_max",
            "cold_tok_per_s", "prefix_speedup", "prefix_hit_rate",
            "prefill_tokens_skipped", "mask_builds_skipped")
    with open(path, "a") as f:
        for row in rows:
            slim = {k: row[k] for k in keep if k in row}
            f.write(json.dumps({"git_sha": sha, "ts": ts, **slim},
                               sort_keys=True) + "\n")


def run(verbose: bool = True, json_path: str = "BENCH_serving.json"):
    eng = _setup()
    # warm compile out of the measured window: one small batch end to end
    warm = ContinuousBatchingScheduler(eng, capacity=CAPACITY,
                                       page_size=32)
    for p in PROMPTS[:CAPACITY]:
        warm.submit(Request(p, ConstraintSpec(grammar="json",
                                              mode="domino"),
                            DecodeParams(max_tokens=4)))
    warm.run()

    fault_free = _drive(eng, injector=None, label="fault_free",
                        trace=_make_trace(), verbose=verbose)
    # traffic-replay mode: the identical trace again, bitwise-compared
    _replay_check(eng, fault_free, verbose=verbose)
    injector = FaultInjector(seed=0, rates=FAULT_RATES, max_faults=30)
    faulted = _drive(eng, injector=injector, label="faulted",
                     trace=_make_trace(), verbose=verbose)
    fault_free.pop("_token_ids")
    faulted.pop("_token_ids")

    # radix prefix cache over Zipf-shared preambles (ISSUE 10)
    prefix_zipf = _drive_prefix(eng, verbose=verbose)

    # device-resident fused loop vs per-token host loop (ISSUE 8)
    eng_dev = _setup_certified()
    host_loop = _drive_loop(eng_dev, device_loop=False, label="host_loop",
                            verbose=verbose)
    device_loop = _drive_loop(eng_dev, device_loop=True,
                              label="device_loop", verbose=verbose)
    # crash + storm + restore drill (ISSUE 9): MTTR and replayed tokens
    recovered = _drive_recovery(eng_dev, verbose=verbose)
    speedup = device_loop["tok_per_s"] / host_loop["tok_per_s"]
    # acceptance bars: sustained speedup AND the sync economy it rests on
    assert speedup >= 1.5, \
        f"device loop speedup {speedup:.2f}x < 1.5x"
    assert device_loop["host_syncs_per_token"] <= 1 / SYNC_N + 0.05, \
        device_loop["host_syncs_per_token"]
    if verbose:
        print(f"  [serving] device-loop speedup {speedup:.2f}x",
              flush=True)

    record = {
        "config": {"n_requests": N_REQUESTS, "capacity": CAPACITY,
                   "max_tokens": MAX_TOKENS,
                   "arrival_rate_hz": ARRIVAL_RATE_HZ,
                   "trace_seed": TRACE_SEED,
                   "zipf_a": ZIPF_A, "zipf_cap": ZIPF_CAP,
                   "fault_rates": FAULT_RATES,
                   "grammars": ["json", "c", "unconstrained"],
                   "sync_n": SYNC_N,
                   "dev_n_requests": DEV_N_REQUESTS,
                   "dev_max_tokens": DEV_MAX_TOKENS,
                   "pfx_n_requests": PFX_N_REQUESTS,
                   "pfx_page_size": PFX_PAGE_SIZE,
                   "pfx_n_pages": PFX_N_PAGES},
        "fault_free": fault_free,
        "traffic_replay_identical": True,     # asserted above
        "faulted": faulted,
        "host_loop": host_loop,
        "device_loop": device_loop,
        "device_speedup": speedup,
        "faulted_recovered": recovered,
        "prefix_zipf": prefix_zipf,
    }
    pathlib.Path(json_path).write_text(json.dumps(record, indent=2))
    _append_history([{**fault_free, "label": "fault_free"},
                     {**faulted, "label": "faulted"},
                     host_loop, device_loop, recovered, prefix_zipf])
    if verbose:
        print(f"  [serving] wrote {json_path} and appended "
              f"{HISTORY_PATH.name}", flush=True)
    return record


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--replay", action="store_true",
                    help="traffic-replay mode only: drive the seeded "
                         "trace twice and assert bitwise-identical "
                         "token ids (no artifacts written)")
    args = ap.parse_args()
    if args.replay:
        _eng = _setup()
        base = _drive(_eng, injector=None, label="fault_free",
                      trace=_make_trace())
        _replay_check(_eng, base)
    else:
        run()
