"""Sustained-throughput serving benchmark (ISSUE 7) -> BENCH_serving.json.

Drives the continuous-batching scheduler the way a deployment would:
Poisson arrivals over mixed traffic (json grammar, c grammar, and
unconstrained rows in one batch), more requests than slots, paged KV,
tick-boundary invariants audited throughout.  Two passes:

 - **fault_free**: the baseline trajectory — sustained tok/s and p50/p99
   request latency (submission -> terminal status, queue wait included).
 - **faulted**: the same workload under a seeded ~5%-rate fault storm
   (device-step NaNs, checker/mask failures, injected pool exhaustion).
   Reports the same metrics plus the terminal-status mix, so the cost of
   graceful degradation is a number, not a hope.

Assertions are the acceptance bars: the fault-free pass completes every
request `ok`, and BOTH passes drain without leaking a single page.

This file seeds the ROADMAP's perf-trajectory artifact for the serving
layer: CI uploads ``BENCH_serving.json`` next to ``BENCH_mask.json`` /
``BENCH_decode.json`` so tok/s and tail latency get a tracked history.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.core import grammars
from repro.core.sampling import GrammarSampler
from repro.models import build_model
from repro.serving import (ConstraintSpec, ContinuousBatchingScheduler,
                           DecodeParams, EngineConfig, FaultInjector,
                           Request, ServingEngine)
from repro.tokenizer import train_bpe

N_REQUESTS = 24
CAPACITY = 4
MAX_TOKENS = 24
ARRIVAL_RATE_HZ = 40.0           # Poisson arrival intensity
# rates are PER CONSULTATION (every mask build / device row / admission
# draws once), so per-request failure odds compound over ~MAX_TOKENS
# ticks; these values land the storm at roughly a 5%-per-request-phase
# fault intensity rather than killing the whole batch
FAULT_RATES = {"mask_error": 0.005, "decode_nan": 0.005,
               "advance_error": 0.005, "prefill_nan": 0.01,
               "page_exhaustion": 0.05}
MODEL = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
             dtype="float32", max_seq_len=512)

PROMPTS = ["a: ", "record: ", "x = ", "{", "fn: ", "data -> "]

# device-loop vs host-loop comparison (ISSUE 8): certified-JSON-only
# workload on a byte-complete vocabulary (the json grammar certifies
# CLEAN there, so the engine uploads a device table), greedy rows —
# exactly the population the fused loop accelerates
SYNC_N = 8
DEV_N_REQUESTS = 16
DEV_MAX_TOKENS = 32
HISTORY_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_history.jsonl"


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=pathlib.Path(__file__).resolve().parent)
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _setup() -> ServingEngine:
    gj, gc = grammars.load("json"), grammars.load("c")
    corpus = (GrammarSampler(gj, seed=5).corpus(150)
              + GrammarSampler(gc, seed=6).corpus(150))
    tok = train_bpe(corpus, vocab_size=420)
    cfg = ModelConfig(arch_id="serve-bench", family="dense",
                      vocab_size=tok.vocab_size, **MODEL)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, tok, max_len=256)
    eng.register_grammar("json", gj)
    eng.register_grammar("c", gc)
    eng.precompute()               # trees off the serving critical path
    return eng


def _requests():
    specs = [ConstraintSpec(grammar="json", mode="domino"),
             ConstraintSpec(grammar="c", mode="domino"),
             ConstraintSpec()]    # unconstrained rows ride along
    return [Request(PROMPTS[i % len(PROMPTS)], specs[i % len(specs)],
                    DecodeParams(max_tokens=MAX_TOKENS, seed=i))
            for i in range(N_REQUESTS)]


def _drive(eng: ServingEngine, injector=None, label="fault_free",
           verbose=True):
    """One serving pass: Poisson arrivals submitted by wall clock into a
    stepping scheduler; returns the metric record."""
    rng = np.random.default_rng(42)   # arrival process, not sampling
    arrivals = np.cumsum(rng.exponential(1.0 / ARRIVAL_RATE_HZ,
                                         N_REQUESTS))
    reqs = _requests()
    sched = ContinuousBatchingScheduler(eng, capacity=CAPACITY,
                                        page_size=32,
                                        fault_injector=injector,
                                        debug_invariants=True)
    sessions = []
    next_i = 0
    t0 = time.perf_counter()
    while next_i < len(reqs) or sched.waiting \
            or any(s is not None for s in sched.slots):
        now = time.perf_counter() - t0
        while next_i < len(reqs) and arrivals[next_i] <= now:
            sessions.append(sched.submit(reqs[next_i]))
            next_i += 1
        if not sched.waiting and all(s is None for s in sched.slots):
            time.sleep(min(1e-3, max(0.0, arrivals[next_i] - now)))
            continue
        sched.step()                 # invariants audited every tick
    wall = time.perf_counter() - t0

    lat = np.array([s.result.wall_time_s for s in sessions])
    n_tok = sum(s.result.n_tokens for s in sessions)
    statuses = dict(sched.status_counts)
    rec = {
        "wall_s": wall,
        "n_requests": len(sessions),
        "n_tokens": n_tok,
        "tok_per_s": n_tok / wall,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "n_forward_passes": sched.n_fwd,
        "n_preemptions": sched.n_preempt,
        "n_host_syncs": sched.n_host_syncs,
        "host_syncs_per_token": sched.n_host_syncs / max(n_tok, 1),
        "statuses": statuses,
        "n_faults_fired": 0 if injector is None else injector.n_fired(),
        "fault_sites": {} if injector is None else {
            site: injector.n_fired(site) for site in FAULT_RATES},
    }
    # acceptance bars, not just reporting
    assert len(sessions) == N_REQUESTS
    assert sched.pool.available == sched.n_pages - 1, "page leak"
    assert all(s is None for s in sched.slots), "slot leak"
    if injector is None:
        assert statuses == {"ok": N_REQUESTS}, statuses
    else:
        assert sum(statuses.values()) == N_REQUESTS, statuses
    if verbose:
        print(f"  [serving/{label}] {n_tok} tok in {wall:.2f}s "
              f"({rec['tok_per_s']:.1f} tok/s), "
              f"p50={rec['latency_p50_s'] * 1e3:.0f}ms "
              f"p99={rec['latency_p99_s'] * 1e3:.0f}ms, "
              f"statuses={statuses}", flush=True)
    emit(f"serving_{label}_tok_per_s", 1e6 / max(rec["tok_per_s"], 1e-9),
         f"{rec['tok_per_s']:.1f} tok/s")
    return rec


def _setup_certified() -> ServingEngine:
    """Byte-vocab engine whose json grammar certifies CLEAN, so
    ``device_tables=True`` actually uploads a table."""
    g = grammars.load("json")
    corpus = GrammarSampler(g, seed=5).corpus(80)
    tok = train_bpe(corpus, vocab_size=257)
    cfg = ModelConfig(arch_id="serve-bench-dev", family="dense",
                      vocab_size=tok.vocab_size, **MODEL)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, tok, g,
                        EngineConfig(mode="domino",
                                     max_tokens=DEV_MAX_TOKENS),
                        max_len=256, device_tables=True)
    eng.register_grammar("json", g)
    eng.precompute()
    assert "json" in eng.device_tables, \
        "json failed to certify on the byte vocabulary"
    return eng


def _drive_loop(eng: ServingEngine, device_loop: bool, label: str,
                verbose=True):
    """One drain pass of the certified-JSON workload.  Warm requests run
    through the SAME scheduler first (the fused loop compiles per
    scheduler instance), then counters reset and the measured batch is
    submitted up front — a sustained-throughput drain, no arrival
    process to hide the per-token host syncs behind."""
    sched = ContinuousBatchingScheduler(eng, capacity=CAPACITY,
                                        page_size=32,
                                        device_loop=device_loop,
                                        sync_n=SYNC_N,
                                        debug_invariants=True)
    for p in PROMPTS[:CAPACITY]:
        sched.submit(Request(p, ConstraintSpec(grammar="json",
                                               mode="domino"),
                             DecodeParams(max_tokens=SYNC_N + 2)))
    sched.run()                               # compile warm-up
    sched.n_host_syncs = sched.n_device_tokens = sched.n_fwd = 0
    sessions = [sched.submit(
        Request(PROMPTS[i % len(PROMPTS)],
                ConstraintSpec(grammar="json", mode="domino"),
                DecodeParams(max_tokens=DEV_MAX_TOKENS, seed=i)))
        for i in range(DEV_N_REQUESTS)]
    t0 = time.perf_counter()
    results = sched.run()
    wall = time.perf_counter() - t0
    lat = np.array([s.result.wall_time_s for s in sessions])
    n_tok = sum(r.n_tokens for r in results)
    assert all(r.status == "ok" for r in results), \
        {r.status for r in results}
    rec = {
        "label": label,
        "wall_s": wall,
        "n_requests": len(sessions),
        "n_tokens": n_tok,
        "tok_per_s": n_tok / wall,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "n_host_syncs": sched.n_host_syncs,
        "host_syncs_per_token": sched.n_host_syncs / max(n_tok, 1),
        "n_device_tokens": sched.n_device_tokens,
        "n_quotient_escapes": sched.n_quotient_escapes,
        "n_table_rejects": sched.n_table_rejects,
    }
    if verbose:
        print(f"  [serving/{label}] {n_tok} tok in {wall:.2f}s "
              f"({rec['tok_per_s']:.1f} tok/s), "
              f"syncs/tok={rec['host_syncs_per_token']:.3f}, "
              f"device_tokens={sched.n_device_tokens}", flush=True)
    emit(f"serving_{label}_tok_per_s", 1e6 / max(rec["tok_per_s"], 1e-9),
         f"{rec['tok_per_s']:.1f} tok/s")
    return rec


def _append_history(rows, path=HISTORY_PATH):
    """Append per-PR benchmark rows to the tracked JSONL history — one
    line per (commit, label), so the perf trajectory across PRs is a
    diffable artifact, not a dashboard."""
    sha = _git_sha()
    ts = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    keep = ("label", "tok_per_s", "latency_p50_s", "latency_p99_s",
            "host_syncs_per_token", "n_tokens", "n_device_tokens",
            "n_quotient_escapes", "n_table_rejects")
    with open(path, "a") as f:
        for row in rows:
            slim = {k: row[k] for k in keep if k in row}
            f.write(json.dumps({"git_sha": sha, "ts": ts, **slim},
                               sort_keys=True) + "\n")


def run(verbose: bool = True, json_path: str = "BENCH_serving.json"):
    eng = _setup()
    # warm compile out of the measured window: one small batch end to end
    warm = ContinuousBatchingScheduler(eng, capacity=CAPACITY,
                                       page_size=32)
    for p in PROMPTS[:CAPACITY]:
        warm.submit(Request(p, ConstraintSpec(grammar="json",
                                              mode="domino"),
                            DecodeParams(max_tokens=4)))
    warm.run()

    fault_free = _drive(eng, injector=None, label="fault_free",
                        verbose=verbose)
    injector = FaultInjector(seed=0, rates=FAULT_RATES, max_faults=30)
    faulted = _drive(eng, injector=injector, label="faulted",
                     verbose=verbose)

    # device-resident fused loop vs per-token host loop (ISSUE 8)
    eng_dev = _setup_certified()
    host_loop = _drive_loop(eng_dev, device_loop=False, label="host_loop",
                            verbose=verbose)
    device_loop = _drive_loop(eng_dev, device_loop=True,
                              label="device_loop", verbose=verbose)
    speedup = device_loop["tok_per_s"] / host_loop["tok_per_s"]
    # acceptance bars: sustained speedup AND the sync economy it rests on
    assert speedup >= 1.5, \
        f"device loop speedup {speedup:.2f}x < 1.5x"
    assert device_loop["host_syncs_per_token"] <= 1 / SYNC_N + 0.05, \
        device_loop["host_syncs_per_token"]
    if verbose:
        print(f"  [serving] device-loop speedup {speedup:.2f}x",
              flush=True)

    record = {
        "config": {"n_requests": N_REQUESTS, "capacity": CAPACITY,
                   "max_tokens": MAX_TOKENS,
                   "arrival_rate_hz": ARRIVAL_RATE_HZ,
                   "fault_rates": FAULT_RATES,
                   "grammars": ["json", "c", "unconstrained"],
                   "sync_n": SYNC_N,
                   "dev_n_requests": DEV_N_REQUESTS,
                   "dev_max_tokens": DEV_MAX_TOKENS},
        "fault_free": fault_free,
        "faulted": faulted,
        "host_loop": host_loop,
        "device_loop": device_loop,
        "device_speedup": speedup,
    }
    pathlib.Path(json_path).write_text(json.dumps(record, indent=2))
    _append_history([{**fault_free, "label": "fault_free"},
                     {**faulted, "label": "faulted"},
                     host_loop, device_loop])
    if verbose:
        print(f"  [serving] wrote {json_path} and appended "
              f"{HISTORY_PATH.name}", flush=True)
    return record


if __name__ == "__main__":
    run()
