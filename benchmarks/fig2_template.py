"""Fig. 2 analogue — template-induced misalignment and perplexity.

The paper shows GUIDANCE-style templates force unnatural tokenizations:
comparing (1) unconstrained output, (2) template output under the
template's own (externally tokenized) segmentation, and (3) the same
template TEXT re-tokenized with Algorithm 3 (model-preferred), template
outputs carry much higher perplexity, and naturalizing the templated
text under the model's preferred tokenization exposes a perplexity
explosion.  We reproduce all three measurements, plus a Table-2-style
task-accuracy row for template mode.
"""
from __future__ import annotations

import math
import random

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, get_model_and_params
from repro.core.baselines import Fixed, Gen
from repro.core.retokenize import greedy_tokenize, retokenize
from repro.serving import EngineConfig, ServingEngine
from repro.training.data import evaluate_answer, few_shot_prefix, \
    make_task_example

N_PROBLEMS = 8
PAD_LEN = 320


def gsm8k_template():
    """The paper's schema as a GUIDANCE-style template: structure fixed
    (with the template's own whitespace), only values generated."""
    return [
        Fixed('{"thoughts": [{"step": "'),
        Gen(r"[a-z+\- 0-9]+", stop='"', max_tokens=8),
        Fixed('", "calculation": "'),
        Gen(r"[0-9+\-*]+", stop='"', max_tokens=6),
        Fixed('", "result": '),
        Gen(r"-?[0-9]+", max_tokens=3),
        Fixed('}], "answer": '),
        Gen(r"-?[0-9]+", max_tokens=3),
        Fixed("}"),
    ]


def _seq_logprob(model, params, tok, prompt_ids, out_ids):
    """Mean negative log-likelihood of out_ids given prompt_ids."""
    ids = prompt_ids + out_ids
    logits, _ = model.train_logits(
        params, {"tokens": jnp.asarray([ids[:-1]], jnp.int32)})
    lp = jax.nn.log_softmax(np.asarray(logits, np.float32)[0], axis=-1)
    nll = 0.0
    for t, target in enumerate(ids[1:]):
        if t + 1 > len(prompt_ids) - 1:   # only score the output region
            nll -= float(lp[t, target])
    return nll / max(1, len(out_ids))


_score_fn = None


def run(verbose: bool = True):
    global _score_fn
    model, params, tok = get_model_and_params()
    _score_fn = jax.jit(
        lambda p, t: model.train_logits(p, {"tokens": t})[0])
    rng = random.Random(11)
    problems = [make_task_example(rng, easy=True) for _ in range(N_PROBLEMS)]
    shots = few_shot_prefix(random.Random(5), 2, easy=True)

    un = ServingEngine(model, params, tok, None,
                       EngineConfig(mode="unconstrained", max_tokens=72),
                       max_len=1024)
    te = ServingEngine(model, params, tok, None,
                       EngineConfig(mode="unconstrained", max_tokens=72),
                       max_len=1024)

    ppl_un, ppl_te, ppl_nat = [], [], []
    acc_te = wf_te = 0
    forced_frac = []
    for ex in problems:
        prompt = shots + ex.prompt
        p_ids = tok.encode(prompt)
        r_un = un.generate(prompt)
        if r_un.token_ids:
            ppl_un.append(_seq_logprob(model, params, tok, p_ids,
                                       r_un.token_ids))
        r_te = te.generate_template(prompt, gsm8k_template())
        if r_te.token_ids:
            ppl_te.append(_seq_logprob(model, params, tok, p_ids,
                                       r_te.token_ids))
            forced_frac.append(r_te.n_interventions
                               / max(1, r_te.n_tokens))
            # Algorithm 3: naturalize the template text under the model's
            # preferred tokenization, then score that segmentation.
            # Jitted once at a fixed padded width; each call reads the
            # logits row at the true prefix length.
            text = tok.decode_bytes(r_te.token_ids)

            def model_logits(ids):
                ids = ids or [tok.bos_id]
                n = min(len(ids), PAD_LEN)
                padded = (ids[-PAD_LEN:] + [tok.pad_id]
                          * (PAD_LEN - n))
                lg = _score_fn(params,
                               jnp.asarray([padded], jnp.int32))
                return np.asarray(lg, np.float32)[0, n - 1]
            try:
                nat_ids = retokenize(model_logits, p_ids, text, tok.vocab)
                ppl_nat.append(_seq_logprob(model, params, tok, p_ids,
                                            nat_ids))
            except ValueError:
                pass
        v = evaluate_answer(r_te.text)
        if v is not None:
            wf_te += 1
            if v == ex.answer_value:
                acc_te += 1

    def ppl(xs):
        return math.exp(sum(xs) / max(1, len(xs))) if xs else float("nan")

    rows = {
        "ppl_unconstrained": ppl(ppl_un),
        "ppl_template": ppl(ppl_te),
        "ppl_template_naturalized": ppl(ppl_nat),
        "template_accuracy": acc_te / N_PROBLEMS,
        "template_well_formed": wf_te / N_PROBLEMS,
        "template_forced_token_frac": float(np.mean(forced_frac))
        if forced_frac else 0.0,
    }
    if verbose:
        print(f"  [fig2] ppl: unconstrained={rows['ppl_unconstrained']:.2f} "
              f"template={rows['ppl_template']:.2f} "
              f"naturalized={rows['ppl_template_naturalized']:.2f}",
              flush=True)
        print(f"  [fig2] template: acc={rows['template_accuracy']:.2f} "
              f"wf={rows['template_well_formed']:.2f} "
              f"forced={rows['template_forced_token_frac']:.2f}", flush=True)
    emit("fig2_ppl", 0.0,
         f"un={rows['ppl_unconstrained']:.3f};"
         f"tmpl={rows['ppl_template']:.3f};"
         f"nat={rows['ppl_template_naturalized']:.3f}")
    emit("fig2_template_task", 0.0,
         f"acc={rows['template_accuracy']:.3f};"
         f"wf={rows['template_well_formed']:.3f}")
    return rows


if __name__ == "__main__":
    run()
