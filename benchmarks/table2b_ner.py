"""Table 2, second dataset — CoNLL-2003 NER analogue under the App. D
entity-JSON schema.  Same protocol as table2: unconstrained vs naive vs
DOMINO vs online; scores are entity-set F1 + well-formedness + match rate.

Needs its own trained model (NER data); cached at artifacts/bench/ner/.
"""
from __future__ import annotations

import random
import time

import jax
import jax.numpy as jnp

from benchmarks.common import ART, MODEL_CFG, emit, get_tokenizer
from repro.configs.base import ModelConfig
from repro.core import grammars
from repro.models import build_model
from repro.serving import EngineConfig, ServingEngine
from repro.training import checkpoint, optimizer as opt
from repro.training.data import (NERDataset, evaluate_entities,
                                 make_ner_example, ner_few_shot)
from repro.training.train_loop import make_train_step

N_PROBLEMS = 20
MAX_TOKENS = 72
STEPS = 350


def get_ner_model():
    tok = get_tokenizer()
    cfg = ModelConfig(arch_id="bench-ner", family="dense",
                      vocab_size=tok.vocab_size, **MODEL_CFG)
    model = build_model(cfg)
    ck = ART / "ner"
    if (ck / "params.npz").exists():
        params, _, _ = checkpoint.load(
            ck, jax.eval_shape(model.init, jax.random.PRNGKey(0)))
        return model, jax.tree.map(jnp.asarray, params), tok
    params = model.init(jax.random.PRNGKey(1))
    step = make_train_step(model, opt.AdamWConfig(
        lr=3e-3, schedule="wsd", warmup_steps=10, total_steps=STEPS))
    state = opt.init_state(params)
    data = NERDataset(tok, seq_len=160, few_shot=1).batches(8)
    for i in range(STEPS):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, state, m = step(params, state, batch)
        if i % 50 == 0:
            print(f"  [ner-train] step {i} loss={float(m['loss']):.3f}",
                  flush=True)
    checkpoint.save(ck, params, meta={"steps": STEPS})
    return model, params, tok


MODES = [
    ("unconstrained", EngineConfig(mode="unconstrained",
                                   max_tokens=MAX_TOKENS)),
    ("naive_k0", EngineConfig(mode="naive", max_tokens=MAX_TOKENS)),
    ("domino_kinf", EngineConfig(mode="domino", max_tokens=MAX_TOKENS)),
    ("domino_kinf_spec", EngineConfig(mode="domino", speculative=True,
                                      spec_s=8, spec_threshold=0.4,
                                      max_tokens=MAX_TOKENS)),
]


def run(verbose: bool = True):
    model, params, tok = get_ner_model()
    g = grammars.load("json_conll")
    rng = random.Random(31)
    problems = [make_ner_example(rng) for _ in range(N_PROBLEMS)]
    shots = ner_few_shot(random.Random(7), 2)
    out = {}
    base_tokens = {}
    for name, ecfg in MODES:
        eng = ServingEngine(model, params, tok,
                            None if name == "unconstrained" else g,
                            ecfg, max_len=1024)
        f1 = wf = 0.0
        match = total = 0
        toks = fwd = 0
        for i, ex in enumerate(problems):
            r = eng.generate(shots + ex.prompt)
            toks += max(1, r.n_tokens)
            fwd += r.n_forward_passes
            score = evaluate_entities(r.text, ex.answer_json)
            if score is not None:
                wf += 1
                f1 += score
            if name == "unconstrained":
                base_tokens[i] = r.token_ids
            else:
                b = base_tokens.get(i, [])
                n = min(len(b), len(r.token_ids))
                match += sum(1 for a, c in zip(b[:n], r.token_ids[:n])
                             if a == c)
                total += max(len(b), len(r.token_ids), 1)
        row = {"f1": f1 / N_PROBLEMS, "well_formed": wf / N_PROBLEMS,
               "match_rate": (match / total) if total else 1.0,
               "tok_per_fwd": toks / fwd}
        out[name] = row
        if verbose:
            print(f"  [table2b] {name:18s} f1={row['f1']:.2f} "
                  f"wf={row['well_formed']:.2f} "
                  f"match={row['match_rate']:.2f} "
                  f"tok/fwd={row['tok_per_fwd']:.2f}", flush=True)
        emit(f"table2b_ner_{name}", 0.0,
             f"f1={row['f1']:.3f};wf={row['well_formed']:.3f};"
             f"match={row['match_rate']:.3f}")
    return out


if __name__ == "__main__":
    run()
