"""Table 3 — constrained-generation overhead per grammar x method.

Reports, per (grammar, method):
  us/token        — wall time per generated token (CPU; absolute)
  rel_throughput  — tokens/s relative to unconstrained on the same model
  tok/fwd         — tokens per model forward (>1 = speculation wins; this
                    is the hardware-independent speedup driver of Table 3)
  mask_us/tok     — host-side constraint cost per token (DOMINO's
                    precomputation advantage vs the online baseline)

Plus a serving section: aggregate tokens/s of N concurrent constrained
requests through the continuous-batching scheduler (slot reuse, device-side
masking) vs serving the same requests sequentially.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit, get_model_and_params
from repro.core import grammars
from repro.models import build_model
from repro.serving import (ContinuousBatchingScheduler, EngineConfig,
                           ServingEngine)

GRAMMARS = {
    "json": ("A JSON file describing a person: ", "json"),
    "json_schema": ("Q: compute 3 + 4\nA: ", "json_gsm8k"),
    "c": ("A C program: ", "c"),
    "xml_schema": ("An XML file describing a person: ", "xml_schema"),
    "template": ("A character profile for an RPG game in JSON format: ",
                 "template_rpg"),
}

REPS = 4
MAX_TOKENS = 56


def methods(max_tokens):
    return [
        ("unconstrained", EngineConfig(mode="unconstrained",
                                       max_tokens=max_tokens)),
        ("online", EngineConfig(mode="online", max_tokens=max_tokens)),
        ("domino", EngineConfig(mode="domino", max_tokens=max_tokens)),
        ("domino_opp", EngineConfig(mode="domino", opportunistic=True,
                                    max_tokens=max_tokens)),
        ("domino_spec10", EngineConfig(mode="domino", speculative=True,
                                       spec_s=10, spec_threshold=0.4,
                                       max_tokens=max_tokens)),
    ]


def run(verbose: bool = True):
    model, params, tok = get_model_and_params()
    out = {}
    for gname, (prompt, gkey) in GRAMMARS.items():
        g = grammars.load(gkey)
        base_tps = None
        for mname, ecfg in methods(MAX_TOKENS):
            eng = ServingEngine(model, params, tok,
                                None if mname == "unconstrained" else g,
                                ecfg, max_len=1024)
            eng.generate(prompt)                   # warmup + spec prior
            toks = fwd = 0
            mask_t = model_t = wall = 0.0
            for _ in range(REPS):
                r = eng.generate(prompt)
                toks += max(1, r.n_tokens)
                fwd += r.n_forward_passes
                mask_t += r.mask_time_s
                model_t += r.model_time_s
                wall += r.wall_time_s
            tps = toks / wall
            if mname == "unconstrained":
                base_tps = tps
            row = {
                "us_per_token": 1e6 * wall / toks,
                "rel_throughput": tps / base_tps,
                "tok_per_fwd": toks / fwd,
                "mask_us_per_token": 1e6 * mask_t / toks,
            }
            out[(gname, mname)] = row
            if verbose:
                print(f"  [table3] {gname:12s} {mname:14s} "
                      f"rel={row['rel_throughput']:.2f}x "
                      f"tok/fwd={row['tok_per_fwd']:.2f} "
                      f"mask={row['mask_us_per_token']:.0f}us/tok",
                      flush=True)
            emit(f"table3_{gname}_{mname}", row["us_per_token"],
                 f"rel={row['rel_throughput']:.3f};"
                 f"tokfwd={row['tok_per_fwd']:.3f};"
                 f"maskus={row['mask_us_per_token']:.1f}")
    out.update(run_serving(model, params, tok, verbose=verbose))
    return out


N_REQUESTS = 6
SLOTS = 3


def run_serving(model, params, tok, verbose: bool = True):
    """Continuous-batching scheduler vs sequential single-request serving:
    N concurrent grammar-constrained requests, SLOTS decode slots."""
    g = grammars.load("json")
    prompts = [f"request {i}, a JSON value: " for i in range(N_REQUESTS)]
    eng = ServingEngine(model, params, tok, g,
                        EngineConfig(mode="domino", max_tokens=MAX_TOKENS),
                        max_len=1024)
    eng.precompute()                   # masks off the critical path
    eng.generate(prompts[0])           # compile warmup (prefill + decode)
    t0 = time.perf_counter()
    seq = [eng.generate(p) for p in prompts]
    seq_wall = time.perf_counter() - t0
    seq_toks = sum(max(1, r.n_tokens) for r in seq)
    # warm the batched path's compilations (B=SLOTS decode, slot scatter,
    # fused masked argmax) the same way the sequential path was warmed
    warm = ContinuousBatchingScheduler(eng, capacity=SLOTS)
    for p in prompts[:SLOTS]:
        warm.submit(p)
    warm.run()
    sched = ContinuousBatchingScheduler(eng, capacity=SLOTS)
    for p in prompts:
        sched.submit(p)
    t0 = time.perf_counter()
    batch = sched.run()
    batch_wall = time.perf_counter() - t0
    batch_toks = sum(max(1, r.n_tokens) for r in batch)
    row = {
        "seq_tok_per_s": seq_toks / seq_wall,
        "batch_tok_per_s": batch_toks / batch_wall,
        "speedup": (batch_toks / batch_wall) / (seq_toks / seq_wall),
        "fwd_seq": sum(r.n_forward_passes for r in seq),
        "fwd_batch": sched.n_fwd,
    }
    if verbose:
        print(f"  [table3] serving      continuous    "
              f"{row['batch_tok_per_s']:.1f} tok/s vs "
              f"{row['seq_tok_per_s']:.1f} sequential "
              f"({row['speedup']:.2f}x, "
              f"fwd {row['fwd_batch']} vs {row['fwd_seq']})", flush=True)
    emit("table3_serving_continuous", row["batch_tok_per_s"],
         f"speedup={row['speedup']:.3f};fwd={row['fwd_batch']}")
    out = {("serving", "continuous"): row}
    out.update(run_serving_fused(model, params, tok, verbose=verbose))
    out.update(run_serving_paged(model, params, tok, verbose=verbose))
    out.update(run_serving_mixed(model, params, tok, verbose=verbose))
    return out


def run_serving_mixed(model, params, tok, verbose: bool = True):
    """Mixed-traffic serving (ISSUE 5): the paper's near-zero-overhead
    claim should hold for a MIXED batch, not just a homogeneous one.

    One engine + grammar registry serves N requests through SLOTS slots
    twice: a homogeneous batch (all JSON-domino) and a mixed batch
    cycling {json domino, c domino, unconstrained} — same request count,
    same budgets, same pool.  The row records both aggregate throughputs;
    a mixed/homogeneous ratio near 1 means per-request constraint routing
    adds no serving cost."""
    from repro.serving import (ConstraintSpec, ContinuousBatchingScheduler,
                               DecodeParams, Request, ServingEngine)

    eng = ServingEngine(model, params, tok, max_len=1024)
    eng.register_grammar("json", grammars.load("json"))
    eng.register_grammar("c", grammars.load("c"))
    eng.precompute()
    dp = DecodeParams(max_tokens=MAX_TOKENS)
    prompts = [f"request {i}, a value: " for i in range(N_REQUESTS)]
    homo = [Request(p, ConstraintSpec(grammar="json", mode="domino"), dp)
            for p in prompts]
    cycle = [ConstraintSpec(grammar="json", mode="domino"),
             ConstraintSpec(grammar="c", mode="domino"),
             ConstraintSpec()]
    mixed = [Request(p, cycle[i % len(cycle)], dp)
             for i, p in enumerate(prompts)]

    rows = {}
    for label, reqs in (("homogeneous", homo), ("mixed", mixed)):
        warm = ContinuousBatchingScheduler(eng, capacity=SLOTS)
        for r in reqs:
            warm.submit(r)
        warm.run()                      # compile + tree/memo warmup
        sched = ContinuousBatchingScheduler(eng, capacity=SLOTS)
        for r in reqs:
            sched.submit(r)
        t0 = time.perf_counter()
        res = sched.run()
        wall = time.perf_counter() - t0
        toks = sum(max(1, r.n_tokens) for r in res)
        rows[label] = {"tok_per_s": toks / wall, "fwd": sched.n_fwd,
                       "mask_cache_hits": sched.mask_cache_hits}
    rows["mixed"]["rel_vs_homogeneous"] = (
        rows["mixed"]["tok_per_s"] / rows["homogeneous"]["tok_per_s"])
    for label, r in rows.items():
        if verbose:
            rel = (f" ({r['rel_vs_homogeneous']:.2f}x vs homogeneous)"
                   if "rel_vs_homogeneous" in r else "")
            print(f"  [table3] serving      traffic_{label:11s}"
                  f"{r['tok_per_s']:8.1f} tok/s "
                  f"(fwd {r['fwd']}, memo hits {r['mask_cache_hits']})"
                  f"{rel}", flush=True)
        emit(f"table3_serving_traffic_{label}", r["tok_per_s"],
             f"fwd={r['fwd']};memo={r['mask_cache_hits']}")
    return {("serving", "mixed_traffic"): rows}


def run_serving_fused(model, params, tok, verbose: bool = True):
    """Ragged-fused vs dense-fallback batched decode: the same continuous
    batch served with ``use_pallas_kernels`` on (every batched decode step
    reads the cache through the ragged flash-decode kernel) and off (dense
    jnp attention over the full cache).  On CPU the kernel runs
    interpreted, so absolute wall time is NOT the TPU story — the
    hardware-independent quantity is the per-step cache traffic ratio
    reported by ``kernel_bench``; this row exists to pin the routing and
    track the two paths' trajectories."""
    g = grammars.load("json")
    prompts = [f"request {i}, a JSON value: " for i in range(3)]
    rows = {}
    for label, fused in (("dense_fallback", False), ("ragged_fused", True)):
        cfg = dataclasses.replace(model.cfg, use_pallas_kernels=fused)
        eng = ServingEngine(build_model(cfg), params, tok, g,
                            EngineConfig(mode="domino", max_tokens=24),
                            max_len=1024)
        eng.precompute()
        warm = ContinuousBatchingScheduler(eng, capacity=len(prompts))
        for p in prompts:
            warm.submit(p)
        warm.run()                      # compile warmup for this path
        sched = ContinuousBatchingScheduler(eng, capacity=len(prompts))
        for p in prompts:
            sched.submit(p)
        t0 = time.perf_counter()
        res = sched.run()
        wall = time.perf_counter() - t0
        toks = sum(max(1, r.n_tokens) for r in res)
        rows[label] = {"tok_per_s": toks / wall, "fwd": sched.n_fwd}
        if verbose:
            print(f"  [table3] serving      {label:14s}"
                  f"{rows[label]['tok_per_s']:8.1f} tok/s "
                  f"(fwd {sched.n_fwd})", flush=True)
        emit(f"table3_serving_{label}", rows[label]["tok_per_s"],
             f"fwd={sched.n_fwd}")
    return {("serving", "fused_vs_fallback"): rows}


def run_serving_paged(model, params, tok, verbose: bool = True):
    """Paged vs contiguous KV under the SAME HBM budget (ISSUE 3).

    The budget is two contiguous max_len stripes (pool HBM = 2 x 1024
    tokens < capacity x max_len).  The contiguous layout can only hold
    ``budget / max_len`` = 2 resident requests — admission queues the
    rest.  The paged layout spends the budget as 64-token pages, so every
    slot admits with just ``ceil(need/64)`` pages and 4 requests decode
    concurrently; the row records the achieved residency and aggregate
    throughput of each layout.
    """
    from repro.serving.scheduler import ContinuousBatchingScheduler

    g = grammars.load("json")
    prompts = [f"request {i}, a JSON value: " for i in range(N_REQUESTS)]
    max_len, ps = 1024, 64
    pool_tokens = 2 * max_len                 # HBM budget: 2 full stripes
    eng = ServingEngine(model, params, tok, g,
                        EngineConfig(mode="domino", max_tokens=24),
                        max_len=max_len)
    eng.precompute()

    def serve(label, **kw):
        warm = ContinuousBatchingScheduler(eng, **kw)
        for p in prompts:
            warm.submit(p)
        warm.run()                             # compile warmup
        sched = ContinuousBatchingScheduler(eng, **kw)
        for p in prompts:
            sched.submit(p)
        resident_max = 0
        t0 = time.perf_counter()
        done = []
        while sched.waiting or any(s is not None for s in sched.slots):
            done.extend(sched.step())
            resident_max = max(resident_max,
                               sum(s is not None for s in sched.slots))
        wall = time.perf_counter() - t0
        toks = sum(max(1, s.result.n_tokens) for s in done)
        return {"tok_per_s": toks / wall, "resident_max": resident_max,
                "fwd": sched.n_fwd}

    rows = {
        # contiguous: the budget holds 2 max_len stripes -> 2 slots
        "contiguous": serve("contiguous",
                            capacity=pool_tokens // max_len, paged=False),
        # paged: the same budget as 64-token pages serves 4 slots
        "paged": serve("paged", capacity=4, page_size=ps,
                       n_pages=pool_tokens // ps + 1),
    }
    assert rows["paged"]["resident_max"] > rows["contiguous"]["resident_max"], \
        "paged admission should out-admit contiguous under the same HBM"
    for label, r in rows.items():
        if verbose:
            print(f"  [table3] serving      kv_{label:10s}"
                  f"{r['tok_per_s']:8.1f} tok/s "
                  f"(resident {r['resident_max']}, fwd {r['fwd']}, "
                  f"HBM budget {pool_tokens} tokens)", flush=True)
        emit(f"table3_serving_kv_{label}", r["tok_per_s"],
             f"resident={r['resident_max']};fwd={r['fwd']};"
             f"pool_tokens={pool_tokens}")
    return {("serving", "paged_vs_contiguous"): rows}


if __name__ == "__main__":
    run()
