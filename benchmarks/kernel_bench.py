"""Kernel micro-benchmarks: fused ops vs unfused references.

On CPU the Pallas kernels run interpreted (not representative), so we
benchmark the REF path wall-time and report the analytic HBM-bytes saved
by fusion (the TPU-relevant derived quantity):

 - masked_argmax: the unfused path writes + re-reads the masked logits,
   2*4*|V| bytes per sequence per step;
 - ragged flash-decode: the dense fallback streams the full B x T cache
   every step, the ragged kernel streams only each row's
   ceil((len_b + S - 1)/BLOCK_T) live tiles — on a continuous batch with
   mixed progress that is the dominant decode-step byte saving;
 - paged decode (page_size sweep): the same ragged read through a shared
   page pool + per-row block tables (BLOCK_T == page_size).  Streamed
   bytes shrink further as pages get smaller (less last-tile padding),
   at the cost of more, smaller DMAs — the sweep records both sides of
   that trade per page size, with paged-vs-dense parity asserted at
   every point.

Running this module as a script doubles as the CI interpret-mode smoke
(kernel-vs-oracle parity on the ragged + verify-window + paged layouts)
and writes a ``BENCH_decode.json`` artifact so the perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    out = {}
    for (b, v) in [(8, 32768), (8, 131072), (8, 262144)]:
        logits = jnp.asarray(rng.normal(size=(b, v)).astype(np.float32))
        mask = jnp.asarray((rng.random((b, v)) < 0.01).astype(np.int8))
        from repro.kernels.masked_sample.ref import masked_argmax_ref
        f = jax.jit(masked_argmax_ref)
        f(logits, mask)[0].block_until_ready()
        t0 = time.perf_counter()
        n = 20
        for _ in range(n):
            f(logits, mask)[0].block_until_ready()
        dt = (time.perf_counter() - t0) / n
        saved = 2 * 4 * v  # bytes/seq/step the fused kernel avoids
        out[(b, v)] = {"us": 1e6 * dt, "hbm_saved": saved}
        if verbose:
            print(f"  [kernel] masked_argmax B={b} V={v}: "
                  f"{1e6*dt:.0f}us (ref), fused saves {saved/1024:.0f}KiB "
                  f"HBM/seq/step", flush=True)
        emit(f"kernel_masked_argmax_v{v}", 1e6 * dt,
             f"fused_hbm_saved_bytes={saved}")
    out.update(run_decode(verbose=verbose))
    return out


def run_decode(verbose: bool = True,
               json_path: str = "BENCH_decode.json"):
    """Ragged flash-decode: interpret-mode parity smoke + dense-fallback
    wall time + analytic dense-vs-ragged HBM traffic.  Emits
    ``BENCH_decode.json`` (the CI perf-trajectory artifact)."""
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    rng = np.random.default_rng(1)
    b, g, qh, d, t, bt = 4, 2, 4, 64, 2048, 512
    k = jnp.asarray(rng.normal(size=(b, t, g, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, t, g, d)).astype(np.float32))
    # mixed progress, as a continuous batch produces: one nearly-drained
    # row, one fresh admission, two mid-flight
    lens = np.asarray([2048, 96, 512, 1200], np.int32)
    record = {"config": {"B": b, "G": g, "Qh": qh, "D": d, "T": t,
                         "BLOCK_T": bt, "lens": lens.tolist()},
              "cases": {}}
    for s_win in (1, 5):
        q = jnp.asarray(
            rng.normal(size=(b, s_win, g, qh, d)).astype(np.float32))
        ln = jnp.asarray(lens)
        o_k = decode_attention(q, k, v, ln, block_t=bt)
        o_r = decode_attention_ref(q, k, v, ln)
        err = float(jnp.max(jnp.abs(o_k - o_r)))
        assert err < 1e-3, f"ragged kernel diverged from oracle: {err}"
        # wall time of the dense fallback the kernel replaces (CPU, jit)
        f = jax.jit(decode_attention_ref)
        f(q, k, v, ln).block_until_ready()
        n = 10
        t0 = time.perf_counter()
        for _ in range(n):
            f(q, k, v, ln).block_until_ready()
        dt = (time.perf_counter() - t0) / n
        # analytic per-step K/V HBM traffic (f32)
        dense = b * t * g * d * 2 * 4
        tiles = np.ceil(np.minimum(lens + s_win - 1, t) / bt).sum()
        fused = int(tiles) * bt * g * d * 2 * 4
        case = {"ref_us": 1e6 * dt, "max_abs_err": err,
                "dense_bytes": dense, "fused_bytes": fused,
                "bytes_ratio": dense / fused}
        record["cases"][f"S{s_win}"] = case
        if verbose:
            print(f"  [kernel] decode_attention S={s_win} "
                  f"B={b} T={t}: {1e6*dt:.0f}us (dense ref), ragged "
                  f"streams {fused/2**20:.1f}MiB vs {dense/2**20:.1f}MiB "
                  f"({dense/fused:.2f}x fewer bytes), "
                  f"err={err:.1e}", flush=True)
        emit(f"kernel_decode_attention_s{s_win}", 1e6 * dt,
             f"dense_bytes={dense};fused_bytes={fused};"
             f"ratio={dense/fused:.3f};err={err:.2e}")
    record["paged_sweep"] = run_paged_sweep(verbose=verbose)
    pathlib.Path(json_path).write_text(json.dumps(record, indent=2))
    if verbose:
        print(f"  [kernel] wrote {json_path}", flush=True)
    return {("decode", int(name[1:])): c
            for name, c in record["cases"].items()}


def run_paged_sweep(verbose: bool = True):
    """Page-size sweep for the paged (block-table) decode read.

    For each page_size the same mixed-progress batch is laid out as a
    shuffled page pool; the paged kernel must match the dense kernel on
    the gathered view EXACTLY (identical tile order and accumulation) and
    the jnp oracle to float tolerance.  Recorded per point: max abs error
    vs both references, the streamed-bytes ratio vs the dense fallback,
    and the DMA (tile) count — the page-size trade on TPU is fewer
    padding bytes per row frontier vs more, smaller asynchronous copies.
    """
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                    gather_pages)

    rng = np.random.default_rng(2)
    b, g, qh, d, t = 4, 2, 4, 64, 2048
    lens = np.asarray([2048, 96, 512, 1200], np.int32)
    sweep = {}
    for ps in (16, 32, 64, 128):
        mp = t // ps
        n_pages = 1 + int(np.ceil(lens / ps).sum())
        pool_k = jnp.asarray(
            rng.normal(size=(n_pages, ps, g, d)).astype(np.float32))
        pool_v = jnp.asarray(
            rng.normal(size=(n_pages, ps, g, d)).astype(np.float32))
        # deliberately non-contiguous page assignment
        perm = list(rng.permutation(np.arange(1, n_pages)))
        tbl = np.zeros((b, mp), np.int32)
        for i, ln in enumerate(lens):
            n_pg = int(np.ceil(ln / ps))
            tbl[i, :n_pg] = perm[:n_pg]
            del perm[:n_pg]
        tbl = jnp.asarray(tbl)
        entry = {}
        for s_win in (1, 5):
            q = jnp.asarray(
                rng.normal(size=(b, s_win, g, qh, d)).astype(np.float32))
            ln = jnp.asarray(lens)
            o_paged = decode_attention(q, pool_k, pool_v, ln,
                                       block_tables=tbl)
            o_dense = decode_attention(q, gather_pages(pool_k, tbl),
                                       gather_pages(pool_v, tbl), ln,
                                       block_t=ps)
            err_dense = float(jnp.max(jnp.abs(o_paged - o_dense)))
            assert err_dense == 0.0, \
                f"paged kernel != dense kernel at ps={ps}: {err_dense}"
            o_ref = decode_attention_ref(q, pool_k, pool_v, ln,
                                         block_tables=tbl)
            err = float(jnp.max(jnp.abs(o_paged - o_ref)))
            assert err < 1e-3, \
                f"paged kernel diverged from oracle at ps={ps}: {err}"
            tiles = int(np.ceil(np.minimum(lens + s_win - 1, t) / ps).sum())
            fused = tiles * ps * g * d * 2 * 4
            dense = b * t * g * d * 2 * 4
            entry[f"S{s_win}"] = {
                "max_abs_err": err, "err_vs_dense_kernel": err_dense,
                "tiles": tiles, "fused_bytes": fused,
                "bytes_ratio": dense / fused}
        entry["pool_pages"] = n_pages
        sweep[f"ps{ps}"] = entry
        if verbose:
            e1 = entry["S1"]
            print(f"  [kernel] paged decode ps={ps:4d}: "
                  f"{e1['tiles']} tiles/step, "
                  f"{e1['bytes_ratio']:.2f}x fewer bytes vs dense, "
                  f"err={e1['max_abs_err']:.1e} "
                  f"(== dense kernel: "
                  f"{e1['err_vs_dense_kernel'] == 0.0})", flush=True)
        emit(f"kernel_decode_paged_ps{ps}", entry["S1"]["tiles"],
             f"ratio={entry['S1']['bytes_ratio']:.3f};"
             f"err={entry['S1']['max_abs_err']:.2e}")
    return sweep


if __name__ == "__main__":
    run()
