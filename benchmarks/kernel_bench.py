"""Kernel micro-benchmarks: fused ops vs unfused references.

On CPU the Pallas kernels run interpreted (not representative), so we
benchmark the REF path wall-time and report the analytic HBM-bytes saved
by fusion (the TPU-relevant derived quantity):

 - masked_argmax: the unfused path writes + re-reads the masked logits,
   2*4*|V| bytes per sequence per step;
 - ragged flash-decode: the dense fallback streams the full B x T cache
   every step, the ragged kernel streams only each row's
   ceil((len_b + S - 1)/BLOCK_T) live tiles — on a continuous batch with
   mixed progress that is the dominant decode-step byte saving.

Running this module as a script doubles as the CI interpret-mode smoke
(kernel-vs-oracle parity on the ragged + verify-window layouts) and
writes a ``BENCH_decode.json`` artifact so the perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    out = {}
    for (b, v) in [(8, 32768), (8, 131072), (8, 262144)]:
        logits = jnp.asarray(rng.normal(size=(b, v)).astype(np.float32))
        mask = jnp.asarray((rng.random((b, v)) < 0.01).astype(np.int8))
        from repro.kernels.masked_sample.ref import masked_argmax_ref
        f = jax.jit(masked_argmax_ref)
        f(logits, mask)[0].block_until_ready()
        t0 = time.perf_counter()
        n = 20
        for _ in range(n):
            f(logits, mask)[0].block_until_ready()
        dt = (time.perf_counter() - t0) / n
        saved = 2 * 4 * v  # bytes/seq/step the fused kernel avoids
        out[(b, v)] = {"us": 1e6 * dt, "hbm_saved": saved}
        if verbose:
            print(f"  [kernel] masked_argmax B={b} V={v}: "
                  f"{1e6*dt:.0f}us (ref), fused saves {saved/1024:.0f}KiB "
                  f"HBM/seq/step", flush=True)
        emit(f"kernel_masked_argmax_v{v}", 1e6 * dt,
             f"fused_hbm_saved_bytes={saved}")
    out.update(run_decode(verbose=verbose))
    return out


def run_decode(verbose: bool = True,
               json_path: str = "BENCH_decode.json"):
    """Ragged flash-decode: interpret-mode parity smoke + dense-fallback
    wall time + analytic dense-vs-ragged HBM traffic.  Emits
    ``BENCH_decode.json`` (the CI perf-trajectory artifact)."""
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    rng = np.random.default_rng(1)
    b, g, qh, d, t, bt = 4, 2, 4, 64, 2048, 512
    k = jnp.asarray(rng.normal(size=(b, t, g, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, t, g, d)).astype(np.float32))
    # mixed progress, as a continuous batch produces: one nearly-drained
    # row, one fresh admission, two mid-flight
    lens = np.asarray([2048, 96, 512, 1200], np.int32)
    record = {"config": {"B": b, "G": g, "Qh": qh, "D": d, "T": t,
                         "BLOCK_T": bt, "lens": lens.tolist()},
              "cases": {}}
    for s_win in (1, 5):
        q = jnp.asarray(
            rng.normal(size=(b, s_win, g, qh, d)).astype(np.float32))
        ln = jnp.asarray(lens)
        o_k = decode_attention(q, k, v, ln, block_t=bt)
        o_r = decode_attention_ref(q, k, v, ln)
        err = float(jnp.max(jnp.abs(o_k - o_r)))
        assert err < 1e-3, f"ragged kernel diverged from oracle: {err}"
        # wall time of the dense fallback the kernel replaces (CPU, jit)
        f = jax.jit(decode_attention_ref)
        f(q, k, v, ln).block_until_ready()
        n = 10
        t0 = time.perf_counter()
        for _ in range(n):
            f(q, k, v, ln).block_until_ready()
        dt = (time.perf_counter() - t0) / n
        # analytic per-step K/V HBM traffic (f32)
        dense = b * t * g * d * 2 * 4
        tiles = np.ceil(np.minimum(lens + s_win - 1, t) / bt).sum()
        fused = int(tiles) * bt * g * d * 2 * 4
        case = {"ref_us": 1e6 * dt, "max_abs_err": err,
                "dense_bytes": dense, "fused_bytes": fused,
                "bytes_ratio": dense / fused}
        record["cases"][f"S{s_win}"] = case
        if verbose:
            print(f"  [kernel] decode_attention S={s_win} "
                  f"B={b} T={t}: {1e6*dt:.0f}us (dense ref), ragged "
                  f"streams {fused/2**20:.1f}MiB vs {dense/2**20:.1f}MiB "
                  f"({dense/fused:.2f}x fewer bytes), "
                  f"err={err:.1e}", flush=True)
        emit(f"kernel_decode_attention_s{s_win}", 1e6 * dt,
             f"dense_bytes={dense};fused_bytes={fused};"
             f"ratio={dense/fused:.3f};err={err:.2e}")
    pathlib.Path(json_path).write_text(json.dumps(record, indent=2))
    if verbose:
        print(f"  [kernel] wrote {json_path}", flush=True)
    return {("decode", int(name[1:])): c
            for name, c in record["cases"].items()}


if __name__ == "__main__":
    run()
