"""Kernel micro-benchmarks: fused masked-argmax vs unfused reference.

On CPU the Pallas kernels run interpreted (not representative), so we
benchmark the REF path wall-time and report the analytic HBM-bytes saved
by fusion (the TPU-relevant derived quantity): the unfused path writes +
re-reads the masked logits, 2*4*|V| bytes per sequence per step.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    out = {}
    for (b, v) in [(8, 32768), (8, 131072), (8, 262144)]:
        logits = jnp.asarray(rng.normal(size=(b, v)).astype(np.float32))
        mask = jnp.asarray((rng.random((b, v)) < 0.01).astype(np.int8))
        from repro.kernels.masked_sample.ref import masked_argmax_ref
        f = jax.jit(masked_argmax_ref)
        f(logits, mask)[0].block_until_ready()
        t0 = time.perf_counter()
        n = 20
        for _ in range(n):
            f(logits, mask)[0].block_until_ready()
        dt = (time.perf_counter() - t0) / n
        saved = 2 * 4 * v  # bytes/seq/step the fused kernel avoids
        out[(b, v)] = {"us": 1e6 * dt, "hbm_saved": saved}
        if verbose:
            print(f"  [kernel] masked_argmax B={b} V={v}: "
                  f"{1e6*dt:.0f}us (ref), fused saves {saved/1024:.0f}KiB "
                  f"HBM/seq/step", flush=True)
        emit(f"kernel_masked_argmax_v{v}", 1e6 * dt,
             f"fused_hbm_saved_bytes={saved}")
    return out


if __name__ == "__main__":
    run()
