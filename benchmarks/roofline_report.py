"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the artifacts
written by repro.launch.dryrun / calibrate / roofline.

  PYTHONPATH=src python -m benchmarks.roofline_report > /tmp/tables.md
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.configs import ALIASES  # noqa: E402
from repro.configs.base import INPUT_SHAPES  # noqa: E402
from repro.launch.roofline import SUGGESTIONS, load_dryrun, roofline  # noqa: E402

ART = ROOT / "artifacts"


def fmt_bytes(n):
    if n is None:
        return "-"
    return f"{n/2**30:.2f}"


def dryrun_table(mesh: str) -> str:
    rows = [
        "| arch | shape | compile s | arg GiB/dev | temp GiB/dev | "
        "HLO GFLOP/dev* | collectives (count) |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ALIASES:
        for shape in INPUT_SHAPES:
            rec = load_dryrun(arch, shape, mesh)
            if rec is None:
                rows.append(f"| {arch} | {shape} | MISSING | | | | |")
                continue
            if "skipped" in rec:
                rows.append(f"| {arch} | {shape} | skip (long_500k "
                            f"n/a: full attention) | | | | |")
                continue
            mem = rec.get("memory", {})
            colls = rec.get("collectives", {})
            cstr = ", ".join(f"{k}:{v['count']}" for k, v in colls.items()
                             if v["count"])
            rows.append(
                f"| {arch} | {shape} | {rec['compile_s']:.1f} | "
                f"{fmt_bytes(mem.get('argument_bytes'))} | "
                f"{fmt_bytes(mem.get('temp_bytes'))} | "
                f"{rec.get('cost', {}).get('flops_per_device', 0)/1e9:.1f} | "
                f"{cstr} |")
    return "\n".join(rows)


def roofline_table(mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant "
        "| useful/total FLOPs | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    data = []
    for arch in ALIASES:
        for shape in INPUT_SHAPES:
            rec = load_dryrun(arch, shape, mesh)
            if rec is None or "skipped" in rec:
                continue
            coll = rec.get("collective_bytes_corrected")
            r = roofline(arch, shape, mesh, rec, coll_bytes=coll)
            data.append(r)
    data.sort(key=lambda r: (r["arch"], r["shape"]))
    for r in data:
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{SUGGESTIONS[r['dominant']][:60]}... |")
    return "\n".join(rows)


def main() -> None:
    print("## §Dry-run — 16x16 (single pod, 256 chips)\n")
    print(dryrun_table("16x16"))
    print("\n## §Dry-run — 2x16x16 (multi-pod, 512 chips)\n")
    print(dryrun_table("pod2x16x16"))
    print("\n## §Roofline — single pod\n")
    print(roofline_table("16x16"))


if __name__ == "__main__":
    main()
