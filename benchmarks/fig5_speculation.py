"""Fig. 5 — speculative tokens s vs throughput, schema vs free-form JSON.

Paper: s in {6,8,10} gives ~1.7x on schema-driven JSON; free-form JSON
doesn't speculate well (opportunistic masking preferred).  We sweep s and
report tokens-per-forward (the structural speedup) and acceptance rate.
"""
from __future__ import annotations

from benchmarks.common import emit, get_model_and_params
from repro.core import grammars
from repro.serving import EngineConfig, ServingEngine

S_VALUES = [1, 2, 4, 6, 8, 10, 12]
MAX_TOKENS = 56
REPS = 3

WORKLOADS = {
    "schema": ("Q: compute 3 + 4\nA: ", "json_gsm8k"),
    "freeform": ("A JSON file describing a person: ", "json"),
}


def run(verbose: bool = True):
    model, params, tok = get_model_and_params()
    out = {}
    for wname, (prompt, gkey) in WORKLOADS.items():
        g = grammars.load(gkey)
        for s in S_VALUES:
            eng = ServingEngine(model, params, tok, g,
                                EngineConfig(mode="domino", speculative=True,
                                             spec_s=s, spec_threshold=0.4,
                                             max_tokens=MAX_TOKENS),
                                max_len=1024)
            eng.generate(prompt)  # prior
            toks = fwd = prop = acc = 0
            for _ in range(REPS):
                r = eng.generate(prompt)
                toks += max(1, r.n_tokens)
                fwd += r.n_forward_passes
                prop += r.n_spec_proposed
                acc += r.n_spec_accepted
            row = {"tok_per_fwd": toks / fwd,
                   "acceptance": acc / max(1, prop)}
            out[(wname, s)] = row
            if verbose:
                print(f"  [fig5] {wname:9s} s={s:2d} "
                      f"tok/fwd={row['tok_per_fwd']:.2f} "
                      f"accept={row['acceptance']:.2f}", flush=True)
            emit(f"fig5_{wname}_s{s}", 0.0,
                 f"tokfwd={row['tok_per_fwd']:.3f};"
                 f"accept={row['acceptance']:.3f}")
    return out


if __name__ == "__main__":
    run()
