"""Table 2 — task accuracy / invasiveness of constraining methods.

The paper's GSM8K-JSON experiment at laptop scale: the in-repo model is
trained on arithmetic problems with JSON reasoning answers; each method
decodes the same problems and we score

  accuracy      — parsed {"answer": n} equals the gold value
  well-formed   — output parses as JSON at all
  match-rate    — tokens identical to unconstrained output (invasiveness
                  proxy: 1.0 means the constraint never changed anything
                  the model wanted to emit, the paper's Def. 2.1 effect)
  interventions — masked-out argmax count per 100 tokens
"""
from __future__ import annotations

import json
import random
import time

from benchmarks.common import emit, get_model_and_params
from repro.core import grammars
from repro.serving import EngineConfig, ServingEngine
from repro.training.data import evaluate_answer, few_shot_prefix, \
    make_task_example

N_PROBLEMS = 25
MAX_TOKENS = 72

MODES = [
    ("unconstrained", EngineConfig(mode="unconstrained",
                                   max_tokens=MAX_TOKENS)),
    ("naive_k0", EngineConfig(mode="naive", max_tokens=MAX_TOKENS)),
    ("domino_kinf", EngineConfig(mode="domino", max_tokens=MAX_TOKENS)),
    ("domino_kinf_spec", EngineConfig(mode="domino", speculative=True,
                                      spec_s=8, spec_threshold=0.4,
                                      max_tokens=MAX_TOKENS)),
    ("online_parser", EngineConfig(mode="online", max_tokens=MAX_TOKENS)),
]


def run(verbose: bool = True):
    model, params, tok = get_model_and_params()
    g = grammars.load("json_gsm8k")
    rng = random.Random(99)
    problems = [make_task_example(rng, easy=True) for _ in range(N_PROBLEMS)]
    shots = few_shot_prefix(random.Random(5), 2, easy=True)
    results = {}
    baseline_tokens = {}
    for name, ecfg in MODES:
        eng = ServingEngine(model, params, tok,
                            None if name == "unconstrained" else g,
                            ecfg, max_len=1024)
        acc = wf = 0
        match = total_match = 0
        interventions = toks = 0
        t0 = time.perf_counter()
        fwd = 0
        for i, ex in enumerate(problems):
            r = eng.generate(shots + ex.prompt)
            fwd += r.n_forward_passes
            toks += max(1, r.n_tokens)
            interventions += r.n_interventions
            val = evaluate_answer(r.text)
            if val is not None:
                wf += 1
                if val == ex.answer_value:
                    acc += 1
            if name == "unconstrained":
                baseline_tokens[i] = r.token_ids
            else:
                base = baseline_tokens.get(i, [])
                n = min(len(base), len(r.token_ids))
                match += sum(1 for a, b in
                             zip(base[:n], r.token_ids[:n]) if a == b)
                total_match += max(len(base), len(r.token_ids), 1)
        dt = time.perf_counter() - t0
        row = {
            "accuracy": acc / N_PROBLEMS,
            "well_formed": wf / N_PROBLEMS,
            "match_rate": (match / total_match) if total_match else 1.0,
            "interventions_per_100tok": 100.0 * interventions / toks,
            "fwd_per_token": fwd / toks,
            "s_per_problem": dt / N_PROBLEMS,
        }
        results[name] = row
        if verbose:
            print(f"  [table2] {name:18s} acc={row['accuracy']:.2f} "
                  f"wf={row['well_formed']:.2f} "
                  f"match={row['match_rate']:.2f} "
                  f"int/100={row['interventions_per_100tok']:.1f} "
                  f"fwd/tok={row['fwd_per_token']:.2f}",
                  flush=True)
        emit(f"table2_{name}", 1e6 * row["s_per_problem"],
             f"acc={row['accuracy']:.3f};wf={row['well_formed']:.3f};"
             f"match={row['match_rate']:.3f}")
    return results


if __name__ == "__main__":
    run()
