"""Batch analysis runs and report serialization."""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import grammars as zoo
from repro.core.analysis import AnalysisReport, analyze


def bytes_vocab() -> Tuple[List[Optional[bytes]], int]:
    """The synthetic byte-level vocabulary the CI gate analyzes under:
    all 256 single bytes plus one EOS sentinel.  Deterministic, needs no
    trained tokenizer artifact, and exercises every grammar path a
    byte-complete real vocabulary would (alignment gaps against it can
    only come from the grammar itself)."""
    vocab: List[Optional[bytes]] = [bytes([i]) for i in range(256)]
    vocab.append(None)                   # EOS
    return vocab, 256


def run_batch(names: Sequence[str], vocab: Sequence[Optional[bytes]],
              eos_id: int, clamp: int, max_states: int,
              progress=None,
              emit_device_table: bool = False) -> Dict[str, AnalysisReport]:
    """Analyze each named zoo grammar; returns name -> report."""
    out: Dict[str, AnalysisReport] = {}
    for name in names:
        g = zoo.load(name)
        rep = analyze(g, vocab, eos_id, name=name, clamp=clamp,
                      max_states=max_states,
                      emit_device_table=emit_device_table)
        out[name] = rep
        if progress is not None:
            progress(rep)
    return out


def write_json(reports: Dict[str, AnalysisReport], path: str) -> None:
    payload = {
        "reports": {name: rep.to_dict() for name, rep in reports.items()},
        "ok": all(rep.ok() for rep in reports.values()),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
