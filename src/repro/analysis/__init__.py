"""Report/CLI layer over :mod:`repro.core.analysis`.

``repro.core.analysis`` holds the verification engine (pure, importable
from the serving stack); this package holds everything user-facing: batch
runs over the grammar zoo, text/JSON rendering, and the ``python -m
repro.analyze`` entry point the CI gate calls.
"""
from repro.core.analysis import (AnalysisError, AnalysisReport,
                                 ClosureCertificate, Issue, POLICIES,
                                 Witness, analyze, enforce)
from repro.analysis.report import bytes_vocab, run_batch, write_json

__all__ = [
    "AnalysisError", "AnalysisReport", "ClosureCertificate", "Issue",
    "POLICIES", "Witness", "analyze", "enforce",
    "bytes_vocab", "run_batch", "write_json",
]
