"""``python -m repro.analyze`` — registration-time grammar verification.

Examples::

    python -m repro.analyze json               # one zoo grammar
    python -m repro.analyze --all --strict     # the CI gate
    python -m repro.analyze --all --json report.json
    python -m repro.analyze my.lark --tokenizer artifacts/tokenizer.json

Exit status: 0 when every analyzed grammar is clean, 1 under ``--strict``
when any report has problems (the gate condition), 2 on usage errors.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Tuple

from repro.analysis.report import bytes_vocab, run_batch, write_json
from repro.core import grammars as zoo
from repro.core.analysis import (DEFAULT_CLAMP, DEFAULT_MAX_STATES,
                                 AnalysisReport, analyze)
from repro.core.grammar import parse_grammar


def _load_vocab(tokenizer_path: Optional[str]) -> Tuple[list, int]:
    if tokenizer_path is None:
        return bytes_vocab()
    from repro.tokenizer import BPETokenizer
    tok = BPETokenizer.load(tokenizer_path)
    return list(tok.vocab), tok.eos_id


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analyze",
        description="Static grammar x vocabulary analysis (trap states, "
                    "EOS-liveness, alignment gaps, closure certificate).")
    ap.add_argument("grammars", nargs="*",
                    help="zoo grammar names (see --list) or .lark file paths")
    ap.add_argument("--all", action="store_true",
                    help="analyze every grammar in the zoo")
    ap.add_argument("--list", action="store_true",
                    help="list zoo grammar names and exit")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any report has problems (CI gate)")
    ap.add_argument("--tokenizer", metavar="PATH", default=None,
                    help="BPE tokenizer artifact to analyze against "
                         "(default: synthetic 256-byte vocab + EOS)")
    ap.add_argument("--clamp", type=int, default=DEFAULT_CLAMP,
                    help="origin clamp of the abstract quotient "
                         "(default %(default)s)")
    ap.add_argument("--max-states", type=int, default=DEFAULT_MAX_STATES,
                    help="abstract state budget before the closure is "
                         "declared non-finite (default %(default)s)")
    ap.add_argument("--emit-device-table", action="store_true",
                    help="assemble the device grammar table from each "
                         "clean closure certificate and report its "
                         "shape/footprint (what the serving engine "
                         "uploads under device_tables=True); grammars "
                         "whose certificate is not clean report the "
                         "refusal reason instead")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the full reports as JSON")
    ap.add_argument("--quiet", action="store_true",
                    help="only print verdict lines, not full summaries")
    args = ap.parse_args(argv)

    if args.list:
        for name in sorted(zoo.GRAMMARS):
            print(name)
        return 0
    names = list(zoo.GRAMMARS) if args.all else args.grammars
    if not names:
        ap.print_usage(sys.stderr)
        print("error: no grammars given (name one, or use --all)",
              file=sys.stderr)
        return 2

    vocab, eos_id = _load_vocab(args.tokenizer)
    reports = {}
    for name in names:
        if name in zoo.GRAMMARS:
            reports.update(run_batch(
                [name], vocab, eos_id, args.clamp, args.max_states,
                emit_device_table=args.emit_device_table))
        elif os.path.exists(name):
            with open(name) as f:
                g = parse_grammar(f.read())
            reports[name] = analyze(
                g, vocab, eos_id, name=name, clamp=args.clamp,
                max_states=args.max_states,
                emit_device_table=args.emit_device_table)
        else:
            print(f"error: {name!r} is neither a zoo grammar nor a file "
                  f"(zoo: {', '.join(sorted(zoo.GRAMMARS))})",
                  file=sys.stderr)
            return 2

    for name, rep in reports.items():
        if args.quiet:
            print(f"{name}: {'OK' if rep.ok() else 'FAIL'}")
        else:
            print(rep.summary())
            print()
        if args.emit_device_table:
            tbl = rep.device_table
            if tbl is not None:
                print(f"{name}: device table CERTIFIED — "
                      f"{tbl.n_states} states, masks "
                      f"{tbl.mask_table.shape} + trans {tbl.trans.shape}"
                      f" = {tbl.n_bytes / 1024:.0f} KiB")
            else:
                why = []
                if not rep.closure.finite:
                    why.append("closure not finite")
                if rep.n_mask_conflicts:
                    why.append(f"{rep.n_mask_conflicts} mask conflicts")
                if rep.n_hyp_truncations:
                    why.append(f"{rep.n_hyp_truncations} hypothesis "
                               "truncations")
                if rep.traps:
                    why.append(f"{len(rep.traps)} trap states")
                print(f"{name}: device table REFUSED — "
                      f"{'; '.join(why) or 'no exploration masks'} "
                      f"(rows for this grammar serve on the host path)")
    if args.json:
        write_json(reports, args.json)
        print(f"wrote {args.json}")

    n_bad = sum(not rep.ok() for rep in reports.values())
    if n_bad:
        print(f"{n_bad}/{len(reports)} grammar(s) FAILED analysis",
              file=sys.stderr)
        if args.strict:
            return 1
    return 0
