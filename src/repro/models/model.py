"""Public model API: build_model(config) -> Model with

  init(rng)                          -> params
  train_logits(params, batch)        -> (logits, aux)
  loss(params, batch)                -> (scalar, metrics)
  prefill(params, inputs, cache)     -> (last logits, cache)
  decode_step(params, cache, tokens) -> (logits (B,S_new,V), cache)

``tokens`` in decode_step may carry S_new > 1 — that is the speculative
verification path of the paper (§3.6): one forward pass scores all
proposed tokens.  Inputs are dicts so the modality stubs (VLM patch
embeddings, whisper frame embeddings) ride along; see input layout per
family in ``example_batch``/``launch.dryrun.input_specs``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import act_sharding, kvcache
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init
from repro.models.transformer import Ctx, block_init, stack_apply, stack_init

Params = Dict[str, Any]


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # -- init -----------------------------------------------------------------

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/lm_head (and
        the fat (B,S,V) logits) shard over the 16-way model axis even for
        awkward sizes (whisper's 51865 -> 51968).  Standard production
        practice; pad logits are masked to -1e30 in _head."""
        return ((self.cfg.vocab_size + 255) // 256) * 256

    def init(self, rng) -> Params:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(rng, 6)
        params: Params = {
            "embed": dense_init(ks[0], (self.padded_vocab, cfg.d_model),
                                scale=1.0, dtype=dt),
            "final_norm": rmsnorm_init(cfg.d_model, dt),
            "stack": stack_init(ks[1], cfg),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(
                ks[2], (cfg.d_model, self.padded_vocab), dtype=dt)
        if cfg.is_encoder_decoder:
            enc_blocks = [block_init(r, cfg, "attn") for r in
                          jax.random.split(ks[3], cfg.n_encoder_layers)]
            params["encoder"] = {
                "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
                "norm": rmsnorm_init(cfg.d_model, dt),
            }
        return params

    # -- embedding / head -------------------------------------------------------

    def _embed(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        return act_sharding.constrain_batch(params["embed"][tokens])

    def _head(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        x = act_sharding.constrain_batch(
            rmsnorm(params["final_norm"], x, self.cfg.rms_eps))
        if self.cfg.tie_embeddings:
            logits = x @ params["embed"].T
        else:
            logits = x @ params["lm_head"]
        if self.padded_vocab != self.cfg.vocab_size:
            pad = jnp.arange(self.padded_vocab) >= self.cfg.vocab_size
            logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
        return act_sharding.constrain_logits(logits)

    # -- encoder (whisper): bidirectional stack over stub frame embeddings ------

    def _encode(self, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        b, t, _ = frames.shape
        # sinusoidal positions (whisper-style) over the stub embeddings
        pos = jnp.arange(t)[:, None]
        dim = jnp.arange(cfg.d_model // 2)[None, :]
        ang = pos / jnp.power(10000.0, 2 * dim / cfg.d_model)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = frames + pe[None].astype(frames.dtype)
        # bidirectional: all positions 0 -> causal bias never masks
        ctx = Ctx(mode="train", q_pos=jnp.zeros((b, t), jnp.int32))

        from repro.models.transformer import block_apply

        def body(h, p_i):
            h, _, _ = block_apply(p_i, cfg, "attn", h, ctx, None)
            return h, None

        # remat like the decoder stack: grad-of-scan must not save the
        # encoder's per-layer attention intermediates
        body = jax.checkpoint(body, prevent_cse=False,
                              policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        return rmsnorm(params["encoder"]["norm"], x, cfg.rms_eps)

    # -- sequence assembly (modality stubs) ---------------------------------------

    def _assemble(self, params: Params, inputs: Dict[str, jnp.ndarray]
                  ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        """Returns (x (B,S,D), enc_out or None)."""
        cfg = self.cfg
        x = self._embed(params, inputs["tokens"])
        enc_out = None
        if cfg.family == "vlm" and "prefix" in inputs:
            x = jnp.concatenate([inputs["prefix"].astype(x.dtype), x], axis=1)
        if cfg.is_encoder_decoder:
            enc_out = self._encode(params, inputs["frames"])
        return x, enc_out

    # -- train ---------------------------------------------------------------------

    def train_logits(self, params: Params, inputs: Dict[str, jnp.ndarray]
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        x, enc_out = self._assemble(params, inputs)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        ctx = Ctx(mode="train", q_pos=pos, enc_out=enc_out)
        x, aux, _ = stack_apply(params["stack"], self.cfg, x, ctx, None)
        return self._head(params, x), aux

    def loss(self, params: Params, batch: Dict[str, jnp.ndarray]
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """batch['tokens']: (B, S+1); model trains on next-token prediction.
        Extra keys (prefix/frames) pass through.  labels < 0 are masked."""
        tokens = batch["tokens"]
        inputs = dict(batch)
        inputs["tokens"] = tokens[:, :-1]
        labels = tokens[:, 1:]
        logits, aux = self.train_logits(params, inputs)
        # VLM: prefix positions predict nothing; trim to text tail
        if logits.shape[1] != labels.shape[1]:
            logits = logits[:, -labels.shape[1]:]
        nll = cross_entropy(logits, labels)
        total = nll + aux
        return total, {"nll": nll, "aux": aux,
                       "ppl": jnp.exp(jnp.minimum(nll, 20.0))}

    # -- serve ----------------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, page_size=None,
                   n_pages=None):
        """Dense per-row cache by default; with ``page_size`` (pageable
        architectures only) the full-attention/MLA stripes become a
        shared page pool + (B, max_pages) block table (``pages``).
        Paged caches are decode-only: admission prefills a dense B=1 row
        and scatters it into the row's allocated pages."""
        return kvcache.init_cache(self.cfg, batch, max_len,
                                  page_size=page_size, n_pages=n_pages)

    def cache_spec(self, batch: int, max_len: int, page_size=None,
                   n_pages=None):
        return kvcache.cache_spec(self.cfg, batch, max_len,
                                  page_size=page_size, n_pages=n_pages)

    def prefill(self, params: Params, inputs: Dict[str, jnp.ndarray],
                cache) -> Tuple[jnp.ndarray, Any]:
        """Run the prompt, fill the cache.  Returns (last-position logits,
        cache).  Batched serving prefills each request (B=1) and scatters
        the row into its slot, so only the last position's logits are ever
        needed.

        ``inputs`` may carry a scalar int32 ``length``: the prompt is then
        right-padded to a bucket size (serving admission pads to powers of
        two so compile count stays O(log max_len)) and only the first
        ``length`` tokens are real.  The head is read at the true last
        token and ``cache['len']`` advances by ``length``, so pad KV
        entries sit beyond the valid frontier — masked by the pos < len
        validity rule and overwritten as decode proceeds.  Full-attention
        / MLA caches only: ring (SWA) and recurrent (SSM) state would
        absorb the pads (callers gate on the refeed predicate).
        """
        x, enc_out = self._assemble(params, inputs)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        ctx = Ctx(mode="prefill", q_pos=pos, cache_len=cache["len"],
                  max_len=0, enc_out=enc_out)
        x, _, new_cache = stack_apply(params["stack"], self.cfg, x, ctx, cache)
        length = inputs.get("length")
        if length is None:
            new_cache["len"] = cache["len"] + s
            return self._head(params, x[:, -1:]), new_cache
        new_cache["len"] = cache["len"] + length
        last = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
        return self._head(params, last), new_cache

    def decode_step(self, params: Params, cache,
                    tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Any]:
        """tokens: (B, S_new).  S_new=1 for plain decode; >1 verifies a
        speculative chain in one pass.  Returns logits (B,S_new,V)."""
        x = self._embed(params, tokens)
        b, s, _ = x.shape
        ln = cache["len"]
        base = ln[:, None] if ln.ndim == 1 else ln   # (B,) ragged batch
        pos = base + jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (b, s))
        ctx = Ctx(mode="decode", q_pos=pos, cache_len=ln,
                  pages=cache.get("pages"))
        x, _, new_cache = stack_apply(params["stack"], self.cfg, x, ctx, cache)
        new_cache["len"] = cache["len"] + s
        return self._head(params, x), new_cache

    def rollback(self, cache, n_tokens: int):
        """Speculative rollback: rewind ``len`` (KV entries beyond len are
        masked by validity, so no copying).  SSM states cannot be rewound —
        the serving engine snapshots them before speculation instead."""
        out = dict(cache)
        out["len"] = cache["len"] - n_tokens
        return out

    # -- misc -----------------------------------------------------------------------

    def example_batch(self, batch: int, seq: int, rng=None) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        out = {"tokens": jax.random.randint(
            rng, (batch, seq + 1), 0, cfg.vocab_size, dtype=jnp.int32)}
        if cfg.family == "vlm":
            p = cfg.n_prefix_tokens
            out["tokens"] = out["tokens"][:, :max(2, seq + 1 - p)]
            out["prefix"] = jnp.zeros((batch, p, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
        if cfg.is_encoder_decoder:
            out["frames"] = jnp.zeros(
                (batch, cfg.encoder_seq_len, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return out


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Masked CE via one-hot contraction — vocab-sharding friendly: the
    contraction over V composes with a model-axis-sharded vocab (partial
    sums + one small all-reduce) instead of the gather formulation, which
    makes XLA SPMD all-gather the full (B,S,V) logits."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), logits.shape[-1],
                            dtype=jnp.float32)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    mask = (labels >= 0).astype(jnp.float32)
    return ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def build_model(cfg: ModelConfig) -> Model:
    cfg.check()
    return Model(cfg)
