"""Blocked (flash-style) attention in pure JAX — memory-bounded reference.

Full-sequence attention at 32k context would materialize (B, H, S, T)
scores; instead we scan over query blocks and, inside, over KV blocks with
an online-softmax accumulator, so the live intermediate is one
(B, H, q_block, kv_block) tile.  This is the jnp oracle the Pallas
``decode_attention`` kernel is validated against, and the default attention
path for train/prefill at large S.

GQA layout: q (B, S, G, Qh, D) where G = n_kv heads, Qh = n_q // n_kv;
k/v (B, T, G, D).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30


def _bias_tile(q_pos, k_pos, window, k_valid):
    """q_pos (B, qb), k_pos (B, kb) -> additive bias (B,1,1,qb,kb)."""
    ok = k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        ok &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    if k_valid is not None:
        ok &= k_valid[:, None, :]
    return jnp.where(ok, 0.0, NEG)[:, None, None, :, :].astype(jnp.float32)


def _blocks(x, n, blk):
    """(B, n*blk, ...) -> (n, B, blk, ...)"""
    b = x.shape[0]
    return x.reshape((b, n, blk) + x.shape[2:]).swapaxes(0, 1)


def _pick_blocks(s, t, q_block, kv_block):
    if s % q_block != 0 or s <= q_block:
        q_block = s
    if t % kv_block != 0 or t <= kv_block:
        kv_block = t
    return q_block, kv_block


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 7, 8))
def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                      window: Optional[int] = None,
                      k_valid: Optional[jnp.ndarray] = None,
                      q_block: int = 512, kv_block: int = 1024
                      ) -> jnp.ndarray:
    """q: (B,S,G,Qh,D); k,v: (B,T,G,D).  Returns (B,S,G,Qh,D).

    custom_vjp: the backward recomputes the probability tiles flash-style
    from the saved (out, lse) instead of differentiating through the scans
    — without this, grad-of-scan stacks every (q_block x kv_block) tile
    and training memory reverts to the full S x T attention matrix.
    """
    out, _lse = _flash_fwd_impl(q, k, v, q_pos, k_pos, window, k_valid,
                                q_block, kv_block)
    return out


def _flash_fwd_impl(q, k, v, q_pos, k_pos, window, k_valid,
                    q_block, kv_block):
    b, s, g, qh, d = q.shape
    t = k.shape[1]
    dv = v.shape[-1]
    scale = 1.0 / math.sqrt(d)
    q_block, kv_block = _pick_blocks(s, t, q_block, kv_block)
    nq, nk = s // q_block, t // kv_block

    q_t = _blocks(q, nq, q_block)
    qp_t = _blocks(q_pos, nq, q_block)
    k_t = _blocks(k, nk, kv_block)
    v_t = _blocks(v, nk, kv_block)
    kp_t = _blocks(k_pos, nk, kv_block)
    kv_valid_t = None if k_valid is None else _blocks(k_valid, nk, kv_block)

    def q_step(_, q_in):
        qb, qp = q_in                           # (B,qb,G,Qh,D), (B,qb)

        def kv_step(carry, kv_in):
            m, l, acc = carry
            if kv_valid_t is None:
                kb, vb, kp = kv_in
                kval = None
            else:
                kb, vb, kp, kval = kv_in
            sc = jnp.einsum("bsgqd,btgd->bgqst", qb, kb) * scale
            sc = sc.astype(jnp.float32) + _bias_tile(qp, kp, window, kval)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgqst,btgd->bgqsd", p.astype(qb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, g, qh, q_block), NEG, jnp.float32)
        l0 = jnp.zeros((b, g, qh, q_block), jnp.float32)
        a0 = jnp.zeros((b, g, qh, q_block, dv), jnp.float32)
        xs = (k_t, v_t, kp_t) if kv_valid_t is None else \
            (k_t, v_t, kp_t, kv_valid_t)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), xs)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))        # (B,G,Qh,qb)
        return None, (out.transpose(0, 3, 1, 2, 4).astype(q.dtype), lse)

    _, (out_blocks, lse_blocks) = jax.lax.scan(q_step, None, (q_t, qp_t))
    out = out_blocks.swapaxes(0, 1).reshape(b, s, g, qh, dv)
    # lse: (nq, B, G, Qh, qb) -> (B, G, Qh, S)
    lse = lse_blocks.transpose(1, 2, 3, 0, 4).reshape(b, g, qh, s)
    return out, lse


def _flash_fwd(q, k, v, q_pos, k_pos, window, k_valid, q_block, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, k_pos, window, k_valid,
                               q_block, kv_block)
    return out, (q, k, v, q_pos, k_pos, k_valid, out, lse)


def _flash_bwd(window, q_block, kv_block, res, dout):
    q, k, v, q_pos, k_pos, k_valid, out, lse = res
    b, s, g, qh, d = q.shape
    t = k.shape[1]
    dvd = v.shape[-1]
    scale = 1.0 / math.sqrt(d)
    q_block, kv_block = _pick_blocks(s, t, q_block, kv_block)
    nq, nk = s // q_block, t // kv_block

    # delta_i = rowsum(dO_i * O_i)  — flash-2 backward
    delta = jnp.einsum("bsgqd,bsgqd->bgqs", dout.astype(jnp.float32),
                       out.astype(jnp.float32))

    q_t = _blocks(q, nq, q_block)
    qp_t = _blocks(q_pos, nq, q_block)
    do_t = _blocks(dout, nq, q_block)
    k_t = _blocks(k, nk, kv_block)
    v_t = _blocks(v, nk, kv_block)
    kp_t = _blocks(k_pos, nk, kv_block)
    kv_valid_t = None if k_valid is None else _blocks(k_valid, nk, kv_block)
    lse_t = lse.reshape(b, g, qh, nq, q_block).transpose(3, 0, 1, 2, 4)
    del_t = delta.reshape(b, g, qh, nq, q_block).transpose(3, 0, 1, 2, 4)

    def q_step(carry, q_in):
        dk_acc, dv_acc = carry                  # (nk,B,kb,G,D) fp32
        qb, qp, dob, lse_i, del_i = q_in

        def kv_step(_, kv_in):
            if kv_valid_t is None:
                kb, vb, kp = kv_in
                kval = None
            else:
                kb, vb, kp, kval = kv_in
            sc = jnp.einsum("bsgqd,btgd->bgqst", qb, kb) * scale
            sc = sc.astype(jnp.float32) + _bias_tile(qp, kp, window, kval)
            p = jnp.exp(sc - lse_i[..., None])               # (B,G,Qh,qb,kb)
            dv_j = jnp.einsum("bgqst,bsgqd->btgd", p,
                              dob.astype(jnp.float32))
            dp = jnp.einsum("bsgqd,btgd->bgqst",
                            dob.astype(jnp.float32),
                            vb.astype(jnp.float32))
            ds = p * (dp - del_i[..., None]) * scale
            dq_ij = jnp.einsum("bgqst,btgd->bsgqd", ds,
                               kb.astype(jnp.float32))
            dk_j = jnp.einsum("bgqst,bsgqd->btgd", ds,
                              qb.astype(jnp.float32))
            return None, (dq_ij, dk_j, dv_j)

        xs = (k_t, v_t, kp_t) if kv_valid_t is None else \
            (k_t, v_t, kp_t, kv_valid_t)
        _, (dq_stack, dk_stack, dv_stack) = jax.lax.scan(kv_step, None, xs)
        dq_i = dq_stack.sum(axis=0)
        return (dk_acc + dk_stack, dv_acc + dv_stack), dq_i

    dk0 = jnp.zeros((nk, b, kv_block, g, d), jnp.float32)
    dv0 = jnp.zeros((nk, b, kv_block, g, v.shape[-1]), jnp.float32)
    (dk_b, dv_b), dq_blocks = jax.lax.scan(
        q_step, (dk0, dv0), (q_t, qp_t, do_t, lse_t, del_t))
    dq = dq_blocks.swapaxes(0, 1).reshape(b, s, g, qh, d).astype(q.dtype)
    dk = dk_b.swapaxes(0, 1).reshape(b, t, g, d).astype(k.dtype)
    dv = dv_b.swapaxes(0, 1).reshape(b, t, g, dvd).astype(v.dtype)
    zero_valid = None if k_valid is None else _int_zero(k_valid)
    return dq, dk, dv, _int_zero(q_pos), _int_zero(k_pos), zero_valid


def _int_zero(x):
    import numpy as _np
    return _np.zeros(x.shape, jax.dtypes.float0)


blocked_attention.defvjp(_flash_fwd, _flash_bwd)


def naive_attention(q, k, v, q_pos, k_pos, window=None, k_valid=None):
    """Unblocked oracle (small shapes / decode)."""
    b, s, g, qh, d = q.shape
    scale = 1.0 / math.sqrt(d)
    sc = jnp.einsum("bsgqd,btgd->bgqst", q, k) * scale
    bias = _bias_tile(q_pos, k_pos, window, k_valid)
    probs = jax.nn.softmax(sc.astype(jnp.float32) + bias, axis=-1)
    out = jnp.einsum("bgqst,btgd->bsgqd", probs.astype(q.dtype), v)
    return out


def attention_any(q, k, v, q_pos, k_pos, window=None, k_valid=None,
                  blocked_threshold: int = 1024):
    """Dispatch: blocked for long sequences, naive for short/decode."""
    s, t = q.shape[1], k.shape[1]
    if s * t >= blocked_threshold * blocked_threshold:
        return blocked_attention(q, k, v, q_pos, k_pos, window, k_valid)
    return naive_attention(q, k, v, q_pos, k_pos, window, k_valid)
