"""Block assembly: every architecture family as a composition of typed
blocks, executed as head-blocks + lax.scan over a repeated (possibly
heterogeneous) group + tail-blocks.

Scanning over stacked layer params keeps compile time and HLO size flat in
depth (62-layer gemma3 compiles as one 6-block group x 10 reps), which is
what makes the 40-combination dry-run tractable; it is also the layout the
sharding rules expect (leading ``reps`` axis unsharded).

Block kinds:
  attn | swa          GQA transformer block (full / sliding-window)
  mla                 DeepSeek multi-head latent attention block
  moe                 MoE-FFN block (attention = mla if cfg.mla else GQA)
  mamba1 | mamba2     SSM blocks
  shared_attn         zamba2 shared-weight attention block (params shared
                      across invocations; per-invocation KV cache)
  xattn               encoder-decoder decoder block (self + cross attn)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import act_sharding, ssm
from repro.models.flash import attention_any
from repro.models.layers import (attention_init, mla_apply,
                                 mla_apply_absorbed, mla_compress,
                                 mla_init, mlp_apply, mlp_init, moe_apply,
                                 moe_init, rmsnorm, rmsnorm_init, rope,
                                 _split_heads, dense_init)

Params = Dict[str, Any]


@dataclasses.dataclass
class Ctx:
    mode: str                      # 'train' | 'prefill' | 'decode'
    q_pos: jnp.ndarray             # (B, S)
    cache_len: Optional[jnp.ndarray] = None   # scalar int32, or (B,) for
    max_len: int = 0                          # per-request batched serving
    enc_out: Optional[jnp.ndarray] = None     # (B, T_enc, D) for xattn
    # paged KV serving: (B, max_pages) int32 block table — position p of
    # row b lives at pool row pages[b, p // ps], offset p % ps, where ps
    # is the (static) second axis of the pool leaves
    pages: Optional[jnp.ndarray] = None

    @property
    def ragged(self) -> bool:
        return self.cache_len is not None and self.cache_len.ndim == 1

    @property
    def paged(self) -> bool:
        return self.pages is not None


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def block_init(rng, cfg: ModelConfig, kind: str) -> Params:
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 6)
    if kind in ("attn", "swa", "shared_attn"):
        return {
            "norm1": rmsnorm_init(d, dt),
            "attn": attention_init(ks[0], cfg),
            "norm2": rmsnorm_init(d, dt),
            "mlp": mlp_init(ks[1], d, cfg.d_ff, dt),
        }
    if kind == "mla":
        return {
            "norm1": rmsnorm_init(d, dt),
            "mla": mla_init(ks[0], cfg),
            "norm2": rmsnorm_init(d, dt),
            "mlp": mlp_init(ks[1], d, cfg.d_ff, dt),
        }
    if kind == "moe":
        p: Params = {"norm1": rmsnorm_init(d, dt),
                     "norm2": rmsnorm_init(d, dt),
                     "moe": moe_init(ks[1], cfg)}
        if cfg.mla is not None:
            p["mla"] = mla_init(ks[0], cfg)
        else:
            p["attn"] = attention_init(ks[0], cfg)
        return p
    if kind == "mamba1":
        return {"norm": rmsnorm_init(d, dt), "mamba": ssm.mamba1_init(ks[0], cfg)}
    if kind == "mamba2":
        return {"norm": rmsnorm_init(d, dt), "mamba": ssm.mamba2_init(ks[0], cfg)}
    if kind == "xattn":
        return {
            "norm1": rmsnorm_init(d, dt),
            "attn": attention_init(ks[0], cfg),
            "norm_x": rmsnorm_init(d, dt),
            "xattn": attention_init(ks[1], cfg),
            "norm2": rmsnorm_init(d, dt),
            "mlp": mlp_init(ks[2], d, cfg.d_ff, dt),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# attention with cache plumbing
# ---------------------------------------------------------------------------


def _self_attention(p: Params, cfg: ModelConfig, xn: jnp.ndarray, ctx: Ctx,
                    cache: Optional[Params], window: Optional[int]
                    ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Returns (attn_out (B,S,D), updated cache)."""
    nq, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    b, s, _ = xn.shape
    q = _split_heads(xn @ p["wq"], nq, dh)
    q = rope(q, ctx.q_pos, cfg.rope_theta)
    k_new = _split_heads(xn @ p["wk"], nkv, dh)
    k_new = rope(k_new, ctx.q_pos, cfg.rope_theta)
    v_new = _split_heads(xn @ p["wv"], nkv, dh)
    qg = q.reshape(b, s, nkv, nq // nkv, dh)

    new_cache = cache
    if ctx.mode == "train" or cache is None:
        out = attention_any(qg, k_new, v_new, ctx.q_pos, ctx.q_pos, window)
    elif ctx.mode == "prefill":
        out = attention_any(qg, k_new, v_new, ctx.q_pos, ctx.q_pos, window)
        new_cache = _write_kv(cache, cfg, k_new, v_new, ctx, window)
    else:  # decode
        if window is None:
            new_cache = _write_kv(cache, cfg, k_new, v_new, ctx, window)
            k_all, v_all = _read_kv(new_cache, xn.dtype)
            if cfg.use_pallas_kernels:
                # fused ragged flash-decode: q (B,S,G,Qh,D) vs cache
                # (B,T,G,D) — or the (n_pages,ps,G,D) pool streamed
                # through the block table when paged; per-row lengths and
                # the S>1 speculative verify window (causal offsets) are
                # handled in-kernel, so the batched serving path never
                # takes the dense read
                from repro.kernels.decode_attention.ops import \
                    decode_attention
                out = decode_attention(qg, k_all, v_all, ctx.cache_len + 1,
                                       block_tables=ctx.pages)
            else:
                if ctx.paged:
                    from repro.kernels.decode_attention.ref import \
                        gather_pages
                    k_all = gather_pages(k_all, ctx.pages)
                    v_all = gather_pages(v_all, ctx.pages)
                t = k_all.shape[1]
                k_pos = jnp.broadcast_to(
                    jnp.arange(t, dtype=jnp.int32), (b, t))
                lim = (ctx.cache_len[:, None] if ctx.ragged
                       else ctx.cache_len) + s
                k_valid = k_pos < lim
                out = attention_any(qg, k_all, v_all,
                                    ctx.q_pos, k_pos, window, k_valid)
        else:
            # Ring buffer: with S_new > 1 (speculative verification) the new
            # writes may evict entries that earlier queries of this very step
            # still see, so attend over [old ring || new keys] THEN write.
            k_old, v_old = _read_kv(cache, xn.dtype)
            k_all = jnp.concatenate([k_old, k_new], axis=1)
            v_all = jnp.concatenate([v_old, v_new], axis=1)
            k_pos = jnp.concatenate([cache["pos"], ctx.q_pos], axis=1)
            k_valid = k_pos >= 0
            out = attention_any(qg, k_all, v_all, ctx.q_pos, k_pos,
                                window, k_valid)
            new_cache = _write_kv(cache, cfg, k_new, v_new, ctx, window)
    out = out.reshape(b, s, nq * dh) @ p["wo"]
    return out, new_cache


def _quantize_kv(x: jnp.ndarray):
    """(B,S,H,D) -> int8 values + (B,S,H) bf16 scales (per token, head)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def _page_translate(ctx: Ctx, b: int, s: int, page_size: int):
    """(pool row, in-page offset) index pair, both (B, S), for the S new
    tokens each row writes at positions cache_len[b]..cache_len[b]+S-1.
    Vacant table entries (<= 0) AND positions past the table's capacity
    resolve to pool row 0, the reserved trash page: padded rows of a
    batched decode and writes beyond max_len land somewhere harmless
    (the dense layout's equivalent is the scatter dropping OOB indices —
    clamping to the last table column would corrupt the row's newest
    live page instead)."""
    ln = ctx.cache_len
    ln_b = ln[:, None] if ctx.ragged else jnp.full((b, 1), ln, jnp.int32)
    pos = ln_b + jnp.arange(s, dtype=jnp.int32)[None, :]        # (B, S)
    tbl = jnp.maximum(ctx.pages, 0)                             # (B, MP)
    pidx = pos // page_size
    prow = jnp.take_along_axis(
        tbl, jnp.minimum(pidx, tbl.shape[1] - 1), axis=1)
    prow = jnp.where(pidx >= tbl.shape[1], 0, prow)
    return prow, pos % page_size


def _write_kv(cache: Params, cfg: ModelConfig, k: jnp.ndarray,
              v: jnp.ndarray, ctx: Ctx, window: Optional[int]) -> Params:
    b, s = k.shape[:2]
    ln = ctx.cache_len
    quant = "k_scale" in cache
    if quant:
        k, k_sc = _quantize_kv(k)
        v, v_sc = _quantize_kv(v)
    if ctx.paged and window is None:
        # paged pool: scatter each row's S new tokens through its block
        # table (rows own disjoint pages, so index pairs never collide
        # across live rows)
        prow, poff = _page_translate(ctx, b, s, cache["k"].shape[1])
        out = dict(cache)
        out["k"] = cache["k"].at[prow, poff].set(k)
        out["v"] = cache["v"].at[prow, poff].set(v)
        if quant:
            out["k_scale"] = cache["k_scale"].at[prow, poff].set(k_sc)
            out["v_scale"] = cache["v_scale"].at[prow, poff].set(v_sc)
        return out
    if window is None or "pos" not in cache:
        out = dict(cache)
        if ctx.ragged:
            # per-request write offsets (batched serving): scatter rows
            rows = jnp.arange(b, dtype=jnp.int32)[:, None]
            idx = ln[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
            out["k"] = cache["k"].at[rows, idx].set(k)
            out["v"] = cache["v"].at[rows, idx].set(v)
            if quant:
                out["k_scale"] = cache["k_scale"].at[rows, idx].set(k_sc)
                out["v_scale"] = cache["v_scale"].at[rows, idx].set(v_sc)
            return out
        out["k"] = jax.lax.dynamic_update_slice(cache["k"], k, (0, ln, 0, 0))
        out["v"] = jax.lax.dynamic_update_slice(cache["v"], v, (0, ln, 0, 0))
        if quant:
            out["k_scale"] = jax.lax.dynamic_update_slice(
                cache["k_scale"], k_sc, (0, ln, 0))
            out["v_scale"] = jax.lax.dynamic_update_slice(
                cache["v_scale"], v_sc, (0, ln, 0))
        return out
    # Ring write, per-row: rows of a ragged batch sit at different sequence
    # positions, so write offsets and the slot->position map are (B, ...).
    w = cache["k"].shape[1]
    n = min(s, w)                      # only the last w tokens can survive
    ln_b = ln[:, None] if ctx.ragged else jnp.full((b, 1), ln, jnp.int32)
    pos_val = ln_b + (s - n) + jnp.arange(n, dtype=jnp.int32)[None, :]
    idx = pos_val % w                  # (B, n) per-row ring slots
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    out = dict(cache)
    out["k"] = cache["k"].at[rows, idx].set(k[:, -n:])
    out["v"] = cache["v"].at[rows, idx].set(v[:, -n:])
    if quant:
        out["k_scale"] = cache["k_scale"].at[rows, idx].set(k_sc[:, -n:])
        out["v_scale"] = cache["v_scale"].at[rows, idx].set(v_sc[:, -n:])
    out["pos"] = cache["pos"].at[rows, idx].set(pos_val)
    return out


def _read_kv(cache: Params, dtype):
    """Cache k/v in compute dtype (dequantizing int8 caches inline)."""
    if "k_scale" in cache:
        return (_dequantize_kv(cache["k"], cache["k_scale"], dtype),
                _dequantize_kv(cache["v"], cache["v_scale"], dtype))
    return cache["k"], cache["v"]


def _cross_attention(p: Params, cfg: ModelConfig, xn: jnp.ndarray, ctx: Ctx,
                     cache: Optional[Params]) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Decoder->encoder attention.  No rope, no causal mask."""
    nq, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    b, s, _ = xn.shape
    q = _split_heads(xn @ p["wq"], nq, dh).reshape(b, s, nkv, nq // nkv, dh)
    new_cache = cache
    if ctx.mode in ("train", "prefill") and ctx.enc_out is not None:
        xk = _split_heads(ctx.enc_out @ p["wk"], nkv, dh)
        xv = _split_heads(ctx.enc_out @ p["wv"], nkv, dh)
        if cache is not None:
            new_cache = dict(cache)
            new_cache.update(xk=xk, xv=xv)
    else:
        xk, xv = cache["xk"], cache["xv"]
    t = xk.shape[1]
    ones_q = jnp.zeros((b, s), jnp.int32)
    ones_k = jnp.zeros((b, t), jnp.int32)  # pos 0 everywhere = no masking
    out = attention_any(q, xk, xv, ones_q, ones_k, None, None)
    return out.reshape(b, s, nq * dh) @ p["wo"], new_cache


def _mla_attention(p: Params, cfg: ModelConfig, xn: jnp.ndarray, ctx: Ctx,
                   cache: Optional[Params]) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, _ = xn.shape
    c_kv, k_rope = mla_compress(p, cfg, xn, ctx.q_pos)
    new_cache = cache
    if ctx.mode == "train" or cache is None:
        out = mla_apply(p, cfg, xn, ctx.q_pos, (c_kv, k_rope), ctx.q_pos)
    elif ctx.mode == "prefill":
        out = mla_apply(p, cfg, xn, ctx.q_pos, (c_kv, k_rope), ctx.q_pos)
        new_cache = dict(cache)
        new_cache["ckv"] = jax.lax.dynamic_update_slice(
            cache["ckv"], c_kv, (0, ctx.cache_len, 0))
        new_cache["krope"] = jax.lax.dynamic_update_slice(
            cache["krope"], k_rope, (0, ctx.cache_len, 0, 0))
    else:
        new_cache = dict(cache)
        if ctx.paged:
            # paged latent pool: scatter through the block table
            prow, poff = _page_translate(ctx, b, s, cache["ckv"].shape[1])
            new_cache["ckv"] = cache["ckv"].at[prow, poff].set(c_kv)
            new_cache["krope"] = cache["krope"].at[prow, poff].set(k_rope)
        elif ctx.ragged:
            rows = jnp.arange(b, dtype=jnp.int32)[:, None]
            idx = ctx.cache_len[:, None] + \
                jnp.arange(s, dtype=jnp.int32)[None, :]
            new_cache["ckv"] = cache["ckv"].at[rows, idx].set(c_kv)
            new_cache["krope"] = cache["krope"].at[rows, idx].set(k_rope)
        else:
            new_cache["ckv"] = jax.lax.dynamic_update_slice(
                cache["ckv"], c_kv, (0, ctx.cache_len, 0))
            new_cache["krope"] = jax.lax.dynamic_update_slice(
                cache["krope"], k_rope, (0, ctx.cache_len, 0, 0))
        if cfg.use_pallas_kernels:
            # fused ragged latent read (per-row lengths, causal window;
            # paged pools stream through the block table)
            out = mla_apply_absorbed(p, cfg, xn, ctx.q_pos,
                                     (new_cache["ckv"], new_cache["krope"]),
                                     None, None,
                                     lengths=ctx.cache_len + 1,
                                     block_tables=ctx.pages)
        else:
            ckv_r, krope_r = new_cache["ckv"], new_cache["krope"]
            if ctx.paged:
                from repro.kernels.decode_attention.ref import gather_pages
                ckv_r = gather_pages(ckv_r, ctx.pages)
                krope_r = gather_pages(krope_r, ctx.pages)
            t = ckv_r.shape[1]
            k_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
            lim = (ctx.cache_len[:, None] if ctx.ragged
                   else ctx.cache_len) + s
            k_valid = k_pos < lim
            out = mla_apply_absorbed(p, cfg, xn, ctx.q_pos,
                                     (ckv_r, krope_r),
                                     k_pos, k_valid)
    return out, new_cache


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------


def block_apply(p: Params, cfg: ModelConfig, kind: str, x: jnp.ndarray,
                ctx: Ctx, cache: Optional[Params]
                ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Params]]:
    """Returns (x, aux_loss, new_cache)."""
    # NB: no with_sharding_constraint here — inside the remat'd scan body a
    # constraint becomes a save-point and doubles activation memory (saved
    # f32 copies).  Batch sharding is pinned at the embedding/head
    # boundaries instead (model.py) and propagates through the scan.
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "swa", "shared_attn"):
        window = cfg.sliding_window if kind == "swa" else None
        a, cache = _self_attention(p["attn"], cfg,
                                   rmsnorm(p["norm1"], x, cfg.rms_eps),
                                   ctx, cache, window)
        x = x + a
        x = x + mlp_apply(p["mlp"], rmsnorm(p["norm2"], x, cfg.rms_eps))
        return x, aux, cache
    if kind == "mla":
        a, cache = _mla_attention(p["mla"], cfg,
                                  rmsnorm(p["norm1"], x, cfg.rms_eps),
                                  ctx, cache)
        x = x + a
        x = x + mlp_apply(p["mlp"], rmsnorm(p["norm2"], x, cfg.rms_eps))
        return x, aux, cache
    if kind == "moe":
        xn = rmsnorm(p["norm1"], x, cfg.rms_eps)
        if cfg.mla is not None:
            a, cache = _mla_attention(p["mla"], cfg, xn, ctx, cache)
        else:
            a, cache = _self_attention(p["attn"], cfg, xn, ctx, cache, None)
        x = x + a
        h, aux = moe_apply(p["moe"], cfg, rmsnorm(p["norm2"], x, cfg.rms_eps))
        return x + h, aux, cache
    if kind in ("mamba1", "mamba2"):
        xn = rmsnorm(p["norm"], x, cfg.rms_eps)
        conv_st = cache["conv"] if cache is not None else None
        ssm_st = cache["ssm"] if cache is not None else None
        if ctx.mode == "train":
            conv_st = ssm_st = None
        fn = ssm.mamba1_apply if kind == "mamba1" else ssm.mamba2_apply
        y, (new_conv, new_ssm) = fn(p["mamba"], cfg, xn, conv_st, ssm_st)
        new_cache = None if cache is None else {"conv": new_conv,
                                                "ssm": new_ssm}
        return x + y, aux, new_cache
    if kind == "xattn":
        a, cache = _self_attention(p["attn"], cfg,
                                   rmsnorm(p["norm1"], x, cfg.rms_eps),
                                   ctx, cache, None)
        x = x + a
        c, cache = _cross_attention(p["xattn"], cfg,
                                    rmsnorm(p["norm_x"], x, cfg.rms_eps),
                                    ctx, cache)
        x = x + c
        x = x + mlp_apply(p["mlp"], rmsnorm(p["norm2"], x, cfg.rms_eps))
        return x, aux, cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack: head blocks + scanned group + tail blocks
# ---------------------------------------------------------------------------


def stack_init(rng, cfg: ModelConfig) -> Params:
    head, reps, group, tail = cfg.layer_program
    ks = iter(jax.random.split(rng, len(head) + len(tail) + len(group) + 2))
    params: Params = {
        "head": [block_init(next(ks), cfg, k) for k in head],
        "tail": [block_init(next(ks), cfg, k) for k in tail],
    }
    if "shared_attn" in group + head + tail:
        params["shared_attn"] = block_init(next(ks), cfg, "shared_attn")

    def stacked_block(rng_b, kind):
        if kind == "shared_attn":
            return {}  # weights live in params['shared_attn']
        inits = [block_init(r, cfg, kind)
                 for r in jax.random.split(rng_b, reps)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *inits)

    params["group"] = {f"b{i}": stacked_block(next(ks), k)
                      for i, k in enumerate(group)}
    return params


def stack_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray, ctx: Ctx,
                cache: Optional[Params]
                ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Params]]:
    head, reps, group, tail = cfg.layer_program
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Optional[Params] = None if cache is None else dict(cache)

    for i, kind in enumerate(head):
        c = cache["head"][i] if cache is not None else None
        x, aux, nc = block_apply(params["head"][i], cfg, kind, x, ctx, c)
        aux_total += aux
        if cache is not None:
            new_cache["head"] = list(new_cache["head"])
            new_cache["head"][i] = nc

    shared = params.get("shared_attn")

    if reps > 0:
        if cache is not None:
            # The stacked group cache rides in the scan CARRY and is updated
            # with dynamic_update_index_in_dim — the while-loop in-place
            # pattern XLA aliases with the donated input buffer (cache in
            # xs/ys would materialize a second full-size cache).
            def body(carry, xs):
                h, aux, gcache = carry
                p_i, idx = xs
                for j, kind in enumerate(group):
                    pj = shared if kind == "shared_attn" else p_i[f"b{j}"]
                    cj = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, idx, 0, keepdims=False), gcache[f"b{j}"])
                    h, a, nc = block_apply(pj, cfg, kind, h, ctx, cj)
                    aux = aux + a
                    gcache = dict(gcache)
                    gcache[f"b{j}"] = jax.tree.map(
                        lambda full, new: jax.lax.dynamic_update_index_in_dim(
                            full, new.astype(full.dtype), idx, 0),
                        gcache[f"b{j}"], nc)
                return (h, aux, gcache), None

            (x, aux_total, group_cache), _ = jax.lax.scan(
                body, (x, aux_total, cache["group"]),
                (params["group"], jnp.arange(reps, dtype=jnp.int32)))
            new_cache["group"] = group_cache
        else:
            def body_nc(carry, p_i):
                h, aux = carry
                for j, kind in enumerate(group):
                    pj = shared if kind == "shared_attn" else p_i[f"b{j}"]
                    h, a, _ = block_apply(pj, cfg, kind, h, ctx, None)
                    aux = aux + a
                return (h, aux), None

            # prevent_cse=False: scan's loop structure already prevents CSE;
            # the default barrier makes XLA keep an extra f32 copy of the
            # carried activation per layer (~2x saved-activation memory).
            remat_body = jax.checkpoint(
                body_nc, prevent_cse=False,
                policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux_total), _ = jax.lax.scan(
                remat_body, (x, aux_total), params["group"])

    for i, kind in enumerate(tail):
        c = cache["tail"][i] if cache is not None else None
        x, aux, nc = block_apply(params["tail"][i], cfg, kind, x, ctx, c)
        aux_total += aux
        if cache is not None:
            new_cache["tail"] = list(new_cache["tail"])
            new_cache["tail"][i] = nc

    return x, aux_total, new_cache
