"""Core transformer layers: norms, RoPE, attention (GQA / SWA / MLA),
dense MLP and MoE — functional style (param dicts in, arrays out).

Conventions:
 - params are nested dicts of jnp arrays; init fns take an ``rng`` and
   config and return the dict; apply fns mirror them.
 - activations are ``cfg.dtype`` (bf16 at full scale), reductions
   (softmax/norm/router) in float32.
 - attention is exposed in three entry modes: full-sequence causal
   (train/prefill), and single/multi-token decode against a KV cache
   (multi-token = speculative verification, §3.6 of the paper).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

Params = Dict[str, jnp.ndarray]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(rng, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(rng, shape, dtype=jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    # Variance in f32, but the (B,S,D)-sized products stay in x.dtype: a
    # full-width f32 intermediate here becomes the residual XLA saves per
    # scanned layer under remat (2x activation memory at bf16 training).
    var = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]     # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window)
# ---------------------------------------------------------------------------


def attention_init(rng, cfg: ModelConfig) -> Params:
    d, dh = cfg.d_model, cfg.d_head
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], (d, nq * dh), dtype=dt),
        "wk": dense_init(ks[1], (d, nkv * dh), dtype=dt),
        "wv": dense_init(ks[2], (d, nkv * dh), dtype=dt),
        "wo": dense_init(ks[3], (nq * dh, d), dtype=dt),
    }


def _split_heads(x, n_heads, d_head):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, d_head)


def _gqa_scores_and_out(q, k, v, bias):
    """q: (B,S,nq,D), k/v: (B,T,nkv,D), bias: (B,1,1,S,T) additive.

    Grouped-query attention: nq = G*Q where G = nkv.
    """
    b, s, nq, d = q.shape
    t, nkv = k.shape[1], k.shape[2]
    qg = q.reshape(b, s, nkv, nq // nkv, d)
    scores = jnp.einsum("bsgqd,btgd->bgqst", qg, k) / math.sqrt(d)
    scores = scores.astype(jnp.float32) + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgqst,btgd->bsgqd", probs, v)
    return out.reshape(b, s, nq, d)


def causal_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                window: Optional[int] = None,
                k_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Additive attention bias.

    q_pos: (B, S) absolute positions of queries; k_pos: (B, T) of keys.
    window: sliding-window size (None = full causal).
    k_valid: (B, T) bool — False for unwritten cache slots.
    Returns (B, 1, 1, S, T) float32.
    """
    ok = k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        ok &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    if k_valid is not None:
        ok &= k_valid[:, None, :]
    return jnp.where(ok, 0.0, -1e30)[:, None, None, :, :].astype(jnp.float32)


def attention_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                    q_pos: jnp.ndarray,
                    kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    k_pos: Optional[jnp.ndarray] = None,
                    window: Optional[int] = None,
                    k_valid: Optional[jnp.ndarray] = None,
                    cross: bool = False) -> jnp.ndarray:
    """x: (B,S,D).  If kv given, attend to it (decode / cross-attention);
    else self-attention over x.  Returns (B,S,D)."""
    nq, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = _split_heads(x @ params["wq"], nq, dh)
    if kv is None:
        k = _split_heads(x @ params["wk"], nkv, dh)
        v = _split_heads(x @ params["wv"], nkv, dh)
        k_pos = q_pos
    else:
        k, v = kv
    if not cross:
        q = rope(q, q_pos, cfg.rope_theta)
        if kv is None:
            k = rope(k, k_pos, cfg.rope_theta)
    if cross:
        bias = jnp.zeros((), jnp.float32) if k_valid is None else \
            jnp.where(k_valid, 0.0, -1e30)[:, None, None, None, :]
    else:
        bias = causal_bias(q_pos, k_pos, window, k_valid)
    out = _gqa_scores_and_out(q, k, v, bias)
    b, s = out.shape[:2]
    return out.reshape(b, s, nq * dh) @ params["wo"]


def attention_project_kv(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                         k_pos: Optional[jnp.ndarray] = None,
                         use_rope: bool = True):
    """Project k/v for cache writes.  x: (B,S,D) -> k,v: (B,S,nkv,dh)."""
    nkv, dh = cfg.n_kv_heads, cfg.d_head
    k = _split_heads(x @ params["wk"], nkv, dh)
    v = _split_heads(x @ params["wv"], nkv, dh)
    if use_rope and k_pos is not None:
        k = rope(k, k_pos, cfg.rope_theta)
    return k, v


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V3)
# ---------------------------------------------------------------------------


def mla_init(rng, cfg: ModelConfig) -> Params:
    m = cfg.mla
    d, nq = cfg.d_model, cfg.n_heads
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 8)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype=dt),
        "q_norm": rmsnorm_init(m.q_lora_rank, dt),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, nq * qk_dim), dtype=dt),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            dtype=dt),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dt),
        "wkv_b": dense_init(
            ks[3], (m.kv_lora_rank, nq * (m.qk_nope_head_dim + m.v_head_dim)),
            dtype=dt),
        "wo": dense_init(ks[4], (nq * m.v_head_dim, d), dtype=dt),
    }


def mla_compress(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                 k_pos: jnp.ndarray):
    """The cached latent: compressed kv (B,S,r) + rope key (B,S,1,dr)."""
    m = cfg.mla
    kv = x @ params["wkv_a"]
    c_kv, k_rope = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.rms_eps)
    k_rope = rope(k_rope[:, :, None, :], k_pos, cfg.rope_theta)
    return c_kv, k_rope


def _mla_q(params: Params, cfg: ModelConfig, x, q_pos):
    m = cfg.mla
    nq = cfg.n_heads
    b, s, _ = x.shape
    q = rmsnorm(params["q_norm"], x @ params["wq_a"], cfg.rms_eps) \
        @ params["wq_b"]
    q = q.reshape(b, s, nq, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, q_pos, cfg.rope_theta)
    return q_nope, q_rope


def _mla_uk_uv(params: Params, cfg: ModelConfig):
    m = cfg.mla
    w = params["wkv_b"].reshape(
        m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim)
    return w[..., :m.qk_nope_head_dim], w[..., m.qk_nope_head_dim:]


def mla_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray,
              q_pos: jnp.ndarray, latent: Tuple[jnp.ndarray, jnp.ndarray],
              k_pos: jnp.ndarray,
              k_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full (non-absorbed) MLA: materializes per-head K/V from the latent
    and runs blocked flash attention.  Used for train/prefill where S ~ T
    and the K/V materialization is the same order as the activations.

    x: (B,S,D); latent = (c_kv (B,T,r), k_rope (B,T,1,dr)).
    """
    from repro.models.flash import attention_any  # local: avoid cycle
    m = cfg.mla
    nq = cfg.n_heads
    b, s, _ = x.shape
    c_kv, k_rope = latent
    t = c_kv.shape[1]
    q_nope, q_rope = _mla_q(params, cfg, x, q_pos)
    kvb = (c_kv @ params["wkv_b"]).reshape(
        b, t, nq, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kvb[..., :m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim:]
    # assemble MHA layout (G=nq, Qh=1) with concatenated nope||rope dims
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :] \
        .transpose(0, 1, 2, 3, 4)                       # (B,S,H,1,dn+dr)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, t, nq, m.qk_rope_head_dim))],
        axis=-1)                                         # (B,T,H,dn+dr)
    q_full = q_full.reshape(b, s, nq, 1, m.qk_nope_head_dim
                            + m.qk_rope_head_dim)
    out = attention_any(q_full, k_full, v, q_pos, k_pos, None, k_valid)
    out = out.reshape(b, s, nq * m.v_head_dim)
    return out @ params["wo"]


def mla_apply_absorbed(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                       q_pos: jnp.ndarray,
                       latent: Tuple[jnp.ndarray, jnp.ndarray],
                       k_pos: jnp.ndarray,
                       k_valid: Optional[jnp.ndarray] = None,
                       lengths: Optional[jnp.ndarray] = None,
                       block_tables: Optional[jnp.ndarray] = None
                       ) -> jnp.ndarray:
    """Absorbed MLA decode (DeepSeek-V3): W_uk folds into the query and
    W_uv into the output, so attention runs directly against the compressed
    (B,T,r) latent — the whole point of MLA's small cache.  Never
    materializes per-head K/V of the context.

    With ``lengths`` set and ``cfg.use_pallas_kernels``, the latent read
    runs through the fused ragged flash-decode kernel: one KV group whose
    score splits into latent (q_lat . c_kv) + rope (q_rope . k_rope)
    terms and whose values are the latent itself (Dv = r) — the cache
    buffers stream tile-by-tile exactly as stored, no per-step O(T) key
    concatenation; same per-row lengths / causal window semantics as the
    GQA path.  With ``block_tables`` the latent/rope operands are paged
    pools (n_pages, ps, ...) streamed through each row's table.
    """
    m = cfg.mla
    nq = cfg.n_heads
    b, s, _ = x.shape
    c_kv, k_rope = latent
    t = c_kv.shape[1]
    q_nope, q_rope = _mla_q(params, cfg, x, q_pos)
    w_uk, w_uv = _mla_uk_uv(params, cfg)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)    # (B,S,H,r)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    if lengths is not None and cfg.use_pallas_kernels:
        from repro.kernels.decode_attention.ops import \
            decode_attention  # local: avoid cycle
        # k == v == the latent cache itself; k_rope rides as the split
        # (q2, k2) score term — axis inserts are views, nothing O(T) is
        # materialized per step
        ctx_lat = decode_attention(
            q_lat[:, :, None], c_kv[:, :, None], c_kv[:, :, None],
            lengths, scale=scale,
            q2=q_rope[:, :, None], k2=k_rope,
            block_tables=block_tables)[:, :, 0]
        ctx_lat = ctx_lat.astype(x.dtype)                  # (B,S,H,r)
    else:
        scores = (jnp.einsum("bshr,btr->bhst", q_lat, c_kv)
                  + jnp.einsum("bshd,btd->bhst", q_rope, k_rope[:, :, 0]))
        scores = scores.astype(jnp.float32) * scale
        bias = causal_bias(q_pos, k_pos, None, k_valid)[:, :, 0]  # (B,1,S,T)
        probs = jax.nn.softmax(scores + bias, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv)    # (B,S,H,r)
    out = jnp.einsum("bshr,rhd->bshd", ctx_lat, w_uv)
    return out.reshape(b, s, nq * m.v_head_dim) @ params["wo"]


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------


def mlp_init(rng, d: int, f: int, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(ks[0], (d, f), dtype=dtype),
        "w_up": dense_init(ks[1], (d, f), dtype=dtype),
        "w_down": dense_init(ks[2], (f, d), dtype=dtype),
    }


def mlp_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) \
        @ params["w_down"]


def moe_init(rng, cfg: ModelConfig) -> Params:
    mo = cfg.moe
    d, fe = cfg.d_model, mo.d_ff_expert
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 6)
    p: Params = {
        "router": dense_init(ks[0], (d, mo.n_experts), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (mo.n_experts, d, fe), dtype=dt),
        "w_up": dense_init(ks[2], (mo.n_experts, d, fe), dtype=dt),
        "w_down": dense_init(ks[3], (mo.n_experts, fe, d), dtype=dt),
    }
    if mo.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, fe * mo.n_shared_experts, dt)
    if mo.dense_residual_d_ff:
        p["dense"] = mlp_init(ks[5], d, mo.dense_residual_d_ff, dt)
    return p


MOE_GROUP_TOKENS = 2048


def moe_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-choice top-k routing with GROUP-LIMITED capacity-bounded
    einsum dispatch (GShard-style — static shapes, expert-parallel friendly
    on TPU).  Tokens are split into contiguous groups of ~2048 and each
    group dispatches independently: the one-hot dispatch einsum is then
    O(N * group * k * cf * D) instead of O(N^2 * k * cf * D) — dispatch
    FLOPs stay a small constant fraction of expert FLOPs at any batch.

    Returns (output, router aux loss).
    """
    mo = cfg.moe
    b, s, d = x.shape
    n_tok = b * s
    e, k = mo.n_experts, mo.top_k
    # group split (G=1 covers decode and non-divisible cases)
    g = n_tok // MOE_GROUP_TOKENS if n_tok % MOE_GROUP_TOKENS == 0 else 1
    ng = n_tok // g
    xt = x.reshape(g, ng, d)
    logits = (xt.astype(jnp.float32) @ params["router"])        # (G, Ng, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (G, Ng, k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renorm top-k
    # load-balance aux loss (Switch): e * sum_e f_e * p_e, over all tokens
    me = probs.mean(axis=(0, 1))
    one_hot_top1 = jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=(0, 1))
    aux = mo.router_aux_coef * e * jnp.sum(me * ce)

    cap = max(1, int(math.ceil(ng * k / e * mo.capacity_factor)))
    cap = min(cap, ng)
    # position of each (token, slot) within its expert's per-group buffer
    oh = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)         # (G, Ng, k, E)
    flat_oh = oh.reshape(g, ng * k, e)
    pos_in_expert = (jnp.cumsum(flat_oh, axis=1) - flat_oh).reshape(
        g, ng, k, e)
    pos = jnp.sum(pos_in_expert * oh, axis=-1)                  # (G, Ng, k)
    keep = pos < cap
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype)            # (G, Ng, k, C)
    disp = jnp.einsum("gnke,gnkc->gnec", oh.astype(x.dtype)
                      * keep[..., None].astype(x.dtype), pos_oh)
    comb = jnp.einsum("gnke,gnkc,gnk->gnec", oh.astype(jnp.float32),
                      pos_oh.astype(jnp.float32),
                      gate_vals.astype(jnp.float32)).astype(x.dtype)
    xe = jnp.einsum("gnec,gnd->gecd", disp, xt)                 # (G, E, C, D)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])      # (G, E, C, D)
    out = jnp.einsum("gnec,gecd->gnd", comb, ye)
    xt_flat = xt.reshape(n_tok, d)
    out = out.reshape(n_tok, d)
    if "shared" in params:
        out = out + mlp_apply(params["shared"], xt_flat)
    if "dense" in params:
        out = out + mlp_apply(params["dense"], xt_flat)
    return out.reshape(b, s, d), aux
