"""State-space blocks: Mamba1 (selective scan) and Mamba2 (SSD).

Hardware adaptation (DESIGN.md §3): the CUDA selective-scan kernel is
replaced by TPU-friendly formulations —

 - Mamba1: chunked associative scan. ``jax.lax.scan`` over sequence chunks
   carries the (B, d_inner, N) state; within a chunk
   ``jax.lax.associative_scan`` runs in fp32.  The (B, Lc, d, N) chunk
   tensor is the only large intermediate; with d_inner sharded over the
   model axis and batch over data it stays in the MiB range per device.
   The Pallas kernel (repro/kernels/mamba_scan) keeps it in VMEM.

 - Mamba2: SSD block-decomposition — *quadratic attention-like matmuls
   within chunks* (MXU-friendly) + scalar-decay state passing between
   chunks.  No (B,S,nh,hd,N) materialization at all.

Decode is the O(1) recurrent step in both cases, with the state carried in
the serving cache.  Speculative verification (multi-token decode) uses the
same chunked path with a state checkpoint for rollback (§Arch-applicability
of DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

Params = Dict[str, jnp.ndarray]

CHUNK = 128


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------


def mamba1_init(rng, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = max(1, d // 16)
    dt = _dt(cfg)
    ks = jax.random.split(rng, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), dtype=dt),
        "conv_w": dense_init(ks[1], (s.d_conv, d_in), dtype=dt),
        "conv_b": jnp.zeros((d_in,), dtype=dt),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * s.d_state), dtype=dt),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), dtype=dt),
        "dt_bias": jnp.zeros((d_in,), dtype=jnp.float32),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
            (d_in, 1))),                                  # (d_in, N)
        "D": jnp.ones((d_in,), dtype=jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d), dtype=dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  x: (B,S,C), w: (K,C).  state: (B,K-1,C)
    previous inputs (for decode continuity).  Returns (y, new_state)."""
    k = w.shape[0]
    bsz, s, c = x.shape
    if state is None:
        state = jnp.zeros((bsz, k - 1, c), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, C)
    y = jnp.zeros((bsz, s, c), x.dtype)
    for i in range(k):
        y = y + xp[:, i:i + s, :] * w[i]
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return jax.nn.silu(y + b), new_state


def _scan_chunked(a: jnp.ndarray, bx: jnp.ndarray,
                  h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Linear recurrence h_t = a_t * h_{t-1} + bx_t along axis 1.

    a, bx: (B, S, ...) fp32; h0: (B, ...).  Returns (h_all (B,S,...), h_S).
    Chunked: lax.scan over S/CHUNK chunks, associative_scan inside.
    """
    bsz, s = a.shape[:2]
    n_chunks = max(1, s // CHUNK)
    assert s % n_chunks == 0, f"seq {s} not divisible into chunks"
    lc = s // n_chunks
    a_c = a.reshape((bsz, n_chunks, lc) + a.shape[2:]).swapaxes(0, 1)
    bx_c = bx.reshape((bsz, n_chunks, lc) + bx.shape[2:]).swapaxes(0, 1)

    def combine(p, q):
        (a1, b1), (a2, b2) = p, q
        return a1 * a2, a2 * b1 + b2

    def step(h, inputs):
        ac, bc = inputs                     # (B, Lc, ...)
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = aa * h[:, None] + bb        # (B, Lc, ...)
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(step, h0, (a_c, bx_c))
    h_all = h_chunks.swapaxes(0, 1).reshape((bsz, s) + h0.shape[1:])
    return h_all, h_last


def mamba1_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                 conv_state: Optional[jnp.ndarray] = None,
                 ssm_state: Optional[jnp.ndarray] = None):
    """x: (B,S,D) -> (y, (conv_state, ssm_state)).

    With S=1 this is the decode step; larger S covers train/prefill and
    speculative multi-token verification.
    """
    s_cfg = cfg.ssm
    d_in = s_cfg.expand * cfg.d_model
    n = s_cfg.d_state
    dt_rank = max(1, cfg.d_model // 16)
    bsz, slen, _ = x.shape

    xz = x @ params["in_proj"]
    xs, z = xz[..., :d_in], xz[..., d_in:]
    xs, new_conv = _causal_conv(xs, params["conv_w"], params["conv_b"],
                                conv_state)
    proj = xs @ params["x_proj"]
    dt_in = proj[..., :dt_rank]
    b_in = proj[..., dt_rank:dt_rank + n].astype(jnp.float32)     # (B,S,N)
    c_in = proj[..., dt_rank + n:].astype(jnp.float32)            # (B,S,N)
    dt = jax.nn.softplus(
        (dt_in @ params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])                                      # (B,S,d_in)
    a = -jnp.exp(params["A_log"])                                 # (d_in,N)
    if ssm_state is None:
        ssm_state = jnp.zeros((bsz, d_in, n), jnp.float32)
    if cfg.use_pallas_kernels:
        # VMEM-resident selective scan (repro/kernels/mamba_scan)
        from repro.kernels.mamba_scan.ops import mamba_scan
        y, h_last = mamba_scan(dt, xs.astype(jnp.float32), b_in, c_in, a,
                               ssm_state)
    else:
        # discretize: a_bar = exp(dt*A) (B,S,d_in,N); b_bar*x = dt*B*x
        a_bar = jnp.exp(dt[..., None] * a)
        bx = (dt * xs.astype(jnp.float32))[..., None] * b_in[:, :, None, :]
        h_all, h_last = _scan_chunked(a_bar, bx, ssm_state)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, c_in)
    y = y + params["D"] * xs.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    return y, (new_conv, h_last)


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba2_init(rng, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nh = d_in // s.head_dim
    g = s.n_groups
    dt = _dt(cfg)
    conv_dim = d_in + 2 * g * s.d_state
    ks = jax.random.split(rng, 5)
    # z / xBC / dt as SEPARATE projections: a fused (D, 2*d_in+2gN+nh)
    # matrix sharded on the model axis forces cross-shard slices of its
    # output (each logical stream straddles shard boundaries) — XLA
    # reshards with collective-permutes that dominated zamba2's training
    # roofline (EXPERIMENTS.md §Perf bonus pair).
    return {
        "z_proj": dense_init(ks[0], (d, d_in), dtype=dt),
        "xbc_proj": dense_init(ks[3], (d, conv_dim), dtype=dt),
        "dt_in_proj": dense_init(ks[4], (d, nh), dtype=dt),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_dim), dtype=dt),
        "conv_b": jnp.zeros((conv_dim,), dtype=dt),
        "dt_bias": jnp.zeros((nh,), dtype=jnp.float32),
        "A_log": jnp.zeros((nh,), dtype=jnp.float32),
        "D": jnp.ones((nh,), dtype=jnp.float32),
        "norm": rmsnorm_init(d_in, dt),
        "out_proj": dense_init(ks[2], (d_in, d), dtype=dt),
    }


def mamba2_apply(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                 conv_state: Optional[jnp.ndarray] = None,
                 ssm_state: Optional[jnp.ndarray] = None):
    """SSD: within-chunk quadratic (masked, decay-weighted) attention +
    inter-chunk scalar-decay state passing.

    x: (B,S,D) -> (y, (conv_state, ssm_state (B,nh,hd,N)))
    """
    s_cfg = cfg.ssm
    d_in = s_cfg.expand * cfg.d_model
    hd, n, g = s_cfg.head_dim, s_cfg.d_state, s_cfg.n_groups
    nh = d_in // hd
    bsz, slen, _ = x.shape

    z = x @ params["z_proj"]
    xbc = x @ params["xbc_proj"]
    dt_raw = x @ params["dt_in_proj"]
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xs = xbc[..., :d_in].reshape(bsz, slen, nh, hd)
    b_in = xbc[..., d_in:d_in + g * n].reshape(
        bsz, slen, g, n).astype(jnp.float32)
    c_in = xbc[..., d_in + g * n:].reshape(
        bsz, slen, g, n).astype(jnp.float32)
    if g == 1:
        b_in = jnp.broadcast_to(b_in, (bsz, slen, 1, n))
        c_in = jnp.broadcast_to(c_in, (bsz, slen, 1, n))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                     # (nh,)
    log_decay = dt * a                                 # (B,S,nh) <= 0

    if ssm_state is None:
        ssm_state = jnp.zeros((bsz, nh, hd, n), jnp.float32)

    if cfg.use_pallas_kernels and g == 1:
        # SSD block-decomposition kernel (repro/kernels/ssd_scan)
        from repro.kernels.ssd_scan.ops import ssd_scan
        y_k, h_last = ssd_scan(
            xs.astype(jnp.float32), b_in[:, :, 0], c_in[:, :, 0],
            log_decay, dt, ssm_state,
            chunk=min(CHUNK, slen))
        y = y_k + params["D"][:, None] * xs.astype(jnp.float32)
        y = y.reshape(bsz, slen, d_in).astype(x.dtype)
        y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.rms_eps)
        return y @ params["out_proj"], (new_conv, h_last)

    n_chunks = max(1, slen // CHUNK)
    assert slen % n_chunks == 0
    lc = slen // n_chunks
    hpg = nh // g  # heads per group

    def reshape_c(t, extra):
        return t.reshape((bsz, n_chunks, lc) + extra).swapaxes(0, 1)

    xs_c = reshape_c(xs.astype(jnp.float32), (nh, hd))
    b_c = reshape_c(b_in, (g, n))
    c_c = reshape_c(c_in, (g, n))
    ld_c = reshape_c(log_decay, (nh,))
    dt_c = reshape_c(dt, (nh,))

    def chunk_step(h, inp):
        xc, bc, cc, ldc, dtc = inp        # (B,lc,...)
        cum = jnp.cumsum(ldc, axis=1)     # (B,lc,nh) cumulative log decay
        # intra-chunk: y_intra[i] = sum_{j<=i} decay(i,j) * (C_i.B_j) dt_j x_j
        cgrp = cc[:, :, :, None, :]                         # (B,lc,g,1,N)
        bgrp = bc[:, :, :, None, :]
        cb = jnp.einsum("bigkn,bjgkn->bgij", cgrp, bgrp)    # (B,g,lc,lc)
        cb = jnp.repeat(cb, hpg, axis=1)                    # (B,nh,lc,lc)
        dmat = cum.transpose(0, 2, 1)[:, :, :, None] - \
            cum.transpose(0, 2, 1)[:, :, None, :]           # (B,nh,i,j)
        mask = jnp.tril(jnp.ones((lc, lc), bool))
        dmat = jnp.where(mask, dmat, -jnp.inf)
        w = cb * jnp.exp(dmat)                              # (B,nh,lc,lc)
        xdt = xc * dtc[..., None]                           # (B,lc,nh,hd)
        y_intra = jnp.einsum("bhij,bjhd->bihd", w, xdt)
        # contribution of incoming state: y_state[i] = C_i . h * decay(0..i)
        cfull = jnp.repeat(cc, hpg, axis=2)                 # (B,lc,nh,N)
        y_state = jnp.einsum("bihn,bhdn->bihd", cfull, h) \
            * jnp.exp(cum)[..., None]
        # new state: h' = decay(total) * h + sum_j decay(j..end) B_j (dt_j x_j)
        total = cum[:, -1]                                  # (B,nh)
        rev = jnp.exp(total[:, None] - cum)                 # (B,lc,nh)
        bfull = jnp.repeat(bc, hpg, axis=2)                 # (B,lc,nh,N)
        h_new = h * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bjhd,bjhn,bjh->bhdn", xdt, bfull, rev)
        return h_new, y_intra + y_state

    h_last, y_chunks = jax.lax.scan(
        chunk_step, ssm_state, (xs_c, b_c, c_c, ld_c, dt_c))
    y = y_chunks.swapaxes(0, 1).reshape(bsz, slen, nh, hd)
    y = y + params["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, slen, d_in).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.rms_eps)
    return y @ params["out_proj"], (new_conv, h_last)
