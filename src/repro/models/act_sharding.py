"""Activation sharding constraints.

XLA's SPMD propagation occasionally drops the batch sharding across
reshape-heavy regions (blocked attention, loss) and then picks
all-gather-the-world strategies for the adjacent matmuls.  The launchers
register the mesh batch axes here; model code pins activations at the
block boundaries (embedding output, per-layer hidden state, logits) with
``with_sharding_constraint``.  Outside a mesh context (CPU smoke tests)
the constraints are no-ops.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: Optional[Tuple[str, ...]] = None
_MODEL_AXIS: Optional[str] = None


def configure(batch_axes: Optional[Tuple[str, ...]],
              model_axis: Optional[str] = "model") -> None:
    global _BATCH_AXES, _MODEL_AXIS
    _BATCH_AXES = tuple(batch_axes) if batch_axes else None
    _MODEL_AXIS = model_axis


@contextlib.contextmanager
def activation_sharding(batch_axes, model_axis="model"):
    global _BATCH_AXES, _MODEL_AXIS
    old = (_BATCH_AXES, _MODEL_AXIS)
    configure(batch_axes, model_axis)
    try:
        yield
    finally:
        _BATCH_AXES, _MODEL_AXIS = old


def constrain_batch(x):
    """Pin dim0 to the batch axes, rest unspecified."""
    if _BATCH_AXES is None:
        return x
    if x.shape[0] % _axis_prod(_BATCH_AXES) != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(_BATCH_AXES, *([None] * (x.ndim - 1))))


def constrain_logits(x):
    """(B, S, V): batch over data axes, vocab over model."""
    if _BATCH_AXES is None:
        return x
    b_ax = _BATCH_AXES if x.shape[0] % _axis_prod(_BATCH_AXES) == 0 else None
    v_ax = _MODEL_AXIS if (_MODEL_AXIS and
                           x.shape[-1] % _axis_prod((_MODEL_AXIS,)) == 0) \
        else None
    return jax.lax.with_sharding_constraint(
        x, P(b_ax, *([None] * (x.ndim - 2)), v_ax))


_SIZES = {}


def register_mesh(mesh) -> None:
    global _SIZES
    _SIZES = dict(zip(mesh.axis_names, mesh.devices.shape))


def _axis_prod(axes) -> int:
    n = 1
    for a in axes:
        n *= _SIZES.get(a, 1)
    return n
