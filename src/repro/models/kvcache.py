"""Decode caches for every block kind.

Cache layout (all static shapes — TPU/XLA friendly):
 - full attention: k/v (B, T_max, n_kv, d_head); validity = pos < len
 - sliding window: ring buffers (B, W, n_kv, d_head) + per-row
   slot->position map (B, W)
 - MLA: the compressed latent (B, T_max, r_kv) + rope key (B, T_max, 1, dr)
 - SSM: conv state (B, K-1, C) + recurrent state (fp32)
 - cross-attention (whisper): encoder k/v, written once at prefill

The cache for a scanned group of layers is the same pytree with a leading
``reps`` axis, so it can be fed through ``jax.lax.scan`` together with the
stacked layer params.  ``len`` is a single int32 scalar for the whole model
(batch-synchronous decoding) or an (B,) int32 vector for ragged /
continuous-batching serving.

Validity invariant: entries at positions >= len are garbage by contract —
speculative rollback rewinds ``len`` past rejected tokens, bucketed
admission prefills leave pad K/V beyond the true prompt length, and freed
serving slots keep their stale rows until the next admission scatters over
them.  Every reader masks by ``pos < len`` (the dense paths via
``k_valid``; ``kernels/decode_attention`` via its per-row length vector,
which also bounds how many cache tiles each row streams), and writers
append at ``len``, overwriting garbage first.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def block_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one block's cache (used by init and dry-run)."""
    nkv, dh = cfg.n_kv_heads, cfg.d_head
    quant = cfg.kv_cache_dtype == "int8"
    kv_dt = jnp.int8 if quant else dtype

    def _kv(t):
        spec = {
            "k": jax.ShapeDtypeStruct((batch, t, nkv, dh), kv_dt),
            "v": jax.ShapeDtypeStruct((batch, t, nkv, dh), kv_dt),
        }
        if quant:
            spec["k_scale"] = jax.ShapeDtypeStruct((batch, t, nkv),
                                                   jnp.bfloat16)
            spec["v_scale"] = jax.ShapeDtypeStruct((batch, t, nkv),
                                                   jnp.bfloat16)
        return spec

    if kind in ("attn", "shared_attn"):
        return _kv(max_len)
    if kind == "swa":
        w = min(cfg.sliding_window or max_len, max_len)
        spec = _kv(w)
        # per-row slot->position map: rows of a continuous batch sit at
        # different sequence positions, so each carries its own ring state
        spec["pos"] = jax.ShapeDtypeStruct((batch, w), jnp.int32)
        return spec
    if kind == "mla":
        m = cfg.mla
        return {
            "ckv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank),
                                        dtype),
            "krope": jax.ShapeDtypeStruct(
                (batch, max_len, 1, m.qk_rope_head_dim), dtype),
        }
    if kind == "moe":
        base = "mla" if cfg.mla is not None else "attn"
        return block_cache_spec(cfg, base, batch, max_len, dtype)
    if kind in ("mamba1", "mamba2"):
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        if s.version == 1 and kind == "mamba1":
            conv_c = d_in
            state_shape = (batch, d_in, s.d_state)
        else:
            conv_c = d_in + 2 * s.n_groups * s.d_state
            nh = d_in // s.head_dim
            state_shape = (batch, nh, s.head_dim, s.d_state)
        return {
            "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_c),
                                         dtype),
            "ssm": jax.ShapeDtypeStruct(state_shape, jnp.float32),
        }
    if kind == "xattn":
        spec = block_cache_spec(cfg, "attn", batch, max_len, dtype)
        spec["xk"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq_len, nkv, dh), dtype)
        spec["xv"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq_len, nkv, dh), dtype)
        return spec
    raise ValueError(kind)


def _zeros_like_spec(spec):
    def mk(s):
        if s.dtype == jnp.int32:  # slot->position maps start invalid
            return jnp.full(s.shape, -1, jnp.int32)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree.map(mk, spec)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Concrete zero cache matching cache_spec()."""
    return jax.tree.map(lambda s: s, _cache_build(
        cfg, batch, max_len, concrete=True))


def cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct pytree (for .lower() in the dry-run)."""
    return _cache_build(cfg, batch, max_len, concrete=False)


def _cache_build(cfg: ModelConfig, batch: int, max_len: int, concrete: bool):
    dtype = jnp.dtype(cfg.dtype)
    head, reps, group, tail = cfg.layer_program

    def one(kind):
        spec = block_cache_spec(cfg, kind, batch, max_len, dtype)
        return _zeros_like_spec(spec) if concrete else spec

    def stacked(kind):
        spec = block_cache_spec(cfg, kind, batch, max_len, dtype)
        spec = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((reps,) + s.shape, s.dtype), spec)
        return _zeros_like_spec(spec) if concrete else spec

    cache = {
        "len": (jnp.zeros((), jnp.int32) if concrete
                else jax.ShapeDtypeStruct((), jnp.int32)),
        "head": [one(k) for k in head],
        "group": {f"b{i}": stacked(k) for i, k in enumerate(group)},
        "tail": [one(k) for k in tail],
    }
    return cache
