"""Decode caches for every block kind — dense per-row stripes or a paged
pool with per-slot block tables.

Dense layout (all static shapes — TPU/XLA friendly):
 - full attention: k/v (B, T_max, n_kv, d_head); validity = pos < len
 - sliding window: ring buffers (B, W, n_kv, d_head) + per-row
   slot->position map (B, W)
 - MLA: the compressed latent (B, T_max, r_kv) + rope key (B, T_max, 1, dr)
 - SSM: conv state (B, K-1, C) + recurrent state (fp32)
 - cross-attention (whisper): encoder k/v, written once at prefill

Paged layout (``init_cache(..., page_size=ps)``; serving hot path): the
full-attention / MLA stripes above are replaced by a POOL shared across
all slots plus a per-slot block table:
 - full attention: k/v (n_pages, ps, n_kv, d_head) pool pages
 - MLA: ckv (n_pages, ps, r_kv) + krope (n_pages, ps, 1, dr) pool pages
 - ``cache["pages"]``: (B, max_pages) int32 block table, max_pages =
   T_max / ps.  Logical position p of row b lives at pool row
   ``pages[b, p // ps]``, offset ``p % ps``.  Every layer indexes its own
   pool arrays through the SAME table (one allocation covers the whole
   stack; scanned groups carry a leading ``reps`` axis on the pool).
 - page 0 is reserved as the trash page: unallocated table entries point
   at it, so batched decode writes from vacant slots (which feed pads and
   advance ``len`` like every row) land somewhere harmless instead of in
   a live row's storage.  Allocators hand out pages 1..n_pages-1.
Row state that is already O(W)/O(1) per row — SWA rings, SSM states,
cross-attention encoder K/V — stays dense; ``pageable(cfg)`` says whether
every cache-bearing block of an architecture can take the paged layout.

The cache for a scanned group of layers is the same pytree with a leading
``reps`` axis, so it can be fed through ``jax.lax.scan`` together with the
stacked layer params.  ``len`` is a single int32 scalar for the whole model
(batch-synchronous decoding) or an (B,) int32 vector for ragged /
continuous-batching serving.

Validity invariant: entries at positions >= len are garbage by contract —
speculative rollback rewinds ``len`` past rejected tokens, bucketed
admission prefills leave pad K/V beyond the true prompt length, and freed
serving slots keep their stale rows until the next admission scatters over
them.  Every reader masks by ``pos < len`` (the dense paths via
``k_valid``; ``kernels/decode_attention`` via its per-row length vector,
which also bounds how many cache tiles each row streams), and writers
append at ``len``, overwriting garbage first.  The paged layout extends
the invariant through the block table: position p of row b is valid iff
p < len[b] AND ``pages[b, p // ps]`` is a page currently allocated to b —
the scheduler's allocator guarantees every position below the frontier
has a live table entry, so readers still only need ``pos < len``; a page
freed by rollback/eviction may hold stale K/V, but no surviving row's
table points at it, and its next owner overwrites positions below its own
frontier before they become visible.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# block kinds whose cache can take the paged pool layout (everything the
# paged serving scheduler needs; ring/recurrent/encoder state stays dense)
PAGEABLE_KINDS = ("attn", "shared_attn", "mla", "moe")


def pageable(cfg: ModelConfig) -> bool:
    """True iff every cache-bearing block of ``cfg`` can be paged — i.e.
    the whole stack is full-attention / MLA (incl. MoE blocks, whose
    attention is one of the two).  SWA rings and SSM states are already
    O(W)/O(1) per row, and whisper's encoder K/V is written once — those
    architectures keep the dense per-row layout."""
    head, reps, group, tail = cfg.layer_program
    kinds = list(head) + list(group) + list(tail)
    return (not cfg.is_encoder_decoder
            and all(k in PAGEABLE_KINDS for k in kinds))


def block_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype, page_size: Optional[int] = None,
                     n_pages: Optional[int] = None
                     ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one block's cache (used by init and dry-run).

    With ``page_size`` set (pageable kinds only), K/V stripes become
    shared pool pages: leading axis ``n_pages`` instead of ``batch``,
    second axis ``page_size`` instead of ``max_len``.
    """
    nkv, dh = cfg.n_kv_heads, cfg.d_head
    quant = cfg.kv_cache_dtype == "int8"
    kv_dt = jnp.int8 if quant else dtype
    paged = page_size is not None
    lead, t_axis = (n_pages, page_size) if paged else (batch, max_len)

    def _kv(t, lead=lead):
        spec = {
            "k": jax.ShapeDtypeStruct((lead, t, nkv, dh), kv_dt),
            "v": jax.ShapeDtypeStruct((lead, t, nkv, dh), kv_dt),
        }
        if quant:
            spec["k_scale"] = jax.ShapeDtypeStruct((lead, t, nkv),
                                                   jnp.bfloat16)
            spec["v_scale"] = jax.ShapeDtypeStruct((lead, t, nkv),
                                                   jnp.bfloat16)
        return spec

    if kind in ("attn", "shared_attn"):
        return _kv(t_axis)
    if kind == "swa":
        assert not paged, "SWA ring caches stay dense (O(W) per row)"
        w = min(cfg.sliding_window or max_len, max_len)
        spec = _kv(w, lead=batch)
        # per-row slot->position map: rows of a continuous batch sit at
        # different sequence positions, so each carries its own ring state
        spec["pos"] = jax.ShapeDtypeStruct((batch, w), jnp.int32)
        return spec
    if kind == "mla":
        m = cfg.mla
        return {
            "ckv": jax.ShapeDtypeStruct((lead, t_axis, m.kv_lora_rank),
                                        dtype),
            "krope": jax.ShapeDtypeStruct(
                (lead, t_axis, 1, m.qk_rope_head_dim), dtype),
        }
    if kind == "moe":
        base = "mla" if cfg.mla is not None else "attn"
        return block_cache_spec(cfg, base, batch, max_len, dtype,
                                page_size=page_size, n_pages=n_pages)
    if kind in ("mamba1", "mamba2"):
        assert not paged, "SSM states stay dense (O(1) per row)"
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        if s.version == 1 and kind == "mamba1":
            conv_c = d_in
            state_shape = (batch, d_in, s.d_state)
        else:
            conv_c = d_in + 2 * s.n_groups * s.d_state
            nh = d_in // s.head_dim
            state_shape = (batch, nh, s.head_dim, s.d_state)
        return {
            "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_c),
                                         dtype),
            "ssm": jax.ShapeDtypeStruct(state_shape, jnp.float32),
        }
    if kind == "xattn":
        assert not paged, "encoder-decoder caches stay dense"
        spec = block_cache_spec(cfg, "attn", batch, max_len, dtype)
        spec["xk"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq_len, nkv, dh), dtype)
        spec["xv"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq_len, nkv, dh), dtype)
        return spec
    raise ValueError(kind)


def _zeros_like_spec(spec):
    def mk(s):
        if s.dtype == jnp.int32:  # slot->position maps start invalid
            return jnp.full(s.shape, -1, jnp.int32)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree.map(mk, spec)


def default_n_pages(batch: int, max_len: int, page_size: int) -> int:
    """Capacity-equivalent pool: as many tokens as ``batch`` contiguous
    stripes would hold, plus the reserved trash page.  Serving pools are
    usually sized SMALLER than this — that is the paged win."""
    return batch * (max_len // page_size) + 1


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               page_size: Optional[int] = None,
               n_pages: Optional[int] = None):
    """Concrete zero cache matching cache_spec()."""
    return jax.tree.map(lambda s: s, _cache_build(
        cfg, batch, max_len, concrete=True, page_size=page_size,
        n_pages=n_pages))


def cache_spec(cfg: ModelConfig, batch: int, max_len: int,
               page_size: Optional[int] = None,
               n_pages: Optional[int] = None):
    """ShapeDtypeStruct pytree (for .lower() in the dry-run)."""
    return _cache_build(cfg, batch, max_len, concrete=False,
                        page_size=page_size, n_pages=n_pages)


def _cache_build(cfg: ModelConfig, batch: int, max_len: int, concrete: bool,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None):
    dtype = jnp.dtype(cfg.dtype)
    head, reps, group, tail = cfg.layer_program
    if page_size is not None:
        assert pageable(cfg), \
            f"{cfg.arch_id}: not every cache-bearing block is pageable"
        assert max_len % page_size == 0, \
            f"max_len {max_len} must be a multiple of page_size {page_size}"
        if n_pages is None:
            n_pages = default_n_pages(batch, max_len, page_size)
        assert n_pages >= 2, "pool needs the trash page plus >= 1 usable"

    def one(kind):
        spec = block_cache_spec(cfg, kind, batch, max_len, dtype,
                                page_size=page_size, n_pages=n_pages)
        return _zeros_like_spec(spec) if concrete else spec

    def stacked(kind):
        spec = block_cache_spec(cfg, kind, batch, max_len, dtype,
                                page_size=page_size, n_pages=n_pages)
        spec = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((reps,) + s.shape, s.dtype), spec)
        return _zeros_like_spec(spec) if concrete else spec

    cache = {
        "len": (jnp.zeros((), jnp.int32) if concrete
                else jax.ShapeDtypeStruct((), jnp.int32)),
        "head": [one(k) for k in head],
        "group": {f"b{i}": stacked(k) for i, k in enumerate(group)},
        "tail": [one(k) for k in tail],
    }
    if page_size is not None:
        mp = max_len // page_size
        # table entries start at 0 = the reserved trash page, so vacant /
        # unallocated positions always resolve to a harmless pool row
        cache["pages"] = (jnp.zeros((batch, mp), jnp.int32) if concrete
                          else jax.ShapeDtypeStruct((batch, mp), jnp.int32))
    return cache


def page_size_of(cache) -> Optional[int]:
    """Static page size of a paged cache (None for dense layouts): the
    second axis of any pool leaf."""
    if "pages" not in cache:
        return None
    for part in (cache["head"], cache["tail"]):
        for blk in part:
            for v in blk.values():
                return v.shape[1]
    for blk in cache["group"].values():
        for v in blk.values():
            return v.shape[2]          # (reps, n_pages, ps, ...)
    return None
