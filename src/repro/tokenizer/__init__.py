from repro.tokenizer.bpe import BPETokenizer, train_bpe

__all__ = ["BPETokenizer", "train_bpe"]
