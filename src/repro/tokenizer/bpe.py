"""Byte-level BPE tokenizer, trained in-repo.

The 256 single bytes are always in the vocabulary (ids 0..255), so every
byte string is encodable — a requirement for DOMINO's subterminal trees
(any grammar-legal string must have at least one tokenization) and for
Algorithm 3 retokenization.  Merges are learned with the standard BPE
objective over a corpus; special tokens (PAD/BOS/EOS) sit at the top of the
id space.

Encoding supports two modes:
 - ``encode`` — canonical merge-order BPE (what a deployed tokenizer does);
 - ``encode_greedy`` — longest-match (used to emulate an *external*
   tokenizer for template-misalignment experiments).
"""
from __future__ import annotations

import collections
import json
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

PAD_TOKEN = "<pad>"
BOS_TOKEN = "<bos>"
EOS_TOKEN = "<eos>"
SPECIALS = (PAD_TOKEN, BOS_TOKEN, EOS_TOKEN)


class BPETokenizer:
    def __init__(self, merges: List[Tuple[int, int]]):
        # vocab: id -> bytes; specials map to None (no byte content)
        self.vocab: List[Optional[bytes]] = [bytes([i]) for i in range(256)]
        self.merges = list(merges)
        self.merge_rank: Dict[Tuple[int, int], int] = {}
        for rank, (a, b) in enumerate(self.merges):
            new_id = len(self.vocab)
            self.merge_rank[(a, b)] = rank
            self.vocab.append(self.vocab[a] + self.vocab[b])
        self.pad_id = len(self.vocab)
        self.bos_id = self.pad_id + 1
        self.eos_id = self.pad_id + 2
        self.vocab.extend([None, None, None])
        self._merge_to_id = {
            (a, b): 256 + r for r, (a, b) in enumerate(self.merges)}
        self._bytes_to_id = {
            v: i for i, v in enumerate(self.vocab) if v is not None}

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)

    # -- encoding -------------------------------------------------------------

    def encode(self, text: str) -> List[int]:
        return self.encode_bytes(text.encode("utf-8"))

    def encode_bytes(self, data: bytes) -> List[int]:
        ids = list(data)
        if len(ids) < 2:
            return ids
        while True:
            best_rank = None
            best_pos = -1
            for i in range(len(ids) - 1):
                r = self.merge_rank.get((ids[i], ids[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank = r
                    best_pos = i
            if best_rank is None:
                return ids
            ids[best_pos:best_pos + 2] = [
                self._merge_to_id[(ids[best_pos], ids[best_pos + 1])]]

    def encode_greedy(self, text: str) -> List[int]:
        """Longest-match encode (external-tokenizer emulation)."""
        data = text.encode("utf-8")
        out: List[int] = []
        i = 0
        max_len = max((len(v) for v in self.vocab if v), default=1)
        while i < len(data):
            for ln in range(min(max_len, len(data) - i), 0, -1):
                tid = self._bytes_to_id.get(data[i:i + ln])
                if tid is not None:
                    out.append(tid)
                    i += ln
                    break
        return out

    def decode(self, ids: Sequence[int]) -> str:
        return self.decode_bytes(ids).decode("utf-8", errors="replace")

    def decode_bytes(self, ids: Sequence[int]) -> bytes:
        return b"".join(self.vocab[i] or b"" for i in ids)

    # -- persistence ------------------------------------------------------------

    def save(self, path) -> None:
        pathlib.Path(path).write_text(json.dumps({"merges": self.merges}))

    @classmethod
    def load(cls, path) -> "BPETokenizer":
        data = json.loads(pathlib.Path(path).read_text())
        return cls([tuple(m) for m in data["merges"]])


def train_bpe(corpus: bytes, vocab_size: int = 2048,
              word_split: bool = True) -> BPETokenizer:
    """Learn BPE merges.  ``vocab_size`` includes the 256 byte tokens but
    not the 3 specials.  ``word_split`` restricts merges to within
    whitespace-delimited chunks (keeps the pair statistics tractable and
    yields GPT-style word-ish tokens, whitespace prefixed)."""
    n_merges = max(0, vocab_size - 256)
    if word_split:
        # split keeping whitespace attached to the following word
        words: collections.Counter = collections.Counter()
        cur = bytearray()
        for i, b in enumerate(corpus):
            if b in (32, 10, 9, 13) and cur and not _isspace(cur[-1]):
                words[bytes(cur)] += 1
                cur = bytearray()
            cur.append(b)
        if cur:
            words[bytes(cur)] += 1
        seqs = {w: list(w) for w in words}
        counts = dict(words)
    else:
        seqs = {corpus: list(corpus)}
        counts = {corpus: 1}

    # pair -> total count, and pair -> set of words containing it
    pair_count: collections.Counter = collections.Counter()
    pair_words: Dict[Tuple[int, int], set] = collections.defaultdict(set)
    for w, seq in seqs.items():
        c = counts[w]
        for a, b in zip(seq, seq[1:]):
            pair_count[(a, b)] += c
            pair_words[(a, b)].add(w)

    merges: List[Tuple[int, int]] = []
    next_id = 256
    for _ in range(n_merges):
        if not pair_count:
            break
        (a, b), cnt = max(pair_count.items(), key=lambda kv: (kv[1], kv[0]))
        if cnt < 2:
            break
        merges.append((a, b))
        affected = list(pair_words.get((a, b), ()))
        for w in affected:
            seq = seqs[w]
            c = counts[w]
            # remove old pair counts for this word
            for x, y in zip(seq, seq[1:]):
                pair_count[(x, y)] -= c
                if pair_count[(x, y)] <= 0:
                    del pair_count[(x, y)]
                pair_words[(x, y)].discard(w)
            # apply merge
            i = 0
            new_seq = []
            while i < len(seq):
                if i + 1 < len(seq) and seq[i] == a and seq[i + 1] == b:
                    new_seq.append(next_id)
                    i += 2
                else:
                    new_seq.append(seq[i])
                    i += 1
            seqs[w] = new_seq
            for x, y in zip(new_seq, new_seq[1:]):
                pair_count[(x, y)] += c
                pair_words[(x, y)].add(w)
        next_id += 1
    return BPETokenizer(merges)


def _isspace(b: int) -> bool:
    return b in (32, 10, 9, 13)
