"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b]

24L d_model=2048 32H (kv=32, MHA) d_ff=5632 vocab=100352.
Full attention => long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    group=("attn",),
    max_seq_len=32768,
)

SMOKE = ModelConfig(
    arch_id="stablelm-1.6b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    group=("attn",),
    dtype="float32",
    max_seq_len=128,
)
