"""yi-34b [dense] — llama-architecture GQA dense model. [arXiv:2403.04652]

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Full attention only => long_500k is skipped (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    group=("attn",),
    rope_theta=5e6,
    max_seq_len=32768,
)

SMOKE = ModelConfig(
    arch_id="yi-34b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    group=("attn",),
    dtype="float32",
    max_seq_len=128,
)
