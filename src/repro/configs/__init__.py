"""Config registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full-scale config (dry-run only);
``get_config(arch_id, smoke=True)`` returns the reduced same-family variant
used in CPU smoke tests (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

ARCH_IDS = [
    "llava_next_mistral_7b",
    "yi_34b",
    "whisper_tiny",
    "gemma3_27b",
    "zamba2_1p2b",
    "falcon_mamba_7b",
    "minicpm_2b",
    "stablelm_1p6b",
    "arctic_480b",
    "deepseek_v3_671b",
]

# canonical dashed names from the assignment -> module name
ALIASES = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "yi-34b": "yi_34b",
    "whisper-tiny": "whisper_tiny",
    "gemma3-27b": "gemma3_27b",
    "zamba2-1.2b": "zamba2_1p2b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "minicpm-2b": "minicpm_2b",
    "stablelm-1.6b": "stablelm_1p6b",
    "arctic-480b": "arctic_480b",
    "deepseek-v3-671b": "deepseek_v3_671b",
}


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(arch_id, arch_id)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCH_IDS}
