"""whisper-tiny [audio] — encoder-decoder ASR transformer. [arXiv:2212.04356]

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.  The mel-spectrogram +
conv1d frontend is a STUB per the carve-out: input_specs() provides 1500
frame embeddings (30 s at 50 Hz after the conv stride-2) of d_model which
feed the bidirectional encoder; the decoder is the constrained-generation
target.  Encoder-decoder with full attention => long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny",
    family="audio",
    n_layers=4,                 # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    group=("xattn",),
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_seq_len=1500,
    max_seq_len=32768,          # assignment decode shape (past 448 ctx of the card)
    tensor_parallel=False,      # 384-wide/6-head model wastes a 16-way axis
)

SMOKE = ModelConfig(
    arch_id="whisper-tiny-smoke",
    family="audio",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    group=("xattn",),
    is_encoder_decoder=True,
    n_encoder_layers=2,
    encoder_seq_len=16,
    dtype="float32",
    max_seq_len=128,
)
