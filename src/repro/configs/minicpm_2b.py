"""minicpm-2b [dense] — llama-like arch trained with WSD schedule.
[arXiv:2404.06395]

40L d_model=2304 36H (kv=36, i.e. MHA) d_ff=5760 vocab=122753.
The WSD (warmup-stable-decay) schedule is implemented in
repro/training/optimizer.py and exercised by the training example.
Full attention => long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    group=("attn",),
    tie_embeddings=True,
    max_seq_len=32768,
)

SMOKE = ModelConfig(
    arch_id="minicpm-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    group=("attn",),
    tie_embeddings=True,
    dtype="float32",
    max_seq_len=128,
)
