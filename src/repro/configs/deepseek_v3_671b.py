"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed experts top-8 + MTP.
[arXiv:2412.19437]

61L d_model=7168 128H (MLA latent cache; the assignment's kv=128 denotes
head count) expert d_ff=2048 vocab=129280.  All layers are MoE per the
assigned config line (the HF release has 3 leading dense layers — noted
deviation).  MLA dims per the paper: q_lora 1536, kv_lora 512,
nope/rope head dims 128/64, v_head 128.  MTP (multi-token prediction,
depth 1) is available through the training substrate.
Full attention => long_500k skipped.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    group=("moe",),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, capacity_factor=1.25),
    max_seq_len=32768,
)

SMOKE = ModelConfig(
    arch_id="deepseek-v3-671b-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    group=("moe",),
    mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                  n_shared_experts=1, capacity_factor=2.0),
    dtype="float32",
    max_seq_len=128,
)
