"""llava-next-mistral-7b [vlm] — LLaVA-NeXT with Mistral-7B language backbone.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000 — anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

Per the assignment carve-out, the SigLIP/CLIP vision tower + projector are a
STUB: input_specs() provides pre-projected patch embeddings (anyres tiling
of up to 4 tiles + base image ~ 2880 tokens of d_model).  The language
backbone is a Mistral-style GQA transformer with 4096-token sliding-window
attention (making long_500k decodable with bounded KV).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    group=("swa",),
    sliding_window=4096,
    rope_theta=1e6,
    n_prefix_tokens=2880,     # anyres: base 576 + 4 tiles x 576
    max_seq_len=524288,
)

SMOKE = ModelConfig(
    arch_id="llava-next-mistral-7b-smoke",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    group=("swa",),
    sliding_window=16,
    n_prefix_tokens=8,
    dtype="float32",
    max_seq_len=128,
)
