"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared-weight attention blocks.
[arXiv:2411.15242]

38 Mamba2 layers, d_model=2048 d_ff=8192 vocab=32000, ssm_state=64; a
single SHARED transformer block (32H kv=32) is invoked every 5 Mamba
blocks (7 invocations; weights shared, per-invocation KV cache).
SSM state is O(1) in context => long_500k runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    group=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2", "shared_attn"),
    tail_blocks=("mamba2", "mamba2", "mamba2"),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, version=2, head_dim=64,
                  n_groups=1),
    max_seq_len=524288,
    # 1.2B params replicate comfortably; 16-way tensor parallelism of the
    # shared-B/C mamba2 einsums is collective-bound (EXPERIMENTS §Perf
    # bonus pair: 290 -> 69 ms collective at train_4k)
    tensor_parallel=False,
)

SMOKE = ModelConfig(
    arch_id="zamba2-1.2b-smoke",
    family="hybrid",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    group=("mamba2", "shared_attn"),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=2, head_dim=32,
                  n_groups=1),
    dtype="float32",
    max_seq_len=128,
)
