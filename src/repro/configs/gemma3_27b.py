"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt (family card)]

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144, d_head=128.
Layer pattern: groups of 5 sliding-window (1024) + 1 global layer, x10,
plus a 2-local tail (62 = 10*6 + 2).  The sliding-window locals bound KV
memory for 52/62 layers => long_500k runs for this dense arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab_size=262144,
    group=("swa", "swa", "swa", "swa", "swa", "attn"),
    tail_blocks=("swa", "swa"),
    sliding_window=1024,
    rope_theta=1e6,
    tie_embeddings=True,
    max_seq_len=524288,
)

SMOKE = ModelConfig(
    arch_id="gemma3-27b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    vocab_size=512,
    group=("swa", "attn"),
    sliding_window=16,
    tie_embeddings=True,
    dtype="float32",
    max_seq_len=128,
)
