"""Model configuration schema + the assigned input shapes.

Every assigned architecture gets a ``configs/<id>.py`` exporting
``CONFIG`` (the exact full-scale config) and ``SMOKE`` (a reduced variant of
the same family: <=2 layers, d_model<=512, <=4 experts) used by CPU smoke
tests.  The full configs are exercised only through the multi-pod dry-run
(ShapeDtypeStruct lowering, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0           # deepseek-style shared expert(s)
    dense_residual_d_ff: int = 0        # arctic: parallel dense FFN
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    version: int = 1                    # 1 = Mamba1 (selective scan), 2 = Mamba2 (SSD)
    head_dim: int = 64                  # Mamba2 only
    n_groups: int = 1                   # Mamba2 only


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                         # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None        # default d_model // n_heads
    # layer program: (group pattern, repetitions, tail pattern). Block kinds:
    #   'attn'    full-attention transformer block
    #   'swa'     sliding-window attention block
    #   'mla'     multi-head latent attention block (deepseek)
    #   'moe'     MoE FFN block (attention per attn_kind)
    #   'mamba1'/'mamba2'  SSM blocks
    #   'shared_attn'      zamba2 shared-weight attention block
    group: Tuple[str, ...] = ("attn",)
    group_reps: int = 0                 # 0 -> n_layers reps of a 1-block group
    head_blocks: Tuple[str, ...] = ()   # unscanned leading blocks
    tail_blocks: Tuple[str, ...] = ()   # unscanned trailing blocks
    sliding_window: Optional[int] = None
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper): encoder consumes stub frame embeddings
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0            # e.g. 1500 frames for whisper
    # modality frontend stub (vlm/audio): prefix embeddings of this many
    # tokens are provided by input_specs() instead of computed from pixels
    n_prefix_tokens: int = 0
    max_seq_len: int = 131072
    dtype: str = "bfloat16"
    # 'native' stores KV in cfg.dtype; 'int8' stores per-(token,head)
    # scaled int8 (halves the memory-bound decode term; §Perf pair 3)
    kv_cache_dtype: str = "native"
    # small models (whisper-tiny) waste the 16-way model axis: heads don't
    # divide it and SPMD falls back to full rematerialization — turn tensor
    # parallelism off and let them ride the data axis only
    tensor_parallel: bool = True
    # route hot-spots through the Pallas kernels (decode attention, mamba
    # scans); interpret=True on CPU, compiled on TPU
    use_pallas_kernels: bool = False

    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # -- derived -----------------------------------------------------------------

    @property
    def layer_program(self) -> Tuple[Tuple[str, ...], int, Tuple[str, ...], Tuple[str, ...]]:
        """(head_blocks, reps, group, tail_blocks) fully resolved."""
        if self.group_reps == 0:
            def real(blocks):  # shared_attn does not count toward n_layers
                return sum(1 for b in blocks if b != "shared_attn")
            remaining = self.n_layers - real(self.head_blocks) \
                - real(self.tail_blocks)
            reps = remaining // max(1, real(self.group))
            return (self.head_blocks, reps, self.group, self.tail_blocks)
        return (self.head_blocks, self.group_reps, self.group, self.tail_blocks)

    def check(self) -> None:
        head, reps, group, tail = self.layer_program
        n = len(head) + reps * len(group) + len(tail)
        # shared_attn blocks do not count toward n_layers (shared weights,
        # they are "extra" invocations in zamba-style hybrids)
        n_shared = (list(head) + list(group) * reps + list(tail)).count("shared_attn")
        assert n - n_shared == self.n_layers, \
            f"{self.arch_id}: layer program gives {n - n_shared} != {self.n_layers}"

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        dh = self.d_head
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        head, reps, group, tail = self.layer_program
        blocks = list(head) + list(group) * reps + list(tail)
        seen_shared = False
        total = emb
        for b in blocks:
            if b == "shared_attn":
                if seen_shared:
                    continue
                seen_shared = True
            total += self._block_params(b)
        if self.is_encoder_decoder:
            total += self.n_encoder_layers * self._block_params("attn")
        return total

    def _block_params(self, kind: str) -> int:
        d, f = self.d_model, self.d_ff
        dh, nq, nkv = self.d_head, self.n_heads, self.n_kv_heads
        attn = d * nq * dh + 2 * d * nkv * dh + nq * dh * d
        mlp3 = 3 * d * f
        if kind in ("attn", "swa", "shared_attn"):
            return attn + mlp3 + 2 * d
        if kind == "xattn":
            return 2 * attn + mlp3 + 3 * d
        if kind == "mla":
            m = self.mla
            q = d * m.q_lora_rank + m.q_lora_rank * nq * (
                m.qk_nope_head_dim + m.qk_rope_head_dim)
            kv = d * (m.kv_lora_rank + m.qk_rope_head_dim) + \
                m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
            o = nq * m.v_head_dim * d
            return q + kv + o + mlp3 + 2 * d
        if kind == "moe":
            mo = self.moe
            experts = mo.n_experts * 3 * d * mo.d_ff_expert
            shared = mo.n_shared_experts * 3 * d * mo.d_ff_expert
            dense = 3 * d * mo.dense_residual_d_ff
            router = d * mo.n_experts
            base_attn = (self._block_params("mla") - mlp3 - 2 * d
                         if self.mla else attn)
            return base_attn + experts + shared + dense + router + 2 * d
        if kind in ("mamba1", "mamba2"):
            s = self.ssm
            d_in = s.expand * d
            if s.version == 1:
                return (d * 2 * d_in + s.d_conv * d_in
                        + d_in * (s.d_state * 2 + d_in // 16)  # x_proj(B,C,dt_rank)
                        + (d_in // 16) * d_in                  # dt_proj
                        + d_in * s.d_state + d_in              # A, D
                        + d_in * d + d)                        # out_proj, norm
            n_heads_m = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            return (d * (2 * d_in + 2 * s.n_groups * s.d_state + n_heads_m)
                    + s.d_conv * conv_dim + n_heads_m * 2
                    + d_in * d + d_in + d)
        raise ValueError(kind)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed-active experts)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        inactive = (mo.n_experts - mo.top_k) * 3 * self.d_model * mo.d_ff_expert
        head, reps, group, tail = self.layer_program
        n_moe = (list(head) + list(group) * reps + list(tail)).count("moe")
        return self.param_count() - n_moe * inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
