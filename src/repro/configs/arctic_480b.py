"""arctic-480b [moe] — dense-MoE hybrid: every layer has a parallel dense
residual FFN + a 128-expert top-2 MoE. [hf:Snowflake/snowflake-arctic-base]

35L d_model=7168 56H (GQA kv=8) expert d_ff=4864 vocab=32000.
Full attention => long_500k skipped.  Experts are sharded over the model
axis (expert parallelism), expert d_ff over the data/fsdp axis.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    group=("moe",),
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual_d_ff=4864, capacity_factor=1.25),
    max_seq_len=32768,
)

SMOKE = ModelConfig(
    arch_id="arctic-480b-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    group=("moe",),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                  dense_residual_d_ff=128, capacity_factor=2.0),
    dtype="float32",
    max_seq_len=128,
)
