"""falcon-mamba-7b [ssm] — pure Mamba1, attention-free. [arXiv:2410.05355]

64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16, expand=2 (d_inner 8192).
No attention anywhere; DOMINO applies unchanged (it constrains logits) but
speculative verification snapshots the recurrent state for rollback
(DESIGN.md §Arch-applicability).  O(1) state => long_500k runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_head=64,
    d_ff=0,
    vocab_size=65024,
    group=("mamba1",),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, version=1),
    max_seq_len=524288,
)

SMOKE = ModelConfig(
    arch_id="falcon-mamba-7b-smoke",
    family="ssm",
    n_layers=2,
    d_model=128,
    n_heads=1,
    n_kv_heads=1,
    d_head=64,
    d_ff=0,
    vocab_size=512,
    group=("mamba1",),
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2, version=1),
    dtype="float32",
    max_seq_len=128,
)
