"""Continuous-batching constrained scheduler.

Replaces the old lockstep ``generate_batch``: a fixed-capacity decode batch
whose rows (KV "slots") are admitted and evicted independently.  Finished
requests free their slot immediately and the next waiting request is
prefilled into it, so the batch stays full under load instead of draining
to the slowest request.

Design points (ISSUE 1 tentpole):
 - admission prefills each request at its EXACT prompt length (B=1, no
   padding) and scatters the resulting row cache into the slot — this is
   what makes recurrent (SSM) and ring-buffer (SWA) rows correct: their
   state never sees pad tokens;
 - every decode step runs ONE batched forward over all slots; grammar
   masks are applied device-side through the fused
   ``kernels/masked_sample`` Pallas op (host only ships the (B, V) bit
   mask and reads back (B,) token ids);
 - speculative decoding (paper §3.6) runs per-row: one (B, 1+s) decode
   verifies every row's proposal chain; rows on full-attention/MLA archs
   roll their per-row cache length back, rows on SSM/SWA archs re-feed
   their accepted tokens from the pre-speculation cache (B=1, exact
   length) and are scattered back into the slot;
 - all sessions share the engine's TreeCache (and count model); call
   ``warm()`` to run the offline ``precompute()`` pass before serving.

Token selection is identical to the single-request engine path at
temperature 0 (greedy masked argmax, ties to the lowest index), so
per-request outputs match ``ServingEngine.generate`` token-for-token.
"""
from __future__ import annotations

import collections
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.masked_sample.ops import masked_argmax
from repro.serving.session import GenerationResult, Session


# -- per-slot cache surgery ----------------------------------------------------
#
# Cache pytree layout (models/kvcache.py): {"len", "head": [block...],
# "group": {"b#": stacked blocks (leading reps axis)}, "tail": [block...]}.
# head/tail leaves carry batch on axis 0, group leaves on axis 1 (after the
# reps axis); "len" is (B,) in a ragged batch cache and scalar in a B=1 row
# cache.


def _scatter_row(dst, src, slot):
    """Write a B=1 row cache ``src`` into row ``slot`` of batch cache."""
    out = dict(dst)
    out["len"] = dst["len"].at[slot].set(src["len"])
    out["head"] = [jax.tree.map(lambda d, s: d.at[slot].set(s[0]), dc, sc)
                   for dc, sc in zip(dst["head"], src["head"])]
    out["tail"] = [jax.tree.map(lambda d, s: d.at[slot].set(s[0]), dc, sc)
                   for dc, sc in zip(dst["tail"], src["tail"])]
    out["group"] = {
        k: jax.tree.map(lambda d, s: d.at[:, slot].set(s[:, 0]),
                        dst["group"][k], src["group"][k])
        for k in dst["group"]}
    return out


def _gather_row(src, slot):
    """Extract row ``slot`` of a batch cache as a B=1 row cache."""
    def row0(a):
        return jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0)

    def row1(a):
        return jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1)

    return {
        "len": jax.lax.dynamic_index_in_dim(src["len"], slot,
                                            keepdims=False),
        "head": [jax.tree.map(row0, c) for c in src["head"]],
        "tail": [jax.tree.map(row0, c) for c in src["tail"]],
        "group": {k: jax.tree.map(row1, v) for k, v in src["group"].items()},
    }


# admission: the old batch cache is dropped on assignment, so donate it —
# without donation every admission copies the whole B x max_len cache
_scatter_row_donate = jax.jit(_scatter_row, donate_argnums=(0,))
# refeed fixup: the pre-speculation snapshot may share untouched leaves
# (e.g. cross-attention xk/xv) with the current cache, so no donation
_scatter_row_jit = jax.jit(_scatter_row)
_gather_row_jit = jax.jit(_gather_row)


class ContinuousBatchingScheduler:
    """Admits requests into a fixed-capacity constrained decode batch."""

    def __init__(self, engine, capacity: int = 4):
        self.eng = engine
        self.capacity = max(1, capacity)
        self.waiting: "collections.deque[Session]" = collections.deque()
        self.slots: List[Optional[Session]] = [None] * self.capacity
        self.cache = engine.model.init_cache(self.capacity, engine.max_len)
        self.cache["len"] = jnp.zeros((self.capacity,), jnp.int32)  # ragged
        vpad = engine.model.padded_vocab
        self._logits = jnp.zeros((self.capacity, vpad), jnp.float32)
        self._raw_argmax = jax.jit(lambda lg: jnp.argmax(lg, axis=-1))
        self.n_fwd = 0                 # global forward count (all slots)
        self._next_rid = 0

    # -- public API -------------------------------------------------------------

    def warm(self) -> Dict[str, float]:
        """Run the offline tree precomputation (paper Algorithm 2) so mask
        construction never lands on the serving critical path."""
        return self.eng.precompute()

    def submit(self, prompt: str, extra_inputs=None) -> Session:
        sess = self.eng.make_session(self._next_rid, prompt, extra_inputs)
        self._next_rid += 1
        self.waiting.append(sess)
        return sess

    def run(self) -> List[GenerationResult]:
        """Drive all submitted sessions to completion; results in rid
        order."""
        done: List[Session] = []
        while self.waiting or any(s is not None for s in self.slots):
            done.extend(self.step())
        done.sort(key=lambda s: s.rid)
        return [s.result for s in done]

    def step(self) -> List[Session]:
        """One scheduler tick: admit -> select -> decode.  Returns sessions
        that finished during this tick."""
        self._finished_now: List[Session] = []
        self._admit()
        if any(s is not None for s in self.slots):
            if self.eng.speculator is not None:
                self._spec_step()
            else:
                self._plain_step()
        return self._finished_now

    # -- admission / eviction ---------------------------------------------------

    def _admit(self) -> None:
        eng = self.eng
        while self.waiting and None in self.slots:
            slot = self.slots.index(None)
            sess = self.waiting.popleft()
            row_cache = eng.model.init_cache(1, eng.max_len)
            inputs = {"tokens": jnp.asarray([sess.prompt_ids], jnp.int32)}
            if sess.extra_inputs:
                inputs.update(sess.extra_inputs)
            t0 = time.perf_counter()
            logits, row_cache = eng._prefill(eng.params, inputs, row_cache)
            self.cache = _scatter_row_donate(self.cache, row_cache, slot)
            self._logits = self._logits.at[slot].set(
                logits[0, -1].astype(jnp.float32))
            sess.model_time += time.perf_counter() - t0
            sess.n_fwd += 1
            self.n_fwd += 1
            sess.slot = slot
            sess.t_admit = time.perf_counter()
            self.slots[slot] = sess

    def _finish(self, sess: Session) -> None:
        sess.finish(self.eng.tok.decode)
        if sess.slot >= 0:
            self.slots[sess.slot] = None
        self._finished_now.append(sess)

    # -- token selection --------------------------------------------------------

    def _choose(self) -> Dict[int, int]:
        """Pick one token per occupied slot (device-side masked argmax at
        temperature 0).  Finishes dead-ended sessions; updates intervention
        stats.  Returns {slot: token}."""
        eng = self.eng
        v = eng._v
        raw = np.asarray(self._raw_argmax(self._logits))
        masks = np.zeros((self.capacity, v), dtype=np.int8)
        masks[:, 0] = 1                      # empty slots: harmless sentinel
        row_mask_bool: Dict[int, Optional[np.ndarray]] = {}
        for slot, sess in enumerate(self.slots):
            if sess is None:
                continue
            ch = sess.checker
            if ch is None:
                masks[slot, :] = 1
                row_mask_bool[slot] = None
                continue
            if eng.cfg.opportunistic and eng.cfg.temperature <= 0.0:
                t0 = time.perf_counter()
                ok = ch.check_token(int(raw[slot]))
                sess.mask_time += time.perf_counter() - t0
                if ok:
                    masks[slot, :] = 0
                    masks[slot, raw[slot]] = 1
                    row_mask_bool[slot] = None
                    continue
            t0 = time.perf_counter()
            m = ch.mask()
            sess.mask_time += time.perf_counter() - t0
            if not m.any():
                sess.dead_end = True
                self._finish(sess)
                continue
            masks[slot, :] = 0
            masks[slot, m] = 1
            row_mask_bool[slot] = m
        occupied = [i for i, s in enumerate(self.slots) if s is not None]
        if not occupied:
            return {}
        if eng.cfg.temperature <= 0.0:
            idx, _ = masked_argmax(self._logits[:, :v], jnp.asarray(masks))
            toks = np.asarray(idx)
        else:
            lg_host = np.asarray(self._logits)[:, :v]
            toks = np.zeros(self.capacity, np.int64)
            for slot in occupied:
                m = row_mask_bool.get(slot)
                toks[slot] = eng._select(lg_host[slot], m)
        out: Dict[int, int] = {}
        for slot in occupied:
            sess = self.slots[slot]
            tok = int(toks[slot])
            sess.n_int += int(tok != int(raw[slot]))
            out[slot] = tok
        return out

    # -- plain decode tick ------------------------------------------------------

    def _commit_first(self, chosen: Dict[int, int]) -> Dict[int, int]:
        """Advance checkers / budgets for the chosen tokens; finish rows
        that hit EOS or exhaust their budget.  Returns {slot: token} for
        rows that still need a forward."""
        eng = self.eng
        live: Dict[int, int] = {}
        for slot, tok in chosen.items():
            sess = self.slots[slot]
            ch = sess.checker
            if tok == eng.tok.eos_id:
                if ch is not None:
                    ch.advance(tok)
                sess.finished_eos = True
                self._finish(sess)
                continue
            if ch is not None and eng.speculator is not None \
                    and hasattr(ch, "clone"):
                eng.speculator.observe(ch.state_key(), tok)
            if ch is not None:
                ch.advance(tok)
            sess.out_ids.append(tok)
            sess.budget -= 1
            if sess.budget <= 0:
                self._finish(sess)
                continue
            live[slot] = tok
        return live

    def _run_decode(self, feed: jnp.ndarray):
        """One batched forward; attributes time/count to resident rows.
        Blocks until the device finishes so per-request model_time_s
        measures execution, not dispatch (the host would otherwise pay the
        wait inside the next tick's argmax readback, attributed to
        nothing)."""
        eng = self.eng
        t0 = time.perf_counter()
        lg, self.cache = eng._decode(eng.params, self.cache, feed)
        lg.block_until_ready()
        dt = time.perf_counter() - t0
        self.n_fwd += 1
        for sess in self.slots:
            if sess is not None:
                sess.n_fwd += 1
                sess.model_time += dt
        return lg

    def _plain_step(self) -> None:
        eng = self.eng
        live = self._commit_first(self._choose())
        if not any(s is not None for s in self.slots):
            return
        feed = [[eng.tok.pad_id]] * self.capacity
        for slot, tok in live.items():
            feed[slot] = [tok]
        lg = self._run_decode(jnp.asarray(feed, jnp.int32))
        self._logits = lg[:, -1].astype(jnp.float32)

    # -- speculative decode tick (§3.6) -----------------------------------------

    def _spec_step(self) -> None:
        eng = self.eng
        pad = eng.tok.pad_id
        live = self._commit_first(self._choose())
        if not any(s is not None for s in self.slots):
            return
        proposals: Dict[int, List[int]] = {}
        for slot, tok in live.items():
            ch = self.slots[slot].checker
            props = []
            if ch is not None and hasattr(ch, "clone"):
                props = eng.speculator.propose(ch)
            self.slots[slot].n_prop += len(props)
            proposals[slot] = props
        if all(len(p) == 0 for p in proposals.values()):
            # nothing to verify anywhere: plain-width forward, no rollback
            feed = [[pad]] * self.capacity
            for slot, tok in live.items():
                feed[slot] = [tok]
            lg = self._run_decode(jnp.asarray(feed, jnp.int32))
            self._logits = lg[:, -1].astype(jnp.float32)
            return
        width = 1 + eng.cfg.spec_s
        feed = [[pad] * width for _ in range(self.capacity)]
        for slot, tok in live.items():
            row = [tok] + proposals[slot]
            feed[slot][:len(row)] = row
        snapshot = self.cache          # JAX arrays are immutable: free
        snap_len = snapshot["len"]
        lg_dev = self._run_decode(jnp.asarray(feed, jnp.int32))
        lg_host = np.asarray(lg_dev)[:, :, :eng._v]
        # rows not in `live` consumed the full pad width; "accepting" it
        # keeps their (garbage, to-be-overwritten) length bookkeeping
        # consistent with the decoded cache
        accepted_vec = np.full(self.capacity, eng.cfg.spec_s, np.int32)
        for slot, props in proposals.items():
            accepted_vec[slot] = self._verify_row(slot, props, lg_host[slot])
        if eng._needs_refeed:
            self._fixup_refeed(snapshot, live, proposals, accepted_vec,
                               lg_dev)
        else:
            # per-row rollback: KV entries beyond `len` are masked by
            # validity, so rewinding the per-row length is the whole rollback
            cache = dict(self.cache)
            cache["len"] = snap_len + 1 + jnp.asarray(accepted_vec)
            self.cache = cache
            self._logits = lg_dev[
                jnp.arange(self.capacity), jnp.asarray(accepted_vec)
            ].astype(jnp.float32)

    def _verify_row(self, slot: int, props: List[int],
                    lg_row: np.ndarray) -> int:
        """Greedy per-row verification, identical to the single-request
        path: accept the longest prefix where the proposal matches the
        (masked) selection at each position."""
        eng = self.eng
        sess = self.slots[slot]
        ch = sess.checker
        accepted = 0
        for i, prop in enumerate(props):
            if sess.budget <= 0:
                break
            tok_i = None
            if eng.cfg.temperature <= 0.0 \
                    and int(lg_row[i].argmax()) == prop:
                t0 = time.perf_counter()
                ok = ch.check_token(prop)
                sess.mask_time += time.perf_counter() - t0
                if ok:
                    tok_i = prop
            if tok_i is None:
                tok_i, intervened, mask_dt = eng._pick(lg_row[i], ch)
                sess.mask_time += mask_dt
                if tok_i is None:          # dead end mid-verification
                    sess.dead_end = True
                    break
                sess.n_int += intervened
            if tok_i != prop:
                break
            eng.speculator.observe(ch.state_key(), tok_i)
            ch.advance(tok_i)
            accepted += 1
            if tok_i == eng.tok.eos_id:
                sess.finished_eos = True
                break
            sess.out_ids.append(tok_i)
            sess.budget -= 1
        sess.n_acc += accepted
        if sess.finished_eos or sess.dead_end or sess.budget <= 0:
            self._finish(sess)
        return accepted

    def _fixup_refeed(self, snapshot, live, proposals, accepted_vec,
                      lg_dev) -> None:
        """SSM/SWA rows cannot rewind state: re-feed each partially-accepted
        row's committed tokens from the pre-speculation cache (B=1, exact
        length) and scatter the result back into its slot."""
        eng = self.eng
        s_max = eng.cfg.spec_s
        for slot, tok in live.items():
            sess = self.slots[slot]
            if sess is None:
                # finished during verification: the slot is free and its
                # row state is overwritten at the next admission
                continue
            a = int(accepted_vec[slot])
            props = proposals[slot]
            if a == len(props) and len(props) == s_max:
                # full accept, no pads: the batch-decoded row state is exact
                self._logits = self._logits.at[slot].set(
                    lg_dev[slot, -1].astype(jnp.float32))
                continue
            committed = [tok] + props[:a]
            row = _gather_row_jit(snapshot, slot)
            t0 = time.perf_counter()
            lg_re, row = eng._decode(
                eng.params, row, jnp.asarray([committed], jnp.int32))
            self.cache = _scatter_row_jit(self.cache, row, slot)
            self._logits = self._logits.at[slot].set(
                lg_re[0, -1].astype(jnp.float32))
            dt = time.perf_counter() - t0
            self.n_fwd += 1
            sess.n_fwd += 1
            sess.model_time += dt
