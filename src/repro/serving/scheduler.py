"""Continuous-batching constrained scheduler.

Replaces the old lockstep ``generate_batch``: a fixed-capacity decode batch
whose rows (KV "slots") are admitted and evicted independently.  Finished
requests free their slot immediately and the next waiting request is
prefilled into it, so the batch stays full under load instead of draining
to the slowest request.

Design points (ISSUE 1 tentpole):
 - admission prefills each request at B=1 and scatters the resulting row
   cache into the slot — this is what makes recurrent (SSM) and
   ring-buffer (SWA) rows correct: their state never sees pad tokens.
   Full-attention / MLA admissions are additionally padded to
   power-of-two length buckets (with a true-length validity marker) so
   the prefill compiles O(log max_len) programs under heavy traffic
   instead of one per distinct prompt length;
 - every decode step runs ONE batched forward over all slots; grammar
   masks are applied device-side through the fused
   ``kernels/masked_sample`` Pallas op (host only ships the (B, V) bit
   mask and reads back (B,) token ids);
 - the forward is dispatched asynchronously and the host builds the NEXT
   step's grammar masks while the device executes (ISSUE 2 tentpole):
   mask_time moves off the step critical path — it still accrues
   per-session, with the hidden portion reported as ``mask_overlap_s``;
 - speculative decoding (paper §3.6) runs per-row: one (B, 1+s) decode
   verifies every row's proposal chain; rows on full-attention/MLA archs
   roll their per-row cache length back, rows on SSM/SWA archs re-feed
   their accepted tokens from the pre-speculation cache — grouped by
   accepted length, so each group is one gather/decode/scatter round
   instead of a B=1 decode per row;
 - all sessions share the engine's TreeCache (and count model); call
   ``warm()`` to run the offline ``precompute()`` pass before serving.

Token selection is identical to the single-request engine path at
temperature 0 (greedy masked argmax, ties to the lowest index), so
per-request outputs match ``ServingEngine.generate`` token-for-token.
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.masked_sample.ops import masked_argmax
from repro.serving.session import GenerationResult, Session


# -- per-slot cache surgery ----------------------------------------------------
#
# Cache pytree layout (models/kvcache.py): {"len", "head": [block...],
# "group": {"b#": stacked blocks (leading reps axis)}, "tail": [block...]}.
# head/tail leaves carry batch on axis 0, group leaves on axis 1 (after the
# reps axis); "len" is (B,) in a ragged batch cache and scalar in a B=1 row
# cache.


def _scatter_row(dst, src, slot):
    """Write a B=1 row cache ``src`` into row ``slot`` of batch cache."""
    out = dict(dst)
    out["len"] = dst["len"].at[slot].set(src["len"])
    out["head"] = [jax.tree.map(lambda d, s: d.at[slot].set(s[0]), dc, sc)
                   for dc, sc in zip(dst["head"], src["head"])]
    out["tail"] = [jax.tree.map(lambda d, s: d.at[slot].set(s[0]), dc, sc)
                   for dc, sc in zip(dst["tail"], src["tail"])]
    out["group"] = {
        k: jax.tree.map(lambda d, s: d.at[:, slot].set(s[:, 0]),
                        dst["group"][k], src["group"][k])
        for k in dst["group"]}
    return out


def _gather_rows(src, idx):
    """Extract rows ``idx`` (traced (K,) int32) of a batch cache as a
    B=K ragged cache (``len`` stays a vector, so the refeed decode takes
    the per-row ragged write path)."""
    def g0(a):
        return jnp.take(a, idx, axis=0)

    def g1(a):
        return jnp.take(a, idx, axis=1)

    return {
        "len": jnp.take(src["len"], idx, axis=0),
        "head": [jax.tree.map(g0, c) for c in src["head"]],
        "tail": [jax.tree.map(g0, c) for c in src["tail"]],
        "group": {k: jax.tree.map(g1, v) for k, v in src["group"].items()},
    }


def _scatter_rows(dst, src, idx):
    """Write a B=K cache ``src`` back into rows ``idx`` of batch cache."""
    def s0(d, s):
        return d.at[idx].set(s)

    def s1(d, s):
        return d.at[:, idx].set(s)

    out = dict(dst)
    out["len"] = dst["len"].at[idx].set(src["len"])
    out["head"] = [jax.tree.map(s0, dc, sc)
                   for dc, sc in zip(dst["head"], src["head"])]
    out["tail"] = [jax.tree.map(s0, dc, sc)
                   for dc, sc in zip(dst["tail"], src["tail"])]
    out["group"] = {k: jax.tree.map(s1, dst["group"][k], src["group"][k])
                    for k in dst["group"]}
    return out


# admission: the old batch cache is dropped on assignment, so donate it —
# without donation every admission copies the whole B x max_len cache
_scatter_row_donate = jax.jit(_scatter_row, donate_argnums=(0,))
# refeed fixup: the pre-speculation snapshot may share untouched leaves
# (e.g. cross-attention xk/xv) with the current cache, so no donation
_scatter_rows_jit = jax.jit(_scatter_rows)
_gather_rows_jit = jax.jit(_gather_rows)


def _bucket_len(n: int, cap: int) -> int:
    """Smallest power of two >= n, clamped to the cache capacity."""
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


class ContinuousBatchingScheduler:
    """Admits requests into a fixed-capacity constrained decode batch.

    ``overlap`` pipelines host mask construction with device execution;
    ``bucket_prefill`` pads full-attention/MLA admissions to power-of-two
    prompt lengths.  Both default on; they are observationally pure
    (token-for-token identical output) and exist as flags only so tests
    and benchmarks can measure them.
    """

    def __init__(self, engine, capacity: int = 4, overlap: bool = True,
                 bucket_prefill: bool = True):
        self.eng = engine
        self.capacity = max(1, capacity)
        self.overlap = overlap
        self.bucket_prefill = bucket_prefill
        self.waiting: "collections.deque[Session]" = collections.deque()
        self.slots: List[Optional[Session]] = [None] * self.capacity
        self.cache = engine.model.init_cache(self.capacity, engine.max_len)
        self.cache["len"] = jnp.zeros((self.capacity,), jnp.int32)  # ragged
        vpad = engine.model.padded_vocab
        self._logits = jnp.zeros((self.capacity, vpad), jnp.float32)
        self._raw_argmax = jax.jit(lambda lg: jnp.argmax(lg, axis=-1))
        # masks prebuilt from each slot's current checker state while the
        # device executed the previous forward; dropped on any checker
        # advance / slot turnover (state changed -> mask stale)
        self._premask: Dict[int, np.ndarray] = {}
        self.premask_hits = 0          # selections served by a prebuild
        self.n_fwd = 0                 # global forward count (all slots)
        self._next_rid = 0

    # -- public API -------------------------------------------------------------

    def warm(self) -> Dict[str, float]:
        """Run the offline tree precomputation (paper Algorithm 2) so mask
        construction never lands on the serving critical path."""
        return self.eng.precompute()

    def submit(self, prompt: str, extra_inputs=None) -> Session:
        sess = self.eng.make_session(self._next_rid, prompt, extra_inputs)
        self._next_rid += 1
        self.waiting.append(sess)
        return sess

    def run(self) -> List[GenerationResult]:
        """Drive all submitted sessions to completion; results in rid
        order."""
        done: List[Session] = []
        while self.waiting or any(s is not None for s in self.slots):
            done.extend(self.step())
        done.sort(key=lambda s: s.rid)
        return [s.result for s in done]

    def step(self) -> List[Session]:
        """One scheduler tick: admit -> select -> decode.  Returns sessions
        that finished during this tick."""
        self._finished_now: List[Session] = []
        self._admit()
        if any(s is not None for s in self.slots):
            if self.eng.speculator is not None:
                self._spec_step()
            else:
                self._plain_step()
        self._reset_vacant_lens()
        return self._finished_now

    # -- admission / eviction ---------------------------------------------------

    def _admit(self) -> None:
        eng = self.eng
        while self.waiting and None in self.slots:
            slot = self.slots.index(None)
            sess = self.waiting.popleft()
            self._premask.pop(slot, None)
            row_cache = eng.model.init_cache(1, eng.max_len)
            ids = list(sess.prompt_ids)
            inputs = {"tokens": jnp.asarray([ids], jnp.int32)}
            if self.bucket_prefill and not eng._needs_refeed \
                    and not sess.extra_inputs:
                # power-of-two bucket: pads ride beyond the valid frontier
                # (masked by pos < len, overwritten by later decodes), the
                # head reads the true last token.  Gated off refeed archs:
                # ring/recurrent state would absorb the pads.
                p = _bucket_len(len(ids), eng.max_len)
                inputs["tokens"] = jnp.asarray(
                    [ids + [eng.tok.pad_id] * (p - len(ids))], jnp.int32)
                inputs["length"] = jnp.asarray(len(ids), jnp.int32)
            if sess.extra_inputs:
                inputs.update(sess.extra_inputs)
            t0 = time.perf_counter()
            logits, row_cache = eng._prefill(eng.params, inputs, row_cache)
            self.cache = _scatter_row_donate(self.cache, row_cache, slot)
            self._logits = self._logits.at[slot].set(
                logits[0, -1].astype(jnp.float32))
            sess.model_time += time.perf_counter() - t0
            sess.n_fwd += 1
            self.n_fwd += 1
            sess.slot = slot
            sess.t_admit = time.perf_counter()
            self.slots[slot] = sess

    def _reset_vacant_lens(self) -> None:
        """Vacant slots' rows are garbage by contract, but every batched
        forward still advances their ragged ``len`` — left alone, the
        fused kernel would stream ever more dead cache tiles for freed
        rows.  Pin them to 0 so the per-row early-exit actually skips
        them (admission overwrites ``len`` when it scatters a new row)."""
        if all(s is not None for s in self.slots):
            return
        occ = jnp.asarray([0 if s is None else 1 for s in self.slots],
                          jnp.int32)
        cache = dict(self.cache)
        cache["len"] = cache["len"] * occ
        self.cache = cache

    def _finish(self, sess: Session) -> None:
        sess.finish(self.eng.tok.decode)
        if sess.slot >= 0:
            self._premask.pop(sess.slot, None)
            self.slots[sess.slot] = None
        self._finished_now.append(sess)

    # -- mask pipeline ----------------------------------------------------------

    def _prebuild_masks(self):
        """Build the next selection's grammar masks from current checker
        state.  Called while the device executes the just-dispatched
        forward; build time accrues to per-session mask_time immediately,
        but the overlap credit is decided by the caller (``_run_decode``)
        once it knows whether the device actually outlasted the build.
        Returns [(session, build_seconds), ...] for that decision."""
        built = []
        for slot, sess in enumerate(self.slots):
            if sess is None or sess.checker is None \
                    or slot in self._premask:
                continue
            t0 = time.perf_counter()
            m = sess.checker.mask()
            dt = time.perf_counter() - t0
            sess.mask_time += dt
            self._premask[slot] = m
            built.append((sess, dt))
        return built

    # -- token selection --------------------------------------------------------

    def _choose(self) -> Dict[int, int]:
        """Pick one token per occupied slot (device-side masked argmax at
        temperature 0).  Finishes dead-ended sessions; updates intervention
        stats.  Returns {slot: token}."""
        eng = self.eng
        v = eng._v
        raw = np.asarray(self._raw_argmax(self._logits))
        masks = np.zeros((self.capacity, v), dtype=np.int8)
        masks[:, 0] = 1                      # empty slots: harmless sentinel
        row_mask_bool: Dict[int, Optional[np.ndarray]] = {}
        for slot, sess in enumerate(self.slots):
            if sess is None:
                continue
            ch = sess.checker
            if ch is None:
                masks[slot, :] = 1
                row_mask_bool[slot] = None
                continue
            if eng.cfg.opportunistic and eng.cfg.temperature <= 0.0:
                t0 = time.perf_counter()
                ok = ch.check_token(int(raw[slot]))
                sess.mask_time += time.perf_counter() - t0
                if ok:
                    masks[slot, :] = 0
                    masks[slot, raw[slot]] = 1
                    row_mask_bool[slot] = None
                    continue
            m = self._premask.pop(slot, None)   # overlapped prebuild
            if m is None:
                t0 = time.perf_counter()
                m = ch.mask()
                sess.mask_time += time.perf_counter() - t0
            else:
                self.premask_hits += 1
            if not m.any():
                sess.dead_end = True
                self._finish(sess)
                continue
            masks[slot, :] = 0
            masks[slot, m] = 1
            row_mask_bool[slot] = m
        occupied = [i for i, s in enumerate(self.slots) if s is not None]
        if not occupied:
            return {}
        if eng.cfg.temperature <= 0.0:
            idx, _ = masked_argmax(self._logits[:, :v], jnp.asarray(masks))
            toks = np.asarray(idx)
        else:
            lg_host = np.asarray(self._logits)[:, :v]
            toks = np.zeros(self.capacity, np.int64)
            for slot in occupied:
                m = row_mask_bool.get(slot)
                toks[slot] = eng._select(lg_host[slot], m)
        out: Dict[int, int] = {}
        for slot in occupied:
            sess = self.slots[slot]
            tok = int(toks[slot])
            sess.n_int += int(tok != int(raw[slot]))
            out[slot] = tok
        return out

    # -- plain decode tick ------------------------------------------------------

    def _commit_first(self, chosen: Dict[int, int]) -> Dict[int, int]:
        """Advance checkers / budgets for the chosen tokens; finish rows
        that hit EOS or exhaust their budget.  Returns {slot: token} for
        rows that still need a forward."""
        eng = self.eng
        live: Dict[int, int] = {}
        for slot, tok in chosen.items():
            sess = self.slots[slot]
            ch = sess.checker
            if tok == eng.tok.eos_id:
                if ch is not None:
                    ch.advance(tok)
                sess.finished_eos = True
                self._finish(sess)
                continue
            if ch is not None and eng.speculator is not None \
                    and hasattr(ch, "clone"):
                eng.speculator.observe(ch.state_key(), tok)
            if ch is not None:
                ch.advance(tok)
                self._premask.pop(slot, None)   # state moved: mask stale
            sess.out_ids.append(tok)
            sess.budget -= 1
            if sess.budget <= 0:
                self._finish(sess)
                continue
            live[slot] = tok
        return live

    def _run_decode(self, feed: jnp.ndarray,
                    overlap_fn: Optional[Callable[[], None]] = None):
        """One batched forward; attributes time/count to resident rows.
        The forward is dispatched asynchronously; ``overlap_fn`` (next
        step's host-side mask construction) runs while the device
        executes, then we block so per-request model_time_s measures
        execution, not dispatch (the host would otherwise pay the wait
        inside the next tick's argmax readback, attributed to nothing)."""
        eng = self.eng
        t0 = time.perf_counter()
        lg, self.cache = eng._decode(eng.params, self.cache, feed)
        built = []
        if overlap_fn is not None and self.overlap:
            built = overlap_fn() or []
        t_mask_end = time.perf_counter()
        lg.block_until_ready()
        wait = time.perf_counter() - t_mask_end
        # overlap credit only when the device provably outlasted the
        # prebuild (we still had to wait on it afterwards); if the build
        # outran the device, the excess sat on the critical path — it
        # stays in mask_time uncredited and is excluded from the model
        # wall below, so the two fields still decompose the step
        hidden = wait > 1e-5
        m_total = sum(b_dt for _, b_dt in built)
        if hidden:
            for b_sess, b_dt in built:
                b_sess.mask_overlap += b_dt
        dt = time.perf_counter() - t0 - (0.0 if hidden else m_total)
        self.n_fwd += 1
        for sess in self.slots:
            if sess is not None:
                sess.n_fwd += 1
                sess.model_time += dt
        return lg

    def _plain_step(self) -> None:
        eng = self.eng
        live = self._commit_first(self._choose())
        if not any(s is not None for s in self.slots):
            return
        feed = [[eng.tok.pad_id]] * self.capacity
        for slot, tok in live.items():
            feed[slot] = [tok]
        lg = self._run_decode(jnp.asarray(feed, jnp.int32),
                              overlap_fn=self._prebuild_masks)
        self._logits = lg[:, -1].astype(jnp.float32)

    # -- speculative decode tick (§3.6) -----------------------------------------

    def _spec_step(self) -> None:
        eng = self.eng
        pad = eng.tok.pad_id
        live = self._commit_first(self._choose())
        if not any(s is not None for s in self.slots):
            return
        proposals: Dict[int, List[int]] = {}
        for slot, tok in live.items():
            ch = self.slots[slot].checker
            props = []
            if ch is not None and hasattr(ch, "clone"):
                props = eng.speculator.propose(ch)
            self.slots[slot].n_prop += len(props)
            proposals[slot] = props
        if all(len(p) == 0 for p in proposals.values()):
            # nothing to verify anywhere: plain-width forward, no rollback
            feed = [[pad]] * self.capacity
            for slot, tok in live.items():
                feed[slot] = [tok]
            lg = self._run_decode(jnp.asarray(feed, jnp.int32),
                                  overlap_fn=self._prebuild_masks)
            self._logits = lg[:, -1].astype(jnp.float32)
            return
        width = 1 + eng.cfg.spec_s
        feed = [[pad] * width for _ in range(self.capacity)]
        for slot, tok in live.items():
            row = [tok] + proposals[slot]
            feed[slot][:len(row)] = row
        snapshot = self.cache          # JAX arrays are immutable: free
        snap_len = snapshot["len"]
        # overlapped prebuild: checker state is post-commit, i.e. exactly
        # the state verification position 0 selects from — _verify_row
        # consumes the mask, and untouched rows keep it for the next tick
        lg_dev = self._run_decode(jnp.asarray(feed, jnp.int32),
                                  overlap_fn=self._prebuild_masks)
        lg_host = np.asarray(lg_dev)[:, :, :eng._v]
        # rows not in `live` consumed the full pad width; "accepting" it
        # keeps their (garbage, to-be-overwritten) length bookkeeping
        # consistent with the decoded cache
        accepted_vec = np.full(self.capacity, eng.cfg.spec_s, np.int32)
        for slot, props in proposals.items():
            accepted_vec[slot] = self._verify_row(slot, props, lg_host[slot])
        if eng._needs_refeed:
            self._fixup_refeed(snapshot, live, proposals, accepted_vec,
                               lg_dev)
        else:
            # per-row rollback: KV entries beyond `len` are masked by
            # validity, so rewinding the per-row length is the whole rollback
            cache = dict(self.cache)
            cache["len"] = snap_len + 1 + jnp.asarray(accepted_vec)
            self.cache = cache
            self._logits = lg_dev[
                jnp.arange(self.capacity), jnp.asarray(accepted_vec)
            ].astype(jnp.float32)

    def _verify_row(self, slot: int, props: List[int],
                    lg_row: np.ndarray) -> int:
        """Greedy per-row verification, identical to the single-request
        path: accept the longest prefix where the proposal matches the
        (masked) selection at each position."""
        eng = self.eng
        sess = self.slots[slot]
        ch = sess.checker
        accepted = 0
        for i, prop in enumerate(props):
            if sess.budget <= 0:
                break
            tok_i = None
            if eng.cfg.temperature <= 0.0 \
                    and int(lg_row[i].argmax()) == prop:
                t0 = time.perf_counter()
                ok = ch.check_token(prop)
                sess.mask_time += time.perf_counter() - t0
                if ok:
                    tok_i = prop
            if tok_i is None:
                # position 0 selects from the state the overlapped
                # prebuild saw; later positions advanced past it
                pre = self._premask.pop(slot, None) if i == 0 else None
                # under opportunistic mode _pick may accept the raw
                # argmax without reading the premask — don't count a hit
                # we can't attest
                if not (eng.cfg.opportunistic
                        and eng.cfg.temperature <= 0.0):
                    self.premask_hits += int(pre is not None)
                tok_i, intervened, mask_dt = eng._pick(lg_row[i], ch,
                                                       premask=pre)
                sess.mask_time += mask_dt
                if tok_i is None:          # dead end mid-verification
                    sess.dead_end = True
                    break
                sess.n_int += intervened
            if tok_i != prop:
                break
            eng.speculator.observe(ch.state_key(), tok_i)
            ch.advance(tok_i)
            self._premask.pop(slot, None)   # state moved: mask stale
            accepted += 1
            if tok_i == eng.tok.eos_id:
                sess.finished_eos = True
                break
            sess.out_ids.append(tok_i)
            sess.budget -= 1
        sess.n_acc += accepted
        if sess.finished_eos or sess.dead_end or sess.budget <= 0:
            self._finish(sess)
        return accepted

    def _fixup_refeed(self, snapshot, live, proposals, accepted_vec,
                      lg_dev) -> None:
        """SSM/SWA rows cannot rewind state: re-feed each partially-
        accepted row's committed tokens from the pre-speculation cache.
        Rows are grouped by committed length, so each group is ONE
        gather/decode/scatter round (B=K ragged refeed) instead of a B=1
        decode plus whole-cache scatter per row — one compile per
        (group size, width) pair, bounded by capacity x spec_s."""
        eng = self.eng
        s_max = eng.cfg.spec_s
        groups: Dict[int, List[int]] = {}
        committed: Dict[int, List[int]] = {}
        for slot, tok in live.items():
            sess = self.slots[slot]
            if sess is None:
                # finished during verification: the slot is free and its
                # row state is overwritten at the next admission
                continue
            a = int(accepted_vec[slot])
            props = proposals[slot]
            if a == len(props) and len(props) == s_max:
                # full accept, no pads: the batch-decoded row state is exact
                self._logits = self._logits.at[slot].set(
                    lg_dev[slot, -1].astype(jnp.float32))
                continue
            groups.setdefault(a, []).append(slot)
            committed[slot] = [tok] + props[:a]
        for a, slots in groups.items():
            idx = jnp.asarray(slots, jnp.int32)
            feed = jnp.asarray([committed[s] for s in slots], jnp.int32)
            t0 = time.perf_counter()
            rows = _gather_rows_jit(snapshot, idx)
            lg_re, rows = eng._decode(eng.params, rows, feed)
            self.cache = _scatter_rows_jit(self.cache, rows, idx)
            self._logits = self._logits.at[idx].set(
                lg_re[:, -1].astype(jnp.float32))
            # block so model_time measures execution, not dispatch (the
            # wait would otherwise hide in the next tick's argmax
            # readback, attributed to nothing)
            lg_re.block_until_ready()
            dt = time.perf_counter() - t0
            self.n_fwd += 1
            for slot in slots:
                sess = self.slots[slot]
                sess.n_fwd += 1
                sess.model_time += dt
