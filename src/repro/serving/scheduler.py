"""Continuous-batching constrained scheduler over a paged KV pool.

A fixed-capacity decode batch whose rows (KV "slots") are admitted and
evicted independently: finished requests free their slot immediately and
the next waiting request is prefilled into it, so the batch stays full
under load instead of draining to the slowest request.

The unit of admission is a :class:`~repro.serving.request.Request` —
``submit`` takes one (or a bare prompt string for the engine-default
request) and every per-row policy rides on the resulting Session, never
on an engine-global config.  One batch therefore freely mixes rows with
different grammars (each row's checker walks its own grammar's shared
TreeCache from the engine registry), different constraint modes
(domino/naive/online/unconstrained — unconstrained rows stage the
all-ones sentinel row in the same packed mask buffer), different EOS ids
and token budgets (checked per row in the tick loop), different
temperatures and seeds (greedy rows select through the fused device
kernel; sampled rows draw host-side from their own per-request RNG), and
different speculation knobs (the verify window is sized to the widest
resident row's ``spec_s``; non-speculative rows just skip proposing).
Per-row outputs are bitwise-identical to running each request alone on a
single-grammar engine.

Design points (ISSUE 1 tentpole):
 - admission prefills each request at B=1 and scatters the resulting row
   cache into the slot — this is what makes recurrent (SSM) and
   ring-buffer (SWA) rows correct: their state never sees pad tokens.
   Full-attention / MLA admissions are additionally padded to
   power-of-two length buckets (with a true-length validity marker) so
   the prefill compiles O(log max_len) programs under heavy traffic
   instead of one per distinct prompt length;
 - every decode step runs ONE batched forward over all slots; grammar
   masks are applied device-side through the fused
   ``kernels/masked_sample`` Pallas op.  Masks are PACKED end to end
   (ISSUE 4 tentpole): checkers assemble a ``ceil(V/32)``-word uint32
   bitset by OR-ing precomputed tree-node segments (memoized per
   immutable grammar state on the shared TreeCache — a recurring state
   is a dict lookup, counted in ``mask_cache_hits``), the scheduler
   stages rows into ONE persistent ``(capacity, ceil(V/32))`` uint32
   buffer (zero per-tick allocation; vacant slots keep a precomputed
   sentinel word row), and the kernel unpacks words in-register fused
   with the argmax — so the host ships V/8 mask bytes per slot per tick
   (8x less than the old (B, V) int8 staging array) and reads back (B,)
   token ids;
 - the forward is dispatched asynchronously and the host builds the NEXT
   step's grammar masks while the device executes (ISSUE 2 tentpole):
   mask_time moves off the step critical path — it still accrues
   per-session, with the hidden portion reported as ``mask_overlap_s``.
   Under ``opportunistic`` checking the prebuild is adaptive: it is
   skipped for slots whose previous tick's raw argmax passed the O(token)
   legality check (the mask would go unread), and resumes the tick after
   an intervention;
 - paged KV (ISSUE 3 tentpole): on pageable architectures (pure
   full-attention / MLA stacks) the slots do NOT own contiguous
   ``max_len`` cache stripes.  The cache is a shared pool of
   ``page_size``-token pages plus an (B, max_pages) block table per slot
   (models/kvcache.py); a host-side free-list allocator hands pages out
   at admission (``ceil((prompt+1)/page_size)`` — not a full-length
   stripe), grows rows page-by-page as they decode, shrinks them when
   speculative rollback rewinds the frontier, and frees them the moment
   a request finishes.  Admission blocks on pool exhaustion (the waiting
   queue provides backpressure), and mid-flight exhaustion falls back to
   vLLM-style recompute preemption: the youngest resident row returns
   its pages and re-enters the queue front, to be re-prefilled
   (prompt + generated prefix) when pages free up — the checker state
   rides along, so outputs are unchanged;
 - speculative decoding (paper §3.6) runs per-row: one (B, 1+s) decode
   verifies every row's proposal chain; rows on full-attention/MLA archs
   roll their per-row cache length back (returning now-empty pages),
   rows on SSM/SWA archs re-feed their accepted tokens from the
   pre-speculation cache — grouped by accepted length, so each group is
   one gather/decode/scatter round instead of a B=1 decode per row;
 - sessions on the same grammar share that grammar's TreeCache (and all
   sessions share the engine's count model); call ``warm()`` to run the
   offline ``precompute()`` pass over every registered grammar before
   serving;
 - fault tolerance (ISSUE 7 tentpole): every request ends in exactly one
   explicit terminal status (``GenerationResult.status``: ok | dead_end |
   deadline_exceeded | cancelled | rejected | internal_error) and one
   request's failure never perturbs its batch-mates.  Every tick starts
   with a lifecycle sweep (``_reap``): cancellation requested via
   ``cancel(rid)`` and per-request deadlines (``DecodeParams.deadline_s``,
   or the scheduler-wide ``default_deadline_s`` / ``queue_timeout_s``)
   take effect here, freeing the slot and pages immediately.  Failures
   are quarantined to the offending row: non-finite logits from the
   device step fail only that row (detected before selection), a
   checker / mask-build exception — including during the overlapped
   prebuild and speculative verification — evicts that session with
   ``internal_error`` while the tick completes for everyone else, an
   admission whose demand can NEVER be met (prompt pages > pool
   capacity, prompt > max_len) is rejected instead of blocking the FIFO
   queue forever, and ``queue_limit`` bounds the waiting queue by
   shedding overflow with ``rejected``.  A seeded
   :class:`~repro.serving.faults.FaultInjector` can be wired to the
   documented injection sites (one per tick phase), and
   ``debug_invariants=True`` audits free-list/block-table consistency
   and the slot<->session bijection at every tick boundary — surviving
   rows are asserted bitwise-identical to fault-free runs by the chaos
   suite (tests/test_faults.py).

Token selection is identical to the single-request engine path at
temperature 0 (greedy masked argmax, ties to the lowest index), so
per-request outputs match ``ServingEngine.generate`` token-for-token.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import math
import time
from typing import Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask
from repro.core.analysis import OFF_FRONTIER
from repro.core.domino import DominoDecoder
from repro.kernels.masked_sample.kernel import masked_argmax_pallas_packed
from repro.kernels.masked_sample.ops import (masked_argmax,
                                             masked_sample_packed)
from repro.models import kvcache
from repro.serving.faults import (FaultInjector, InjectedFault,
                                  InvariantViolation, check_invariants)
from repro.serving.journal import JournalEntry, TokenJournal
from repro.serving.prefix_cache import PrefixCache
from repro.serving.request import (ConstraintSpec, DecodeParams, Request,
                                   select_token)
from repro.serving.session import GenerationResult, Session
from repro.serving.supervisor import DegradationSupervisor


# -- page allocation -----------------------------------------------------------


class PagePool:
    """Host-side free-list allocator over pool page ids.

    Page 0 is the reserved trash page (vacant block-table entries point at
    it, so padded decode writes from empty slots land somewhere harmless);
    pages 1..n_pages-1 are allocatable.  LIFO reuse: a freed page is the
    next one handed out, which keeps the hot pages hot and makes
    stale-read bugs surface immediately under test.

    Pages carry refcounts so the prefix cache can share them: ``alloc``
    hands out pages at refcount 1, ``retain`` adds a reference (a radix
    node adopting the page, or a block table mapping a cached page) and
    ``release``/``free`` drops one — the page returns to the free list
    only when the LAST reference goes.  Exclusive ownership is the
    refcount-1 special case, so every pre-cache call site keeps its exact
    semantics.
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self._free: List[int] = list(range(1, n_pages))
        self._ref = np.zeros(n_pages, np.int32)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """All-or-nothing: n page ids, or None if the pool can't cover
        the request (partial grants would deadlock admission)."""
        if n > len(self._free):
            return None
        got = self._free[-n:][::-1] if n else []
        if n:
            del self._free[-n:]
        for p in got:
            self._ref[p] = 1
        return got

    def retain(self, pages) -> None:
        """Add one reference to already-allocated pages."""
        for p in pages:
            p = int(p)
            assert self._ref[p] > 0, f"retain of unallocated page {p}"
            self._ref[p] += 1

    def release(self, pages) -> None:
        """Drop one reference per page; last reference frees the page."""
        for p in pages:
            p = int(p)
            assert self._ref[p] > 0, f"release of unallocated page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
        assert len(self._free) <= self.n_pages - 1

    # historical name: exclusive owners "free" their pages (refcount 1).
    free = release

    def refcount(self, page: int) -> int:
        return int(self._ref[int(page)])


# -- per-slot cache surgery ----------------------------------------------------
#
# Cache pytree layout (models/kvcache.py): {"len", "head": [block...],
# "group": {"b#": stacked blocks (leading reps axis)}, "tail": [block...]}.
# Dense layouts carry batch on leaf axis 0 (head/tail) or 1 (group); paged
# layouts carry pool pages there instead, with the per-slot block table at
# cache["pages"].  "len" is (B,) in a ragged batch cache and scalar in a
# B=1 row cache.


def _scatter_row(dst, src, slot):
    """Write a B=1 row cache ``src`` into row ``slot`` of batch cache."""
    out = dict(dst)
    out["len"] = dst["len"].at[slot].set(src["len"])
    out["head"] = [jax.tree.map(lambda d, s: d.at[slot].set(s[0]), dc, sc)
                   for dc, sc in zip(dst["head"], src["head"])]
    out["tail"] = [jax.tree.map(lambda d, s: d.at[slot].set(s[0]), dc, sc)
                   for dc, sc in zip(dst["tail"], src["tail"])]
    out["group"] = {
        k: jax.tree.map(lambda d, s: d.at[:, slot].set(s[:, 0]),
                        dst["group"][k], src["group"][k])
        for k in dst["group"]}
    return out


def _scatter_row_paged(dst, src, slot, page_ids, page_size: int):
    """Write a dense B=1 row cache ``src`` into the pool pages
    ``page_ids`` ((max_pages,) int32) of paged batch cache ``dst``.

    ``page_ids`` is always padded to the full table width with trash-page
    zeros so this jit compiles ONCE (a (n_pg,)-shaped operand would
    recompile the whole-cache donating scatter per distinct admission
    page count): the row stripe is copied page-by-page into (generally
    non-contiguous) pool rows, and every stripe page beyond the
    allocation collapses onto pool row 0, whose contents are garbage by
    contract.  The block table itself is host-owned (the scheduler
    uploads it separately), so only ``len`` and the pool leaves change.
    """
    n_pg = page_ids.shape[0]

    def p0(d, s):          # head/tail: (P, ps, ...) <- (1, T, ...)
        blk = s[0, :n_pg * page_size].reshape(
            (n_pg, page_size) + s.shape[2:])
        return d.at[page_ids].set(blk)

    def p1(d, s):          # group: (reps, P, ps, ...) <- (reps, 1, T, ...)
        blk = s[:, 0, :n_pg * page_size].reshape(
            (s.shape[0], n_pg, page_size) + s.shape[3:])
        return d.at[:, page_ids].set(blk)

    out = dict(dst)
    out["len"] = dst["len"].at[slot].set(src["len"])
    out["head"] = [jax.tree.map(p0, dc, sc)
                   for dc, sc in zip(dst["head"], src["head"])]
    out["tail"] = [jax.tree.map(p0, dc, sc)
                   for dc, sc in zip(dst["tail"], src["tail"])]
    out["group"] = {k: jax.tree.map(p1, dst["group"][k], src["group"][k])
                    for k in dst["group"]}
    return out


def _gather_rows(src, idx):
    """Extract rows ``idx`` (traced (K,) int32) of a batch cache as a
    B=K ragged cache (``len`` stays a vector, so the refeed decode takes
    the per-row ragged write path)."""
    def g0(a):
        return jnp.take(a, idx, axis=0)

    def g1(a):
        return jnp.take(a, idx, axis=1)

    return {
        "len": jnp.take(src["len"], idx, axis=0),
        "head": [jax.tree.map(g0, c) for c in src["head"]],
        "tail": [jax.tree.map(g0, c) for c in src["tail"]],
        "group": {k: jax.tree.map(g1, v) for k, v in src["group"].items()},
    }


def _scatter_rows(dst, src, idx):
    """Write a B=K cache ``src`` back into rows ``idx`` of batch cache."""
    def s0(d, s):
        return d.at[idx].set(s)

    def s1(d, s):
        return d.at[:, idx].set(s)

    out = dict(dst)
    out["len"] = dst["len"].at[idx].set(src["len"])
    out["head"] = [jax.tree.map(s0, dc, sc)
                   for dc, sc in zip(dst["head"], src["head"])]
    out["tail"] = [jax.tree.map(s0, dc, sc)
                   for dc, sc in zip(dst["tail"], src["tail"])]
    out["group"] = {k: jax.tree.map(s1, dst["group"][k], src["group"][k])
                    for k in dst["group"]}
    return out


# admission: the old batch cache is dropped on assignment, so donate it —
# without donation every admission copies the whole B x max_len cache
_scatter_row_donate = jax.jit(_scatter_row, donate_argnums=(0,))
# refeed fixup: the pre-speculation snapshot may share untouched leaves
# (e.g. cross-attention xk/xv) with the current cache, so no donation
_scatter_rows_jit = jax.jit(_scatter_rows)
_gather_rows_jit = jax.jit(_gather_rows)


def _bucket_len(n: int, cap: int) -> int:
    """Smallest power of two >= n, clamped to the cache capacity."""
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class ContinuousBatchingScheduler:
    """Admits requests into a fixed-capacity constrained decode batch.

    ``overlap`` pipelines host mask construction with device execution;
    ``bucket_prefill`` pads full-attention/MLA admissions to power-of-two
    prompt lengths; ``adaptive_prebuild`` skips overlapped prebuilds for
    opportunistic-mode slots whose previous tick did not intervene.  All
    default on; they are observationally pure (token-for-token identical
    output) and exist as flags only so tests and benchmarks can measure
    them.

    Paged KV: ``paged`` defaults to auto — on for architectures whose
    every cache-bearing block is full-attention / MLA, off otherwise
    (ring/recurrent rows keep dense state).  ``page_size`` is the pool
    page length in tokens (the fused kernel's BLOCK_T); ``n_pages`` sizes
    the pool — default is capacity-equivalent
    (capacity * max_len / page_size + trash page), and sizing it SMALLER
    is the point: admission needs only each request's actual pages, so a
    sub-capacity pool still serves a full batch of short requests where
    the contiguous layout would hold ``pool_tokens / max_len`` rows.
    """

    def __init__(self, engine, capacity: int = 4, overlap: bool = True,
                 bucket_prefill: bool = True,
                 paged: Optional[bool] = None, page_size: int = 64,
                 n_pages: Optional[int] = None,
                 adaptive_prebuild: bool = True,
                 queue_limit: Optional[int] = None,
                 queue_timeout_s: Optional[float] = None,
                 default_deadline_s: Optional[float] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 debug_invariants: bool = False,
                 device_loop: bool = False, sync_n: int = 8,
                 journal: Optional[TokenJournal] = None,
                 supervisor: Optional[DegradationSupervisor] = None,
                 prefix_cache: bool = False):
        self.eng = engine
        self.capacity = max(1, capacity)
        self.overlap = overlap
        self.bucket_prefill = bucket_prefill
        self.adaptive_prebuild = adaptive_prebuild
        # fault-tolerance policy: a bounded waiting queue sheds overflow
        # with `rejected` instead of growing without bound; queued
        # requests older than queue_timeout_s shed the same way; a
        # request's own DecodeParams.deadline_s (falling back to
        # default_deadline_s) bounds its total wall time
        self.queue_limit = queue_limit
        self.queue_timeout_s = queue_timeout_s
        self.default_deadline_s = default_deadline_s
        self.injector = fault_injector
        self.debug_invariants = debug_invariants
        self.waiting: "collections.deque[Session]" = collections.deque()
        self.slots: List[Optional[Session]] = [None] * self.capacity
        can_page = kvcache.pageable(engine.model.cfg)
        if paged and not can_page:
            # only the auto default may silently fall back to dense —
            # an explicit request with (possibly undersized) pool sizing
            # must not quietly allocate capacity x max_len stripes
            raise ValueError(
                f"{engine.model.cfg.arch_id}: paged KV requires a pure "
                "full-attention/MLA stack (ring/recurrent rows keep "
                "dense state); use paged=None for auto")
        self.paged = can_page if paged is None else bool(paged)
        if self.paged:
            ps = min(page_size, engine.max_len)
            self.page_size = ps
            self.max_pages = engine.max_len // ps
            self.n_pages = (kvcache.default_n_pages(
                self.capacity, engine.max_len, ps)
                if n_pages is None else int(n_pages))
            self.pool = PagePool(self.n_pages)
            self.cache = engine.model.init_cache(
                self.capacity, engine.max_len, page_size=ps,
                n_pages=self.n_pages)
            # host mirror of the device block table; uploaded (tiny
            # (B, max_pages) int32) whenever the allocator moves pages
            self._page_tbl = np.zeros((self.capacity, self.max_pages),
                                      np.int32)
            self._n_pages_row = np.zeros(self.capacity, np.int32)
            self._pages_dirty = False
            self._scatter_paged = jax.jit(
                functools.partial(_scatter_row_paged, page_size=ps),
                donate_argnums=(0,))
            # per-slot count of block-table entries that map CACHED
            # (shared, read-only) pages — pages [0, n) of the row's
            # table.  The write frontier always sits strictly above the
            # shared region (lookup never matches the final page), so a
            # decode/rollback/refeed write can never touch a shared page.
            self._n_shared_row = np.zeros(self.capacity, np.int32)
        else:
            self.cache = engine.model.init_cache(self.capacity,
                                                 engine.max_len)
        if prefix_cache and not self.paged:
            raise ValueError("prefix_cache=True requires paged KV "
                             "(pages are the sharing granularity)")
        self.prefix_cache = (PrefixCache(self.pool, self.page_size)
                             if prefix_cache else None)
        self.n_prefix_hits = 0         # admissions served >= 1 cached page
        self.n_prefix_tokens = 0       # prefill tokens skipped via cache
        self.n_checker_clones = 0      # adopt() replays served by snapshot
        self._in_reset = False         # engine reset in flight: cached
        #                                pages are garbage, don't insert
        self.cache["len"] = jnp.zeros((self.capacity,), jnp.int32)  # ragged
        vpad = engine.model.padded_vocab
        self._logits = jnp.zeros((self.capacity, vpad), jnp.float32)
        v = engine._v
        # one fused readback per tick: raw argmax + per-row finiteness
        # (the device-fault detector — a NaN/Inf row is quarantined
        # BEFORE any selection consumes it; vocab-padded columns are
        # excluded, their values are unspecified by contract)
        self._raw_stats = jax.jit(lambda lg: (
            jnp.argmax(lg, axis=-1),
            jnp.all(jnp.isfinite(lg[:, :v]), axis=-1)))
        self._raw_argmax = jax.jit(lambda lg: jnp.argmax(lg, axis=-1))
        # persistent packed mask staging buffer: one (capacity, V/32)
        # uint32 row per slot, reused every tick (no per-tick (B, V) int8
        # allocation, 8x fewer host->device mask bytes).  Vacant slots
        # keep the precomputed sentinel row (token 0 legal — harmless,
        # their logits row is garbage by contract anyway).
        w = bitmask.n_words(engine._v)
        self._sentinel_row = np.zeros(w, np.uint32)
        bitmask.set_bit(self._sentinel_row, 0)
        self._allow_all_row = bitmask.pack_bool(
            np.ones(engine._v, bool))          # unconstrained rows
        self._mask_words = np.tile(self._sentinel_row, (self.capacity, 1))
        # packed masks prebuilt from each slot's current checker state
        # while the device executed the previous forward; dropped on any
        # checker advance / slot turnover (state changed -> mask stale)
        self._premask: Dict[int, np.ndarray] = {}
        # opportunistic-mode adaptive prebuild: build a slot's mask only
        # when its previous tick intervened (the O(token) legality check
        # failed and a full mask was consulted); fresh slots start False
        # because the opportunistic fast path usually wins
        self._opp_intervened = np.zeros(self.capacity, bool)
        self.premask_hits = 0          # selections served by a prebuild
        self.premask_skips = 0         # prebuilds adaptively skipped
        self.mask_cache_hits = 0       # mask builds served by the state-
        #                                keyed memo on the shared TreeCache
        self.n_fwd = 0                 # global forward count (all slots)
        self.n_preempt = 0             # paged recompute preemptions
        # device-resident decode loop (ISSUE 8): when enabled AND the
        # engine uploaded device tables (ServingEngine(device_tables=True)
        # + precompute()), ticks whose every resident row is certified
        # (DOMINO k=inf on a cleanly-certified grammar, greedy,
        # non-speculative) run sync_n decode steps in ONE fused device
        # call — mask gather, packed argmax, transition-table state
        # advance, KV append — and sync to the host once per block
        # instead of once per token.  Any host-path row in the batch
        # falls the whole tick back to the per-token path (those rows
        # need a host advance per token anyway), where certified rows
        # still gather their mask from the device table (stage 1).
        # Trade-off knobs documented in README: admission, cancellation,
        # deadline checks and EOS bookkeeping happen at block boundaries,
        # so sync_n bounds how stale they can go (<= sync_n tokens).
        self.device_loop = bool(device_loop)
        self.sync_n = max(1, int(sync_n))
        self._dts = engine.device_table_set if self.device_loop else None
        # per-slot device-table state id; OFF_FRONTIER (<0) = host path.
        # Maintained incrementally: computed from the checker at
        # admission (one abstract_key), advanced by O(1) host transition
        # lookups at every commit, resynced from the device after a
        # fused block, cleared on finish/preempt.
        self._dev_state = np.full(self.capacity, OFF_FRONTIER, np.int64)
        # tokens since the row's table state was last AUDITED against the
        # concrete checker's mask.  The key quotient is an abstraction of
        # a context-free state space, so a table walk can drift off the
        # concrete state (a QUOTIENT ESCAPE); every sync_n tokens — and
        # at every fused-block boundary — the mask row is compared to the
        # concrete mask and an escaped row demotes to the exact host
        # path.  Divergence from the host path is thereby bounded to one
        # audit interval; grammar validity is unconditional (every
        # committed token is validated by a concrete checker advance).
        self._dev_age = np.zeros(self.capacity, np.int64)
        self.n_quotient_escapes = 0    # audit demotions
        self.n_table_rejects = 0       # table-selected token rejected by
        #                                the checker -> recompute-preempt
        # per-tick device-gather plan: slot -> global state id (>=0) for
        # rows whose mask is gathered from the device table this tick
        self._dev_gather = np.full(self.capacity, OFF_FRONTIER, np.int64)
        # decode_nan fault plan for one fused block, consulted host-side
        # up front (persistent: tick funcs must not allocate dense rows)
        self._nan_plan = np.zeros((self.capacity, self.sync_n), bool)
        # device-sampler staging: per-row temperature + per-row
        # counter-based PRNG key (fold_in(PRNGKey(seed), n_draws)),
        # persistent so the tick path never allocates a dense buffer
        self._samp_temps = np.zeros(self.capacity, np.float32)
        self._samp_keys = np.zeros((self.capacity, 2), np.uint32)
        self._fused_fn = None          # built lazily on first device tick
        # mask-table gather: device rows take their table row, host rows
        # keep the staged packed buffer
        self._gather_masks = jax.jit(lambda tab, sid, staged: jnp.where(
            (sid >= 0)[:, None], tab[jnp.maximum(sid, 0).astype(jnp.int32)],
            staged))
        # decode-path host sync points (one blocking readback that gates
        # token commitment): +1 per host-path selection tick, +1 per
        # fused device block.  The benchmark reports syncs per committed
        # token — the quantity this PR drives from ~1 down to ~1/sync_n.
        self.n_host_syncs = 0
        self.n_device_tokens = 0       # tokens committed by fused blocks
        self._next_rid = 0
        # lifecycle bookkeeping: every terminal session in submit order
        # (`run()` reports from here, so submit-time rejections are never
        # lost); _finished_now accumulates between step() drains
        self.finished: List[Session] = []
        self._finished_now: List[Session] = []
        self.status_counts = collections.Counter()
        self._fail_log: List = []      # (rid, error) per quarantined row
        # durability + degradation (ISSUE 9 tentpole).  The journal only
        # BUFFERS during tick phases; all its file I/O happens in
        # _journal_tick at the tick boundary (lint rule R5 enforces
        # this).  _jmark[rid] = tokens already journaled for that rid, so
        # each tick writes a commit DELTA and replay merges idempotently.
        self.journal = journal
        self._jmark: Dict[int, int] = {}
        # supervisor: engine-wide fused -> host -> dense ladder for when
        # the DEVICE is sick (row-level faults stay quarantined per row).
        # A plain default supervisor never trips (no watchdogs, only
        # degrades on real dispatch errors / injected device faults).
        self.sup = supervisor or DegradationSupervisor()
        # effective capacity under HBM pressure: alloc_fail shrinks it
        # (preempting the excess to the queue) and each clean tick grows
        # it back toward the configured capacity
        self._cap_eff = self.capacity
        self.n_engine_resets = 0       # cache/logits re-inits after a
        #                                device error escaped a dispatch
        self.n_capacity_shrinks = 0    # alloc_fail-driven _cap_eff drops
        self.n_deadline_clamps = 0     # fused blocks clamped below
        #                                sync_n by a resident deadline
        self.n_replayed_tokens = 0     # journal-restored (not re-decoded)
        self._last_block_steps = 0     # steps the last fused block ran
        # committed-tokens-per-second EWMA over fused blocks; prices a
        # resident deadline into a block-step cap (0.0 = unprimed)
        self._tok_s_ema = 0.0
        self._shrunk_tick = False      # alloc_fail fired this tick

    # -- public API -------------------------------------------------------------

    def warm(self) -> Dict[str, float]:
        """Run the offline tree precomputation (paper Algorithm 2) over
        every grammar in the engine registry so mask construction never
        lands on the serving critical path, then prefill and PIN any
        engine-default prompts into the prefix cache."""
        stats = self.eng.precompute()
        self._pin_prompts()
        return stats

    def _pin_prompts(self) -> None:
        """Prefill each engine-registered default prompt once and park
        its full pages as PINNED radix nodes (never evicted): every
        future admission sharing the preamble skips its prefill."""
        if self.prefix_cache is None:
            return
        eng = self.eng
        for prompt in getattr(eng, "pinned_prompts", ()):
            ids = eng.tok.encode(prompt)
            n_full = len(ids) // self.page_size
            if n_full == 0:
                continue
            cut = ids[:n_full * self.page_size]
            probe = self.prefix_cache.lookup(cut, max_pages=n_full)
            if probe:
                self.pool.release(probe)     # drop the probe references
                if len(probe) == n_full:
                    continue                 # fully cached (re-warm)
            pages = self._alloc_pages(n_full)
            if pages is None:
                break
            row_cache = eng.model.init_cache(1, eng.max_len)
            _, row_cache = eng._prefill(
                eng.params, {"tokens": jnp.asarray([cut], jnp.int32)},
                row_cache)
            padded = np.zeros(self.max_pages, np.int32)
            padded[:n_full] = pages
            # slot 0 is scratch for the donating scatter; it must be
            # vacant (warm before serving) — restore its len afterwards
            assert self.slots[0] is None, "warm() after admission"
            self.cache = self._scatter_paged(self.cache, row_cache, 0,
                                             jnp.asarray(padded))
            cache = dict(self.cache)
            cache["len"] = cache["len"].at[0].set(0)
            self.cache = cache
            self.prefix_cache.insert(cut, pages, pin=True)
            self.pool.release(pages)   # ownership passes to the nodes

    def submit(self, request: Union[str, Request],
               extra_inputs=None) -> Session:
        """Queue one request.  ``request`` is a
        :class:`~repro.serving.request.Request` (per-row grammar, mode,
        EOS, budget, temperature, seed, speculation, deadline) or a bare
        prompt string, which submits the engine-default request.

        With a bounded queue (``queue_limit``) an overflowing submission
        is shed immediately: the returned session already carries a
        ``rejected`` result instead of growing the queue without bound.
        """
        sess = self.eng.make_session(self._next_rid, request, extra_inputs)
        self._next_rid += 1
        self._journal_submit(sess)
        if self.queue_limit is not None \
                and len(self.waiting) >= self.queue_limit:
            self._finish(sess, status="rejected",
                         error=f"waiting queue full "
                               f"(queue_limit={self.queue_limit})")
            return sess
        self.waiting.append(sess)
        return sess

    def cancel(self, rid: int) -> bool:
        """Request cancellation of a waiting or resident request by rid.
        Takes effect at the NEXT tick boundary: the session terminates
        with status ``cancelled`` and its slot and pages are freed for
        batch-mates.  Returns False when no live request has this rid
        (already finished, or never submitted)."""
        for sess in list(self.waiting) + self.slots:
            if sess is not None and sess.rid == rid \
                    and sess.result is None:
                sess.cancel_requested = True
                return True
        return False

    def run(self) -> List[GenerationResult]:
        """Drive all submitted sessions to a terminal status; results in
        rid order (including submit-time rejections)."""
        while self.waiting or any(s is not None for s in self.slots):
            self.step()
        done = sorted(self.finished, key=lambda s: s.rid)
        return [s.result for s in done]

    def stats(self) -> Dict[str, object]:
        """Operational counters for benchmarks and monitoring: the
        degradation-ladder state plus durability/pressure counters."""
        s = self.sup.stats()
        s.update(
            n_engine_resets=self.n_engine_resets,
            n_capacity_shrinks=self.n_capacity_shrinks,
            n_deadline_clamps=self.n_deadline_clamps,
            n_replayed_tokens=self.n_replayed_tokens,
            cap_eff=self._cap_eff,
            journal_syncs=(0 if self.journal is None
                           else self.journal.n_syncs),
            n_prefix_hits=self.n_prefix_hits,
            n_prefix_tokens=self.n_prefix_tokens,
            n_checker_clones=self.n_checker_clones,
        )
        if self.prefix_cache is not None:
            s.update({"prefix_" + k: v
                      for k, v in self.prefix_cache.stats().items()})
        return s

    def step(self) -> List[Session]:
        """One scheduler tick: reap -> admit -> select -> decode.
        Returns sessions that reached a terminal status since the last
        drain (tick casualties and submit-time rejections alike)."""
        if self.injector is not None:
            self.injector.begin_tick()
        self._reap()
        self._admit()
        if any(s is not None for s in self.slots):
            width = self._verify_width()
            if width > 1:
                self._spec_step(width)
            elif self._device_ready():
                self._device_step()
            else:
                self._plain_step()
        self._reset_vacant_lens()
        self._journal_tick()
        self._supervisor_tick()
        if self.debug_invariants:
            problems = check_invariants(self)
            if problems:
                raise InvariantViolation("; ".join(problems))
        done, self._finished_now = self._finished_now, []
        return done

    # -- lifecycle: deadlines / cancellation ------------------------------------

    def _overdue(self, sess: Session, now: float, waiting: bool):
        """(status, reason) if the session must terminate at this tick
        boundary, else (None, None)."""
        if sess.cancel_requested:
            return "cancelled", ("cancelled while waiting" if waiting
                                 else "cancelled while decoding")
        deadline = sess.deadline_s
        if deadline is None:
            deadline = self.default_deadline_s
        waited = now - sess.t_submit
        if deadline is not None and waited > deadline:
            return "deadline_exceeded", (
                f"deadline {deadline:g}s exceeded after {waited:.3f}s"
                + (" in queue" if waiting else ""))
        if waiting and self.queue_timeout_s is not None \
                and waited > self.queue_timeout_s:
            return "rejected", (f"queue-wait timeout "
                                f"({self.queue_timeout_s:g}s) exceeded")
        return None, None

    def _reap(self) -> None:
        """Tick-boundary lifecycle sweep: honor cancellations, enforce
        per-request deadlines (waiting AND resident), and shed queued
        requests past the queue-wait timeout.  Freed slots and pages are
        available to this very tick's admission."""
        now = time.perf_counter()
        if self.waiting:
            keep: "collections.deque[Session]" = collections.deque()
            while self.waiting:
                sess = self.waiting.popleft()
                status, why = self._overdue(sess, now, waiting=True)
                if status is None:
                    keep.append(sess)
                else:
                    self._finish(sess, status=status, error=why)
            self.waiting = keep
        for sess in list(self.slots):
            if sess is None:
                continue
            status, why = self._overdue(sess, now, waiting=False)
            if status is not None:
                self._finish(sess, status=status, error=why)

    def _verify_width(self) -> int:
        """Speculative verify width for this tick: 1 + the widest
        resident speculative row's ``spec_s`` (per-row policy — a batch
        mixing speculative and plain rows sizes the window to the rows
        that use it; plain rows ride along on pad positions).  1 means no
        resident row speculates and the tick takes the plain path."""
        widths = [1 + s.decode.spec_s for s in self.slots
                  if s is not None and s.speculator is not None]
        return max(widths) if widths else 1

    # -- admission / eviction ---------------------------------------------------

    def _admission_reject_reason(self, n_tokens: int) -> Optional[str]:
        """Reason string when a request's cache demand can NEVER be met
        (not even by an otherwise-empty engine), else None.  These must
        be rejected up front: the FIFO queue blocks behind its head, so
        an unsatisfiable head request would livelock every request
        behind it forever (the old behavior)."""
        if n_tokens + 1 > self.eng.max_len:
            return (f"prompt needs {n_tokens + 1} cache positions > "
                    f"engine max_len {self.eng.max_len}")
        if self.paged:
            n_pg = _ceil_div(n_tokens + 1, self.page_size)
            if n_pg > self.max_pages:
                return (f"prompt needs {n_pg} pages > per-row max_pages "
                        f"{self.max_pages}")
            if n_pg > self.n_pages - 1:
                return (f"prompt needs {n_pg} pages > total pool "
                        f"capacity {self.n_pages - 1}")
        return None

    def _admit(self) -> None:
        eng = self.eng
        while self.waiting and None in self.slots:
            if sum(s is not None for s in self.slots) >= self._cap_eff:
                break      # capacity shrunk under allocation pressure
            slot = self.slots.index(None)
            sess = self.waiting[0]
            # re-admission after preemption re-prefills the generated
            # prefix too (the checker already advanced past it)
            ids = list(sess.prompt_ids) + list(sess.out_ids)
            reason = self._admission_reject_reason(len(ids))
            if reason is not None:
                # unsatisfiable-by-construction: reject NOW (frees the
                # queue head for admissible requests behind it) instead
                # of waiting for pages that can never suffice
                self.waiting.popleft()
                self._finish(sess, status="rejected", error=reason)
                continue
            page_ids = None
            cached: List[int] = []
            if self.paged:
                # +1: the first decode write must fit without a new
                # allocation, or a lone just-admitted row could preempt
                # itself forever without committing a token
                n_pg = _ceil_div(len(ids) + 1, self.page_size)
                if self._inject("page_exhaustion", sess):
                    break      # injected dry pool: backpressure path
                if self.prefix_cache is not None and not sess.extra_inputs:
                    # longest shared whole-page prefix, capped one token
                    # short of the sequence so the boundary page is
                    # always private (COW write barrier by construction)
                    cached = self.prefix_cache.lookup(
                        ids, max_pages=(len(ids) - 1) // self.page_size)
                page_ids = self._alloc_pages(n_pg - len(cached))
                if page_ids is None:
                    if cached:
                        self.pool.release(cached)
                    break          # backpressure: wait for frees (FIFO)
                page_ids = cached + page_ids
            self.waiting.popleft()
            self._premask.pop(slot, None)
            self._opp_intervened[slot] = False
            t0 = time.perf_counter()
            try:
                if cached:
                    logits_row = self._cached_prefill(sess, slot, ids,
                                                      page_ids,
                                                      len(cached))
                else:
                    row_cache = eng.model.init_cache(1, eng.max_len)
                    inputs = {"tokens": jnp.asarray([ids], jnp.int32)}
                    if self.bucket_prefill and not eng._needs_refeed \
                            and not sess.extra_inputs:
                        # power-of-two bucket: pads ride beyond the valid
                        # frontier (masked by pos < len, overwritten by
                        # later decodes), the head reads the true last
                        # token.  Gated off refeed archs: ring/recurrent
                        # state would absorb the pads.
                        p = _bucket_len(len(ids), eng.max_len)
                        inputs["tokens"] = jnp.asarray(
                            [ids + [eng.tok.pad_id] * (p - len(ids))],
                            jnp.int32)
                        inputs["length"] = jnp.asarray(len(ids), jnp.int32)
                    if sess.extra_inputs:
                        inputs.update(sess.extra_inputs)
                    logits, row_cache = eng._prefill(eng.params, inputs,
                                                     row_cache)
                    logits_row = logits[0, -1]
                    if self.paged:
                        padded = np.zeros(self.max_pages, np.int32)
                        padded[:len(page_ids)] = page_ids
                        self.cache = self._scatter_paged(
                            self.cache, row_cache, slot,
                            jnp.asarray(padded))
                        self._page_tbl[slot, :] = 0
                        self._page_tbl[slot, :len(page_ids)] = page_ids
                        self._n_pages_row[slot] = len(page_ids)
                        self._n_shared_row[slot] = 0
                        self._pages_dirty = True
                    else:
                        self.cache = _scatter_row_donate(self.cache,
                                                         row_cache, slot)
            except Exception as e:   # quarantined: reject THIS request
                if self.paged and page_ids:
                    self.pool.free(page_ids)
                self._fail(sess, f"prefill failed: {e!r}")
                continue
            if cached:
                self.n_prefix_hits += 1
                skipped = len(cached) * self.page_size
                self.n_prefix_tokens += skipped
                sess.n_cached_tokens += skipped
            if self.paged and self.prefix_cache is not None \
                    and not sess.extra_inputs:
                # donate the row's full pages right away: requests later
                # in this same admission sweep (and every future one)
                # can share the prefix just prefilled
                n_full = min(len(ids) // self.page_size, len(page_ids))
                self.prefix_cache.insert(
                    ids[:n_full * self.page_size], page_ids[:n_full])
            self._logits = self._logits.at[slot].set(
                logits_row.astype(jnp.float32))
            sess.model_time += time.perf_counter() - t0
            sess.n_fwd += 1
            self.n_fwd += 1
            sess.slot = slot
            sess.t_admit = time.perf_counter()
            self.slots[slot] = sess
            # device-table tracking starts (or resumes, after preemption:
            # the checker already advanced past the generated prefix) at
            # the checker's CURRENT abstract state
            self._dev_state[slot] = self._sid_for(sess)
            self._dev_age[slot] = 0
            if self.journal is not None:
                # cache adoption is recorded for observability/auditing;
                # replay does not need it (restored admissions re-acquire
                # through the cache or fall back to a full re-prefill,
                # identical either way by prefix determinism)
                self.journal.append({"kind": "admit", "rid": sess.rid,
                                     "slot": slot,
                                     "cached_pages": len(cached),
                                     "cached_checker":
                                         sess.cached_checker})
            if self._inject("prefill_nan", sess):
                self._logits = self._logits.at[slot].set(jnp.nan)

    def _cached_prefill(self, sess: Session, slot: int, ids: List[int],
                        page_ids: List[int], n_cached: int):
        """Admission through a prefix-cache hit: the first ``n_cached``
        pages of the row's block table map shared pages whose K/V is
        already resident (bitwise-identical by prefix determinism), so
        only the tail ``ids[n_cached * page_size:]`` is prefilled — as a
        multi-token DECODE over a B=1 view of the pool leaves, which
        reads the shared prefix through the block table and writes only
        private pages (every write position sits at or beyond the
        boundary page).  Returns the last real token's logits row.

        NOT a tick function: runs only from ``_admit`` (lint rule R6
        keeps cache traffic off the per-token path).
        """
        eng = self.eng
        ps = self.page_size
        start = n_cached * ps
        tail = list(ids[start:])
        assert tail, "cache hit must leave a non-empty private tail"
        # bucket the tail so the B=1 decode compiles per size class, not
        # per length; pads write garbage above the final frontier (pos >=
        # len is invalid by contract) into private/trash pages only
        p = min(_bucket_len(len(tail), eng.max_len), eng.max_len - start)
        feed = jnp.asarray(
            [tail + [eng.tok.pad_id] * (p - len(tail))], jnp.int32)
        padded = np.zeros(self.max_pages, np.int32)
        padded[:len(page_ids)] = page_ids
        view = {
            "len": jnp.asarray([start], jnp.int32),
            "head": self.cache["head"],
            "tail": self.cache["tail"],
            "group": self.cache["group"],
            "pages": jnp.asarray(padded)[None, :],
        }
        lg, view = eng._decode(eng.params, view, feed)
        # merge the written pool leaves back; other rows' pages are
        # untouched (the scatter only wrote this row's private pages)
        cache = dict(self.cache)
        cache["head"], cache["tail"] = view["head"], view["tail"]
        cache["group"] = view["group"]
        cache["len"] = cache["len"].at[slot].set(len(ids))
        self.cache = cache
        self._page_tbl[slot, :] = 0
        self._page_tbl[slot, :len(page_ids)] = page_ids
        self._n_pages_row[slot] = len(page_ids)
        self._n_shared_row[slot] = n_cached
        self._pages_dirty = True
        return lg[0, len(tail) - 1]

    def _reset_vacant_lens(self) -> None:
        """Vacant slots' rows are garbage by contract, but every batched
        forward still advances their ragged ``len`` — left alone, the
        fused kernel would stream ever more dead cache tiles for freed
        rows.  Pin them to 0 so the per-row early-exit actually skips
        them (admission overwrites ``len`` when it scatters a new row)."""
        if all(s is not None for s in self.slots):
            return
        occ = jnp.asarray([0 if s is None else 1 for s in self.slots],
                          jnp.int32)
        cache = dict(self.cache)
        cache["len"] = cache["len"] * occ
        self.cache = cache

    def _finish(self, sess: Session, status: Optional[str] = None,
                error: Optional[str] = None) -> None:
        """Terminate one session: resolve its terminal status, free its
        slot and pages, and record it for ``step()``/``run()`` reporting.
        ``status=None`` resolves to ok/dead_end from the session flags."""
        if status is not None:
            sess.status = status
        if error is not None and sess.error is None:
            sess.error = error
        sess.finish(self.eng.tok.decode)
        if self.journal is not None:
            self._journal_commit(sess)
            self.journal.append({
                "kind": "terminal", "rid": sess.rid,
                "status": sess.result.status, "error": sess.result.error,
                "finished": sess.finished_eos,
                "dead_end": sess.dead_end})
            self._jmark.pop(sess.rid, None)
        if sess.slot >= 0:
            self._premask.pop(sess.slot, None)
            self._dev_state[sess.slot] = OFF_FRONTIER
            if self.paged:
                self._insert_prefix(sess)
                self._free_slot_pages(sess.slot)
            self.slots[sess.slot] = None
            sess.slot = -1
        self.status_counts[sess.result.status] += 1
        self.finished.append(sess)
        self._finished_now.append(sess)

    def _fail(self, sess: Session, error: str) -> None:
        """Quarantine a failure to this row: the session terminates with
        ``internal_error`` (never a silent swallow, never a crash that
        takes down batch-mates) and its slot/pages free immediately."""
        self._fail_log.append((sess.rid, error))
        self._finish(sess, status="internal_error", error=error)

    # -- fault injection sites --------------------------------------------------

    def _inject(self, site: str, sess: Optional[Session] = None) -> bool:
        """Consult the fault plan at one injection site (no-op without
        an injector)."""
        if self.injector is None:
            return False
        return self.injector.fire(site,
                                  rid=None if sess is None else sess.rid)

    def _inject_nan_rows(self, site: str) -> None:
        """Corrupt staged logits rows per the fault plan.  Detection is
        NOT short-circuited: the poisoned row flows into the next
        selection's finiteness check exactly like a real device fault."""
        if self.injector is None:
            return
        for slot, sess in enumerate(self.slots):
            if sess is not None and self._inject(site, sess):
                self._logits = self._logits.at[slot].set(jnp.nan)

    # -- durability: write-ahead journal (ISSUE 9 tentpole) ---------------------
    #
    # Tick phases only BUFFER records (journal.append is pure host
    # bookkeeping); the one place file I/O happens is _journal_tick at
    # the tick boundary, which lint rule R5 keeps off the tick functions.

    def _journal_submit(self, sess: Session) -> None:
        """Buffer the submit record: everything replay needs to rebuild
        the request (prompt + ConstraintSpec + DecodeParams fields).  A
        request that cannot be serialized (ad-hoc grammar object,
        extra_inputs pytrees) is journaled as non-recoverable so restore
        reports it explicitly instead of resurrecting it wrong."""
        if self.journal is None:
            return
        rec = {"kind": "submit", "rid": sess.rid, "prompt": sess.prompt}
        recoverable, reason = True, None
        spec = getattr(sess.request, "constraint", None)
        if spec is not None and spec.grammar is not None \
                and not isinstance(spec.grammar, str):
            recoverable = False
            reason = ("ad-hoc grammar object is not serializable; "
                      "register it by name to make the request "
                      "recoverable")
            rec["constraint"] = None
        else:
            rec["constraint"] = (None if spec is None
                                 else dataclasses.asdict(spec))
        dec = getattr(sess.request, "decode", None)
        rec["decode"] = None if dec is None else dataclasses.asdict(dec)
        if sess.extra_inputs:
            recoverable = False
            reason = "extra_inputs are not journaled"
        rec["recoverable"] = recoverable
        rec["reason"] = reason
        self.journal.append(rec)

    def _journal_commit(self, sess: Session) -> None:
        """Buffer a commit DELTA: the session's checker-validated tokens
        beyond what was already journaled, tagged with their offset so
        replay merges idempotently (a re-written delta contributes
        nothing).  The sampling-RNG state rides in the same record as
        the draws that advanced it, so a restored sampled row resumes
        its exact stream."""
        done = len(sess.out_ids)
        mark = self._jmark.get(sess.rid, 0)
        if done <= mark:
            return
        rec = {"kind": "commit", "rid": sess.rid, "off": mark,
               "toks": [int(t) for t in sess.out_ids[mark:]],
               "n_draws": sess.n_draws}
        if sess._rng is not None:
            rec["rng"] = sess._rng.bit_generator.state
        self.journal.append(rec)
        self._jmark[sess.rid] = done

    def _journal_tick(self) -> None:
        """The tick-boundary durability point: buffer commit deltas for
        every live session that gained tokens this tick (resident AND
        freshly-preempted), then let the journal do its batched
        write + fsync.  The ONLY tick-path call allowed to flush."""
        if self.journal is None:
            return
        for sess in list(self.slots) + list(self.waiting):
            if sess is not None and sess.result is None:
                self._journal_commit(sess)
        self.journal.commit_tick()

    # -- degradation supervisor (ISSUE 9 tentpole) ------------------------------

    def _supervisor_tick(self) -> None:
        """Close the tick for the degradation ladder: an alloc_fail-free
        tick regrows effective capacity one slot, and the supervisor
        counts clean ticks toward climbing fused <- host <- dense."""
        if self._shrunk_tick:
            self._shrunk_tick = False
        elif self._cap_eff < self.capacity:
            self._cap_eff += 1
        self.sup.tick_ok()

    def _engine_reset(self, reason: str) -> None:
        """The device surface is untrustworthy after an error escaped a
        dispatch (the fused call donates the cache, so its buffers may be
        gone): recompute-preempt every resident — validated prefixes ride
        along, so outputs are unchanged — and re-initialize the batch
        cache and the staged logits.  Youngest preempts first, so the
        oldest resident lands at the queue front for re-admission."""
        self.n_engine_resets += 1
        self._fail_log.append((None, f"engine reset: {reason}"))
        if self.prefix_cache is not None:
            # cached pages' contents die with the device cache: drop the
            # node references FIRST so the preempts below release the
            # last table references and the pages actually return
            self.prefix_cache.reset()
        self._in_reset = True
        try:
            for sess in sorted((s for s in self.slots if s is not None),
                               key=lambda s: s.t_admit, reverse=True):
                self._preempt(sess)
        finally:
            self._in_reset = False
        eng = self.eng
        if self.paged:
            self.cache = eng.model.init_cache(
                self.capacity, eng.max_len, page_size=self.page_size,
                n_pages=self.n_pages)
            self._pages_dirty = True
        else:
            self.cache = eng.model.init_cache(self.capacity, eng.max_len)
        self.cache["len"] = jnp.zeros((self.capacity,), jnp.int32)
        self._logits = jnp.zeros(
            (self.capacity, eng.model.padded_vocab), jnp.float32)
        self._premask.clear()

    # -- restart recovery -------------------------------------------------------

    def adopt(self, entry: JournalEntry) -> Session:
        """Reconstruct one journal-replayed request (restart recovery).

        Terminal entries become finished shell sessions (their result is
        rebuilt from the journaled tokens/status, nothing re-decodes).
        Live entries rebuild the Request from the journaled spec fields,
        replay the validated committed prefix through a fresh concrete
        checker via ``advance()`` (a rejection is quarantined — the
        journal only ever holds validated tokens, so this means the
        grammar registry changed under us), restore the sampling RNG
        stream, and re-enter the waiting queue: admission re-prefills
        prompt + prefix exactly like a recompute preemption, which is
        what makes the resumed output bitwise-identical."""
        self._next_rid = max(self._next_rid, entry.rid + 1)
        if not entry.recoverable:
            sess = self.eng.make_session(entry.rid, entry.prompt)
            self._finish(sess, status="internal_error",
                         error=f"unrecoverable from journal: "
                               f"{entry.reason}")
            return sess
        req: Union[str, Request] = entry.prompt
        if entry.constraint is not None or entry.decode is not None:
            req = Request(
                prompt=entry.prompt,
                constraint=(ConstraintSpec(**entry.constraint)
                            if entry.constraint is not None
                            else ConstraintSpec(grammar=None,
                                                mode="unconstrained")),
                decode=(DecodeParams(**entry.decode)
                        if entry.decode is not None else DecodeParams()))
        sess = self.eng.make_session(entry.rid, req)
        if entry.terminal is not None:
            sess.out_ids = [int(t) for t in entry.toks]
            sess.n_replayed = len(entry.toks)
            sess.n_draws = entry.n_draws
            sess.finished_eos = entry.terminal["finished"]
            sess.dead_end = entry.terminal["dead_end"]
            st = entry.terminal["status"]
            self._jmark[entry.rid] = len(entry.toks)
            self._finish(sess,
                         status=(None if st in ("ok", "dead_end")
                                 else st),
                         error=entry.terminal["error"])
            return sess
        toks = [int(t) for t in entry.toks]
        n_adopted = 0
        sig = self._checker_sig(sess)
        if self.prefix_cache is not None and sig is not None and toks:
            # fork-point fast path: clone the longest stored checker
            # snapshot covering a prefix of the journaled tokens and
            # replay only the remainder through advance().  Exact-prefix
            # keying (grammar sig + prompt length + token ids) makes the
            # clone's state identical to what the replay would build.
            got = self.prefix_cache.get_checker(
                sig, len(sess.prompt_ids),
                list(sess.prompt_ids) + toks)
            if got is not None:
                n_cov, clone = got
                n_adopted = n_cov - len(sess.prompt_ids)
                sess.checker = clone
                sess.out_ids.extend(toks[:n_adopted])
                sess.budget -= n_adopted
                sess.cached_checker = True
                self.n_checker_clones += 1
        for tok in toks[n_adopted:]:
            try:
                ok = (sess.checker.advance(int(tok))
                      if sess.checker is not None else True)
            except Exception as e:
                self._fail(sess, f"journal replay: checker failed at "
                                 f"position {len(sess.out_ids)}: {e!r}")
                return sess
            if not ok:
                self._fail(sess, f"journal replay: checker rejected "
                                 f"validated token {int(tok)} at position "
                                 f"{len(sess.out_ids)} (grammar changed?)")
                return sess
            sess.out_ids.append(int(tok))
            sess.budget -= 1
        sess.n_replayed = len(entry.toks)
        self.n_replayed_tokens += len(entry.toks)
        if self.prefix_cache is not None and sig is not None \
                and sess.out_ids:
            # snapshot the fully-replayed state so later adopts in this
            # same restore (and their preemption re-admissions) clone it
            self.prefix_cache.put_checker(
                sig, len(sess.prompt_ids),
                list(sess.prompt_ids) + list(sess.out_ids), sess.checker)
        sess.n_draws = entry.n_draws
        if entry.rng_state is not None and sess.decode is not None:
            rng = sess.decode.make_rng()
            rng.bit_generator.state = entry.rng_state
            sess._rng = rng
        sess.n_preempt = entry.n_preempts
        self._jmark[entry.rid] = len(sess.out_ids)
        if sess.budget <= 0:
            self._finish(sess)
            return sess
        self.waiting.append(sess)
        return sess

    # -- page bookkeeping -------------------------------------------------------

    def _free_slot_pages(self, slot: int) -> None:
        n = int(self._n_pages_row[slot])
        if n:
            self.pool.free(self._page_tbl[slot, :n].tolist())
        self._page_tbl[slot, :] = 0         # vacant entries -> trash page
        self._n_pages_row[slot] = 0
        if self.prefix_cache is not None:
            self._n_shared_row[slot] = 0
        self._pages_dirty = True

    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        """``pool.alloc`` with prefix-cache LRU eviction as the
        fallback: cache-only pages (refcount 1, unpinned) are reclaimed
        to cover the shortfall before admission backpressures or a
        resident row is preempted.  A page a live block table maps is
        never a candidate (its refcount is >= 2)."""
        got = self.pool.alloc(n)
        if got is None and self.prefix_cache is not None:
            self.prefix_cache.evict(n - self.pool.available)
            got = self.pool.alloc(n)
        return got

    def _checker_sig(self, sess: Session) -> Optional[tuple]:
        """Hashable signature of everything that shapes a session's
        checker state besides the advanced tokens, or None when the
        checker is not shareable (non-DOMINO modes, healed subclasses,
        ad-hoc grammar objects with no stable name)."""
        if sess.checker is None or type(sess.checker) is not DominoDecoder:
            return None
        req = sess.request
        spec = None if req is None else req.constraint
        if spec is None or not isinstance(spec.grammar, str):
            return None
        return (spec.grammar, spec.mode, spec.k, sess.eos_id)

    def _insert_prefix(self, sess: Session) -> None:
        """Donate a departing row's committed full pages to the radix
        tree and snapshot its checker at the fork point, so a future
        request sharing the prefix skips both the prefill and (on
        restart recovery) the ``advance()`` replay.  Teardown-boundary
        only (``_finish``/``_preempt``) — never from a tick function
        (lint R6), and never during an engine reset (the pool leaves'
        contents are untrustworthy)."""
        if self.prefix_cache is None or sess.slot < 0 or self._in_reset \
                or sess.extra_inputs:
            return
        if sess.status == "internal_error":
            return      # quarantined row: its device state is suspect
        slot = sess.slot
        ids = list(sess.prompt_ids) + list(sess.out_ids)
        n_full = min(len(ids) // self.page_size,
                     int(self._n_pages_row[slot]))
        if n_full > 0:
            self.prefix_cache.insert(
                ids[:n_full * self.page_size],
                self._page_tbl[slot, :n_full].tolist())
        sig = self._checker_sig(sess)
        if sig is not None and sess.out_ids:
            self.prefix_cache.put_checker(sig, len(sess.prompt_ids),
                                          ids, sess.checker)

    def _preempt(self, sess: Session) -> None:
        """Recompute preemption (pool exhausted mid-flight): reclaim the
        row's pages and return the request to the FRONT of the waiting
        queue.  On re-admission the prompt plus everything generated so
        far is re-prefilled; the checker state already reflects the
        generated prefix, so selection resumes exactly where it left off
        and outputs are unchanged."""
        slot = sess.slot
        self._premask.pop(slot, None)
        self._dev_state[slot] = OFF_FRONTIER
        # donate the committed prefix before releasing the table refs:
        # re-admission re-acquires these very pages through the cache,
        # so a recompute preemption re-prefills only the partial tail
        self._insert_prefix(sess)
        self._free_slot_pages(slot)
        self.slots[slot] = None
        sess.slot = -1
        sess.n_preempt += 1
        self.n_preempt += 1
        if self.journal is not None:
            self.journal.append({"kind": "preempt", "rid": sess.rid})
        self.waiting.appendleft(sess)

    def _ensure_pages(self, width: int) -> None:
        """Grow every resident row's block table to cover the ``width``
        cache positions this tick's decode will write.  If the pool can't
        cover everyone, preempt youngest-first until it can — the
        survivors keep decoding, the victims re-enter the queue."""
        if not self.paged:
            return
        lens = np.asarray(self.cache["len"])
        while True:
            need: Dict[int, int] = {}
            for slot, sess in enumerate(self.slots):
                if sess is None:
                    continue
                want = min(_ceil_div(int(lens[slot]) + width,
                                     self.page_size), self.max_pages)
                if want > int(self._n_pages_row[slot]):
                    need[slot] = want
            shortfall = sum(w - int(self._n_pages_row[s])
                            for s, w in need.items())
            if shortfall and self._inject("alloc_fail"):
                # simulated HBM allocation failure: this is pressure, not
                # a row fault — shrink effective capacity (admission
                # stops refilling the slot about to be reclaimed; clean
                # ticks grow it back) and preempt-to-queue below
                self._cap_eff = max(
                    1, sum(s is not None for s in self.slots) - 1)
                self.n_capacity_shrinks += 1
                self._shrunk_tick = True
            elif shortfall <= self.pool.available + (
                    0 if self.prefix_cache is None
                    else self.prefix_cache.evictable()) and not (
                    shortfall and self._inject("page_exhaustion")):
                # cache-only pages count as available: _alloc_pages
                # below reclaims them LRU-first before any preemption
                break
            victims = [s for s in self.slots if s is not None]
            if not victims:
                break
            self._preempt(max(victims, key=lambda s: s.t_admit))
        for slot, want in need.items():
            have = int(self._n_pages_row[slot])
            got = self._alloc_pages(want - have)
            self._page_tbl[slot, have:want] = got
            self._n_pages_row[slot] = want
            self._pages_dirty = True

    def _shrink_pages(self) -> None:
        """Speculative rollback rewound per-row frontiers; pages wholly
        beyond a row's new length hold only rejected-garbage and go back
        to the pool (the next ``_ensure_pages`` re-allocates on demand)."""
        if not self.paged:
            return
        lens = np.asarray(self.cache["len"])
        for slot, sess in enumerate(self.slots):
            if sess is None:
                continue
            keep = _ceil_div(int(lens[slot]), self.page_size)
            n = int(self._n_pages_row[slot])
            if n > keep:
                self.pool.free(self._page_tbl[slot, keep:n].tolist())
                self._page_tbl[slot, keep:n] = 0
                self._n_pages_row[slot] = keep
                self._pages_dirty = True

    def _sync_pages(self) -> None:
        """Upload the host block table to the device cache if the
        allocator moved pages since the last forward."""
        if self.paged and self._pages_dirty:
            cache = dict(self.cache)
            cache["pages"] = jnp.asarray(self._page_tbl)
            self.cache = cache
            self._pages_dirty = False

    # -- mask pipeline ----------------------------------------------------------

    def _checker_bits(self, sess: Session):
        """Build ``sess``'s packed mask row, attributing build time to the
        session and memo hits to ``mask_cache_hits``.  Checkers without a
        ``mask_bits`` API (e.g. test stubs) fall back to packing their
        bool mask."""
        ch = sess.checker
        if self._inject("mask_delay", sess):
            time.sleep(self.injector.delay_s)
        if self._inject("mask_error", sess):
            raise InjectedFault(
                f"injected mask-build failure (rid={sess.rid})")
        before = getattr(ch, "n_mask_memo_hits", 0)
        t0 = time.perf_counter()
        if hasattr(ch, "mask_bits"):
            m = ch.mask_bits()
        else:
            m = bitmask.pack_bool(np.asarray(ch.mask()))
        dt = time.perf_counter() - t0
        sess.mask_time += dt
        self.mask_cache_hits += getattr(ch, "n_mask_memo_hits", 0) - before
        return m, dt

    def _prebuild_masks(self):
        """Build the next selection's grammar masks from current checker
        state.  Called while the device executes the just-dispatched
        forward; build time accrues to per-session mask_time immediately,
        but the overlap credit is decided by the caller (``_run_decode``)
        once it knows whether the device actually outlasted the build.
        Returns [(session, build_seconds), ...] for that decision.

        Under opportunistic checking (a per-ROW mode now) the raw-argmax
        legality check usually makes the mask dead weight, so the
        prebuild is skipped for opportunistic slots whose previous tick
        did NOT intervene — accounting stays honest automatically: a
        skipped build adds no mask_time and can earn no overlap credit."""
        built = []
        for slot, sess in enumerate(self.slots):
            if sess is None or sess.checker is None \
                    or slot in self._premask:
                continue
            if self.device_loop and self._dev_state[slot] >= 0:
                continue   # mask comes from the uploaded device table
            if self.adaptive_prebuild and sess.opportunistic \
                    and sess.temperature <= 0.0 \
                    and not self._opp_intervened[slot]:
                self.premask_skips += 1
                continue
            try:
                m, dt = self._checker_bits(sess)
            except Exception as e:   # quarantined: evict THIS row only
                self._fail(sess, "checker failed during overlapped "
                                 f"prebuild: {e!r}")
                continue
            self._premask[slot] = m
            built.append((sess, dt))
        return built

    # -- token selection --------------------------------------------------------

    def _choose(self) -> Dict[int, int]:
        """Pick one token per occupied slot under that ROW's decode
        policy: greedy rows go through the device-side fused masked
        argmax over the shared packed staging buffer; sampled rows draw
        host-side from their own per-request RNG.  Finishes dead-ended
        sessions; updates intervention stats.  Returns {slot: token}."""
        eng = self.eng
        v = eng._v

        # one fused readback: per-row raw argmax + per-row finiteness over
        # the real vocab columns (padded columns are legitimately -inf).
        # Guarded: a runtime error HERE is the device being sick, not a
        # row fault — bounded retry, then engine reset + ladder step.
        def _readback():
            raw_dev, fin_dev = self._raw_stats(self._logits)
            return np.asarray(raw_dev), np.asarray(fin_dev)

        ok, got = self.sup.guard(
            "tick_readback", _readback,
            inject=lambda: self._inject("device_error"))
        if not ok:
            # every resident recompute-preempts with its validated prefix
            # intact, so outputs are unchanged; selection commits nothing
            # this tick and the next tick runs one ladder level down
            self._engine_reset(f"tick readback failed: {got!r}")
            self.sup.degrade("tick_readback", got)
            return {}
        raw, finite = got
        self.n_host_syncs += 1         # per-token selection sync point
        masks = self._mask_words              # persistent staging buffer
        self._dev_gather[:] = OFF_FRONTIER
        row_bits: Dict[int, Optional[np.ndarray]] = {}
        for slot, sess in enumerate(self.slots):
            if sess is None:
                masks[slot] = self._sentinel_row
                continue
            if not finite[slot]:
                # device fault quarantined to THIS row: selection on NaN
                # logits would commit garbage, so evict it with an
                # explicit status while batch-mates keep decoding
                self._fail(sess, "non-finite logits from device step")
                masks[slot] = self._sentinel_row
                continue
            if self.device_loop and self._dev_state[slot] >= 0:
                # certified row (stage-1 device gather): its mask IS the
                # device table row — gathered device-side for selection,
                # host mirror staged for sampled rows.  No checker walk,
                # no opportunistic probe, no dead-end check (a clean
                # certificate has no trap states), ~zero mask_time.
                sid = int(self._dev_state[slot])
                self._dev_gather[slot] = sid
                row_bits[slot] = self._dts.mask_host[sid]
                continue
            ch = sess.checker
            if ch is None:
                # unconstrained row: the sentinel all-ones row shares the
                # one (capacity, V/32) buffer with the grammar rows
                masks[slot] = self._allow_all_row
                row_bits[slot] = None
                continue
            try:
                if sess.opportunistic and sess.temperature <= 0.0:
                    t0 = time.perf_counter()
                    ok = ch.check_token(int(raw[slot]))
                    sess.mask_time += time.perf_counter() - t0
                    if ok:
                        self._opp_intervened[slot] = False
                        masks[slot, :] = 0
                        bitmask.set_bit(masks[slot], int(raw[slot]))
                        row_bits[slot] = None
                        continue
                    # fast path lost: a full mask is needed this tick, so
                    # next tick's prebuild is worth building again
                    self._opp_intervened[slot] = True
                m = self._premask.pop(slot, None)   # overlapped prebuild
                if m is None:
                    m, _dt = self._checker_bits(sess)
                else:
                    self.premask_hits += 1
            except Exception as e:   # quarantined: evict THIS row only
                self._fail(sess, f"checker failed during mask build: "
                                 f"{e!r}")
                masks[slot] = self._sentinel_row
                continue
            if not m.any():
                sess.dead_end = True
                self._finish(sess)
                masks[slot] = self._sentinel_row
                continue
            masks[slot] = m
            row_bits[slot] = m
        occupied = [i for i, s in enumerate(self.slots) if s is not None]
        if not occupied:
            return {}
        toks = np.zeros(self.capacity, np.int64)
        # certified rows' mask rows are gathered ON DEVICE from the
        # uploaded table (the staged host buffer keeps everyone else);
        # without device tables this is exactly the staged buffer
        m_stage = jnp.asarray(masks)
        if self.device_loop and bool((self._dev_gather >= 0).any()):
            m_stage = self._gather_masks(
                self._dts.mask_dev, jnp.asarray(self._dev_gather), m_stage)
        greedy = [s for s in occupied if self.slots[s].temperature <= 0.0]
        if greedy:
            # ladder level >= 2 (dense): the jnp reference oracle — same
            # greedy selection bitwise (lowest-index ties), no pallas
            # dispatch at all, for when the kernel path itself is suspect
            idx, _ = masked_argmax(self._logits[:, :v], m_stage,
                                   use_ref=self.sup.level >= 2)
            toks[greedy] = np.asarray(idx)[greedy]
        sampled = [s for s in occupied if s not in greedy]
        if sampled and self.device_loop and self.sup.level < 2:
            # device sampler (Gumbel-max over the packed legal set):
            # per-row temperature, per-row counter-based keys — the
            # stream is a pure function of (seed, draw index), so output
            # never depends on batch composition.  NOT bit-identical to
            # the host np.random path below; distributionally identical.
            self._samp_temps[:] = 0.0
            for slot in sampled:
                sess = self.slots[slot]
                self._samp_temps[slot] = sess.temperature
                self._samp_keys[slot] = np.asarray(
                    jax.random.fold_in(jax.random.PRNGKey(sess.decode.seed),
                                       sess.n_draws))
                sess.n_draws += 1
            sel = np.asarray(masked_sample_packed(
                self._logits[:, :v], m_stage,
                jnp.asarray(self._samp_temps), jnp.asarray(self._samp_keys)))
            for slot in sampled:
                toks[slot] = sel[slot]
        elif sampled:
            lg_host = np.asarray(self._logits)[:, :v]
            for slot in sampled:
                sess = self.slots[slot]
                m = row_bits.get(slot)
                # sampling needs a bool mask for probability renormali-
                # zation; this unpack is per SAMPLED row only (greedy
                # rows stay packed through the fused kernel above)
                toks[slot] = select_token(
                    lg_host[slot],
                    None if m is None else bitmask.unpack(m, v),  # hotpath-lint: allow
                    sess.temperature, sess.rng)
        out: Dict[int, int] = {}
        for slot in occupied:
            sess = self.slots[slot]
            tok = int(toks[slot])
            sess.n_int += int(tok != int(raw[slot]))
            out[slot] = tok
        return out

    # -- plain decode tick ------------------------------------------------------

    def _commit_first(self, chosen: Dict[int, int]) -> Dict[int, int]:
        """Advance checkers / budgets for the chosen tokens; finish rows
        that hit their OWN EOS id or exhaust their own budget.  Returns
        {slot: token} for rows that still need a forward."""
        live: Dict[int, int] = {}
        for slot, tok in chosen.items():
            sess = self.slots[slot]
            if sess is None or sess.slot != slot:
                continue     # evicted between selection and commit
            ch = sess.checker
            # tracked rows select from the TABLE's mask row; a quotient
            # escape can therefore offer a token the concrete checker
            # refuses.  advance() leaves state unchanged on False, so the
            # validated prefix is intact: recompute-preempt the row — it
            # re-enters through _sid_for's exact entry audit and resumes
            # on the host path if still escaped.  Untracked rows selected
            # from the checker's own mask; their advance return keeps the
            # pre-device-loop (ignore) semantics.
            tracked = self._dev_state[slot] >= 0
            try:
                if tok == sess.eos_id:
                    if ch is not None:
                        ok = ch.advance(tok)
                        if tracked and not ok:
                            self.n_table_rejects += 1
                            self._preempt(sess)
                            continue
                    sess.finished_eos = True
                    self._finish(sess)
                    continue
                if ch is not None and sess.speculator is not None \
                        and hasattr(ch, "clone"):
                    sess.speculator.observe(ch.state_key(), tok)
                if ch is not None:
                    if self._inject("advance_error", sess):
                        raise InjectedFault(
                            f"injected advance failure (rid={sess.rid})")
                    ok = ch.advance(tok)
                    self._premask.pop(slot, None)  # state moved: stale
                    if tracked:
                        if not ok:
                            self.n_table_rejects += 1
                            self._preempt(sess)
                            continue
                        self._advance_sid(slot, sess, tok)
            except Exception as e:   # quarantined: evict THIS row only
                self._fail(sess, f"checker failed during advance: {e!r}")
                continue
            sess.out_ids.append(tok)
            sess.budget -= 1
            if sess.budget <= 0:
                self._finish(sess)
                continue
            live[slot] = tok
        return live

    def _run_decode(self, feed: jnp.ndarray,
                    overlap_fn: Optional[Callable[[], None]] = None):
        """One batched forward; attributes time/count to resident rows.
        The forward is dispatched asynchronously; ``overlap_fn`` (next
        step's host-side mask construction) runs while the device
        executes, then we block so per-request model_time_s measures
        execution, not dispatch (the host would otherwise pay the wait
        inside the next tick's argmax readback, attributed to nothing)."""
        eng = self.eng
        self._sync_pages()
        t0 = time.perf_counter()
        lg, self.cache = eng._decode(eng.params, self.cache, feed)
        built = []
        if overlap_fn is not None and self.overlap:
            built = overlap_fn() or []
        t_mask_end = time.perf_counter()
        lg.block_until_ready()
        wait = time.perf_counter() - t_mask_end
        # overlap credit only when the device provably outlasted the
        # prebuild (we still had to wait on it afterwards); if the build
        # outran the device, the excess sat on the critical path — it
        # stays in mask_time uncredited and is excluded from the model
        # wall below, so the two fields still decompose the step
        hidden = wait > 1e-5
        m_total = sum(b_dt for _, b_dt in built)
        if hidden:
            for b_sess, b_dt in built:
                b_sess.mask_overlap += b_dt
        dt = time.perf_counter() - t0 - (0.0 if hidden else m_total)
        self.n_fwd += 1
        for sess in self.slots:
            if sess is not None:
                sess.n_fwd += 1
                sess.model_time += dt
        return lg

    def _plain_step(self) -> None:
        eng = self.eng
        self._ensure_pages(1)
        live = self._commit_first(self._choose())
        if not any(s is not None for s in self.slots):
            return
        feed = [[eng.tok.pad_id]] * self.capacity
        for slot, tok in live.items():
            feed[slot] = [tok]
        lg = self._run_decode(jnp.asarray(feed, jnp.int32),
                              overlap_fn=self._prebuild_masks)
        self._logits = lg[:, -1].astype(jnp.float32)
        self._inject_nan_rows("decode_nan")

    # -- device-resident fused decode loop (tentpole) ---------------------------

    def _sid_for(self, sess: Session) -> int:
        """Global device-table state id for this session's CURRENT
        checker state, or OFF_FRONTIER when the row cannot be tracked:
        no uploaded tables, no constraint spec, a checker whose concrete
        type is not exactly DominoDecoder (healed / online / naive
        subclasses and stubs own semantics the table was not built
        from), bounded lookahead, a custom EOS id, an unregistered or
        uncertified grammar, or an abstract state outside the certified
        frontier."""
        dts = self._dts
        if dts is None or sess.checker is None or sess.request is None:
            return OFF_FRONTIER
        ch = sess.checker
        if type(ch) is not DominoDecoder or not ch.device_trackable:
            return OFF_FRONTIER
        spec = sess.request.constraint
        gname = getattr(spec, "grammar", None)
        if not isinstance(gname, str) or gname not in dts.offsets:
            return OFF_FRONTIER
        if sess.eos_id != dts.tables[gname].eos_id:
            return OFF_FRONTIER    # table EOS edges assume the engine EOS
        sid = dts.sid_for(gname, ch)
        if sid < 0:
            return OFF_FRONTIER
        # ENTRY AUDIT.  The abstract-key quotient of a context-free
        # grammar is not a bisimulation: two concrete states can share a
        # key yet disagree on their mask (a quotient escape — see
        # analysis.build_device_table).  Admission is the cheap place to
        # catch it: a fresh checker's mask_bits() hits the shared memo,
        # so this is a dict lookup + array compare, not a mask build.
        t0 = time.perf_counter()
        bits = ch.mask_bits()
        sess.mask_time += time.perf_counter() - t0
        if not np.array_equal(dts.mask_host[sid], bits):
            self.n_quotient_escapes += 1
            return OFF_FRONTIER
        return sid

    def _advance_sid(self, slot: int, sess: Session, tok: int) -> None:
        """Mirror a checker advance through the host transition table —
        O(1) incremental device-state tracking — auditing the landing
        state's mask row against the concrete checker every ``sync_n``
        advances so a quotient escape can't drift unbounded."""
        sid = int(self._dts.trans_host[self._dev_state[slot], tok])
        if sid < 0:
            self._dev_state[slot] = OFF_FRONTIER
            return
        self._dev_age[slot] += 1
        if self._dev_age[slot] < self.sync_n:
            self._dev_state[slot] = sid
            return
        self._dev_state[slot] = self._audit_sid(slot, sess, sid)

    def _audit_sid(self, slot: int, sess: Session, sid: int) -> int:
        """Compare the table's mask row against the concrete checker's
        packed mask.  Equal -> the table keeps selecting for this row;
        different -> a quotient escape: demote the row to the exact host
        path (the audit's mask build is kept as its premask, not
        wasted).  Bounds table/checker divergence to one audit
        interval at a cost of 1/sync_n mask builds per token."""
        t0 = time.perf_counter()
        bits = sess.checker.mask_bits()
        sess.mask_time += time.perf_counter() - t0
        self._dev_age[slot] = 0
        # table_corrupt simulates a corrupted device-table mask row; the
        # audit catches it exactly like a real quotient escape would
        corrupt = self._inject("table_corrupt", sess)
        if not corrupt and np.array_equal(self._dts.mask_host[sid], bits):
            return sid
        self.n_quotient_escapes += 1
        self._premask[slot] = bits
        if self.journal is not None:
            self.journal.append({
                "kind": "demote", "rid": sess.rid,
                "reason": ("injected table corruption" if corrupt
                           else "mask-row audit mismatch")})
        return OFF_FRONTIER

    def _device_ready(self) -> bool:
        """True when EVERY resident row can commit tokens without a host
        round-trip: greedy, non-speculative, constrained by a checker
        whose state sits inside an uploaded device table, with room for a
        full block.  All-or-nothing on purpose: one host-path row needs a
        host sync per token anyway, so fusing its batch-mates buys
        nothing and would split the batched forward — mixed ticks take
        the per-token path, where certified rows still gather their
        masks from the device table."""
        if not self.device_loop or self._dts is None or self.sync_n < 2 \
                or self.eng._needs_refeed or self.sup.level > 0:
            return False     # degraded: the ladder owns the path choice
        ready = False
        for slot, sess in enumerate(self.slots):
            if sess is None:
                continue
            if sess.checker is None or sess.speculator is not None \
                    or sess.temperature > 0.0 \
                    or self._dev_state[slot] < 0:
                return False
            ready = True
        if not ready:
            return False
        # a fused block writes up to sync_n new cache positions per row;
        # near max_len fall back to the per-token path (which stops at
        # the exact boundary) rather than write past the cache
        lens = np.asarray(self.cache["len"])
        return int(lens.max()) + self.sync_n <= self.eng.max_len

    def _build_fused(self):
        """Trace the fused N-step decode loop: forward, packed-mask
        argmax, transition-table state advance and KV append run entirely
        on device inside ``lax.while_loop``; the host syncs once per
        block.  Per-row early exit: EOS selection, budget exhaustion,
        off-frontier transition, or a non-finite logits row (fault) drop
        the row from ``active``; the loop ends when no row is active.

        Faithfulness to the per-token path (bitwise, for greedy rows):
        selection is the same ``masked_argmax_pallas_packed`` over the
        same table mask row; injected NaNs poison logits AFTER the
        forward, so detection happens at the NEXT selection's finiteness
        check exactly like the host path; the whole-block length rewind
        (``len = snap_len + n_fed``) is the speculative-rollback idiom —
        every iteration advances every row's ragged ``len`` by one, only
        the fed tokens are real, KV beyond ``len`` is masked by validity.
        """
        eng = self.eng
        n = self.sync_n
        v = eng._v
        pad_id = eng.tok.pad_id
        decode = eng.model.decode_step
        interpret = jax.default_backend() != "tpu"
        cap = self.capacity

        def fused(params, cache, lg, state, active, rem, eos_ids,
                  nan_plan, mask_tab, trans_tab, n_cap):
            snap_len = cache["len"]
            toks0 = jnp.full((cap, n), -1, jnp.int32)
            raws0 = jnp.full((cap, n), -1, jnp.int32)

            # n_cap is a TRACED operand (the deadline clamp changes it
            # block to block without recompiling); the static n still
            # bounds every buffer shape
            def cond(c):
                return (c[0] < jnp.minimum(n, n_cap)) & jnp.any(c[5])

            def body(c):
                (i, cache, lg, out_lg, state, active, rem, toks, raws,
                 n_fed, fault) = c
                finite = jnp.all(jnp.isfinite(lg[:, :v]), axis=-1)
                fault = fault | (active & ~finite)
                commit = active & finite
                masks = mask_tab[jnp.maximum(state, 0)]
                sel, _ = masked_argmax_pallas_packed(
                    lg[:, :v], masks, interpret=interpret)
                sel = sel.astype(jnp.int32)
                raw = jnp.argmax(lg[:, :v], axis=-1).astype(jnp.int32)
                eos_hit = commit & (sel == eos_ids)
                adv = commit & ~eos_hit
                rem = jnp.where(adv, rem - 1, rem)
                fed = adv & (rem > 0)
                nxt = trans_tab[jnp.maximum(state, 0),
                                jnp.maximum(sel, 0)]
                state = jnp.where(adv, nxt, state)
                toks = toks.at[:, i].set(jnp.where(commit, sel, -1))
                raws = raws.at[:, i].set(jnp.where(commit, raw, -1))
                feed = jnp.where(fed, sel, pad_id)[:, None]
                new_lg, cache = decode(params, cache, feed)
                new_lg = new_lg[:, -1, :].astype(jnp.float32)
                new_lg = jnp.where(nan_plan[:, i][:, None], jnp.nan,
                                   new_lg)
                out_lg = jnp.where(fed[:, None], new_lg, out_lg)
                n_fed = n_fed + fed.astype(jnp.int32)
                active = fed & (nxt >= 0)
                return (i + 1, cache, new_lg, out_lg, state, active, rem,
                        toks, raws, n_fed, fault)

            carry = (jnp.int32(0), cache, lg, lg, state, active, rem,
                     toks0, raws0, jnp.zeros((cap,), jnp.int32),
                     jnp.zeros((cap,), bool))
            (steps, cache, _lg, out_lg, state, _active, _rem, toks, raws,
             n_fed, fault) = jax.lax.while_loop(cond, body, carry)
            cache = dict(cache)
            cache["len"] = snap_len + n_fed
            return cache, out_lg, state, toks, raws, n_fed, fault, steps

        return jax.jit(fused, donate_argnums=(1,))

    def _deadline_cap(self) -> int:
        """Clamp the next fused block's step count to the nearest
        resident deadline: a full sync_n block can overshoot a deadline
        by up to sync_n tokens' wall time, so price the remaining budget
        of every deadline-carrying resident through the measured
        tokens/s EWMA and stop the block there (>= 1 step: lifecycle
        checks still only run at block boundaries, so the block must
        make progress).  Unprimed EWMA (first block) -> no clamp."""
        n_cap = self.sync_n
        if self._tok_s_ema <= 0.0:
            return n_cap
        now = time.perf_counter()
        for sess in self.slots:
            if sess is None:
                continue
            deadline = sess.deadline_s
            if deadline is None:
                deadline = self.default_deadline_s
            if deadline is None:
                continue
            left = deadline - (now - sess.t_submit)
            n_cap = min(n_cap, max(1, int(left * self._tok_s_ema)))
        if n_cap < self.sync_n:
            self.n_deadline_clamps += 1
        return n_cap

    def _device_step(self) -> None:
        """One fused tick: run up to ``sync_n`` decode steps in a single
        device call, then ONE host readback, then replay every committed
        token through the concrete checkers (``_resync_row``) so host
        state, statuses and results are exactly what the per-token path
        would have produced for the same tokens."""
        eng = self.eng
        # reserve the whole block's cache growth up front (may preempt —
        # preempted rows clear their _dev_state, survivors stay eligible)
        self._ensure_pages(self.sync_n)
        if not any(s is not None for s in self.slots):
            return
        self._sync_pages()
        active0 = np.asarray([s is not None for s in self.slots], bool)
        rem0 = np.asarray([0 if s is None else s.budget
                           for s in self.slots], np.int32)
        eos0 = np.asarray([-1 if s is None else s.eos_id
                           for s in self.slots], np.int32)
        state0 = np.where(active0, self._dev_state,
                          OFF_FRONTIER).astype(np.int32)
        # consult the decode_nan fault plan for the whole block up front
        # (same per-row consultation order as sync_n host ticks)
        self._nan_plan[:] = False
        if self.injector is not None:
            for j in range(self.sync_n):
                for slot, sess in enumerate(self.slots):
                    if sess is not None:
                        self._nan_plan[slot, j] = self._inject(
                            "decode_nan", sess)
        if self._fused_fn is None:
            self._fused_fn = self._build_fused()
        n_cap = self._deadline_cap()
        # the fused call DONATES the cache, so it must never be retried:
        # injected device_timeout is consulted PRE-dispatch (nothing
        # dispatched yet -> retry-safe no-op thunk), and a real exception
        # below resets the engine instead of re-running the block
        ok, err = self.sup.guard(
            "fused_dispatch", lambda: None,
            inject=lambda: self._inject("device_timeout"))
        if not ok:
            self.sup.degrade("fused_dispatch", err)
            return           # nothing ran; next tick takes the host path
        t0 = time.perf_counter()
        try:
            (self.cache, out_lg, state_dev, toks_dev, raws_dev, n_fed_dev,
             fault_dev, steps_dev) = self._fused_fn(
                eng.params, self.cache, self._logits,
                jnp.asarray(state0), jnp.asarray(active0),
                jnp.asarray(rem0), jnp.asarray(eos0),
                jnp.asarray(self._nan_plan),
                self._dts.mask_dev, self._dts.trans_dev,
                jnp.int32(n_cap))
            out_lg.block_until_ready()
        except Exception as e:
            # an XLA/runtime error escaped the fused dispatch and the
            # donated cache is unrecoverable: reset the engine surface
            # (residents recompute-preempt, outputs unchanged) and step
            # down the ladder
            self._engine_reset(f"fused block failed: {e!r}")
            self.sup.degrade("fused_block", e)
            return
        dt = time.perf_counter() - t0
        self._logits = out_lg
        # the block's ONE host sync: tokens, states, counts, faults and
        # step count all come back in a single readback
        self.n_host_syncs += 1
        if self._inject("device_error"):
            # simulated corrupt readback: nothing from this block can be
            # trusted, so discard it wholesale — no token was committed
            # or journaled, so recompute-preemption keeps outputs exact
            self._engine_reset("device_error at fused-block readback")
            self.sup.degrade("fused_readback",
                             RuntimeError("injected device_error at "
                                          "fused-block readback"))
            return
        if self.sup.block_watchdog_s is not None \
                and dt > self.sup.block_watchdog_s:
            # the block FINISHED, just too slowly: its results are good
            # (commit them below) but the device is suspect — degrade
            self.sup.n_watchdog_trips += 1
            self.sup.degrade(
                "fused_block_watchdog",
                TimeoutError(f"fused block took {dt:.3f}s > watchdog "
                             f"{self.sup.block_watchdog_s:g}s"))
        toks = np.asarray(toks_dev)
        raws = np.asarray(raws_dev)
        state_out = np.asarray(state_dev)
        n_fed = np.asarray(n_fed_dev)
        fault = np.asarray(fault_dev)
        steps_run = int(steps_dev)
        self._last_block_steps = steps_run
        fed_total = int(n_fed.sum())
        if fed_total and dt > 0:
            # committed-tokens/s EWMA: prices the next block's deadline
            # clamp (_deadline_cap).  alpha=0.3 — quick to prime, stable
            # against one slow (compile) block.
            rate = fed_total / dt
            self._tok_s_ema = (rate if self._tok_s_ema == 0.0
                               else 0.7 * self._tok_s_ema + 0.3 * rate)
        self.n_fwd += steps_run
        for slot, sess in enumerate(list(self.slots)):
            if sess is None:
                continue
            sess.n_fwd += int(n_fed[slot])
            sess.model_time += dt
            self._resync_row(slot, sess, toks[slot], raws[slot],
                             bool(fault[slot]), int(state_out[slot]),
                             steps_run)
        self._shrink_pages()   # rows that exited early rewound their len

    def _resync_row(self, slot: int, sess: Session, toks_row, raws_row,
                    faulted: bool, state_out: int,
                    steps_run: int) -> None:
        """Replay one row's device-committed token block through its
        CONCRETE checker, mirroring ``_commit_first`` token for token —
        grammar state, out_ids, budget, EOS/status taxonomy and
        intervention counts end up exactly as the per-token path would
        have left them.  A checker exception (injected or real)
        quarantines THIS row; a checker REJECTION (quotient escape)
        recompute-preempts it with the validated prefix intact; a device
        fault flag surfaces as the same ``internal_error`` the host
        finiteness check raises."""
        if sess.cancel_requested:
            # cancellation arrived while the block was in flight: honor
            # it at THIS block boundary — none of the block's tokens are
            # committed (or journaled) for this row, and the next tick's
            # lifecycle sweep terminates it with `cancelled`, so a
            # cancel never trails by more than one block
            return
        ch = sess.checker
        for j in range(steps_run):
            tok = int(toks_row[j])
            if tok < 0:
                break                  # row went inactive at step j
            sess.n_int += int(tok != int(raws_row[j]))
            try:
                if tok == sess.eos_id:
                    if ch.advance(tok):
                        sess.finished_eos = True
                        self._finish(sess)
                    else:
                        # quotient escape: table offered EOS where the
                        # checker forbids it.  State is unchanged, the
                        # validated prefix intact: recompute-preempt;
                        # _sid_for's entry audit demotes the row to the
                        # host path on re-admission if still escaped.
                        self.n_table_rejects += 1
                        self._preempt(sess)
                    return
                if self._inject("advance_error", sess):
                    raise InjectedFault(
                        f"injected advance failure (rid={sess.rid})")
                ok = ch.advance(tok)
            except Exception as e:   # quarantined: evict THIS row only
                self._fail(sess, f"checker failed during advance: {e!r}")
                return
            self._premask.pop(slot, None)   # state moved: mask stale
            if not ok:
                # quotient escape surfaced as a concrete rejection (same
                # recovery as the EOS case above) — never silent
                # corruption, never a lost request
                self.n_table_rejects += 1
                self._preempt(sess)
                return
            sess.out_ids.append(tok)
            sess.budget -= 1
            sess.n_device_tokens += 1
            self.n_device_tokens += 1
            if sess.budget <= 0:
                self._finish(sess)
                return
        if faulted:
            self._fail(sess, "non-finite logits from device step")
            return
        if state_out < 0:
            self._dev_state[slot] = OFF_FRONTIER
            return
        # block boundary = audit point: the fused loop ran up to sync_n
        # table transitions with no concrete checker in the loop
        self._dev_state[slot] = self._audit_sid(slot, sess, int(state_out))

    # -- speculative decode tick (§3.6) -----------------------------------------

    def _spec_step(self, width: int) -> None:
        """One speculative tick.  ``width`` is 1 + the widest resident
        row's ``spec_s`` (per-row policy): rows with shorter chains — or
        no speculator at all — ride along on pad positions."""
        eng = self.eng
        pad = eng.tok.pad_id
        # reserve the full verify window up front: growing mid-tick could
        # preempt a row whose token was already committed into the feed
        self._ensure_pages(width)
        live = self._commit_first(self._choose())
        if not any(s is not None for s in self.slots):
            return
        proposals: Dict[int, List[int]] = {}
        for slot, tok in live.items():
            sess = self.slots[slot]
            ch = sess.checker
            props = []
            if ch is not None and sess.speculator is not None \
                    and hasattr(ch, "clone"):
                props = sess.speculator.propose(ch)
            sess.n_prop += len(props)
            proposals[slot] = props
        if all(len(p) == 0 for p in proposals.values()):
            # nothing to verify anywhere: plain-width forward, no rollback
            feed = [[pad]] * self.capacity
            for slot, tok in live.items():
                feed[slot] = [tok]
            lg = self._run_decode(jnp.asarray(feed, jnp.int32),
                                  overlap_fn=self._prebuild_masks)
            self._logits = lg[:, -1].astype(jnp.float32)
            self._inject_nan_rows("decode_nan")
            self._shrink_pages()       # return the unused verify window
            return
        feed = [[pad] * width for _ in range(self.capacity)]
        for slot, tok in live.items():
            row = [tok] + proposals[slot]
            feed[slot][:len(row)] = row
        snapshot = self.cache          # JAX arrays are immutable: free
        snap_len = snapshot["len"]
        # overlapped prebuild: checker state is post-commit, i.e. exactly
        # the state verification position 0 selects from — _verify_row
        # consumes the mask, and untouched rows keep it for the next tick
        lg_dev = self._run_decode(jnp.asarray(feed, jnp.int32),
                                  overlap_fn=self._prebuild_masks)
        lg_host = np.asarray(lg_dev)[:, :, :eng._v]
        # rows not in `live` consumed the full pad width; "accepting" it
        # keeps their (garbage, to-be-overwritten) length bookkeeping
        # consistent with the decoded cache
        accepted_vec = np.full(self.capacity, width - 1, np.int32)
        for slot, props in proposals.items():
            try:
                accepted_vec[slot] = self._verify_row(slot, props,
                                                      lg_host[slot])
            except Exception as e:   # quarantined: evict THIS row only
                accepted_vec[slot] = 0
                if self.slots[slot] is not None:
                    self._fail(self.slots[slot],
                               f"checker failed during speculative "
                               f"verify: {e!r}")
        if eng._needs_refeed:
            self._fixup_refeed(snapshot, live, proposals, accepted_vec,
                               lg_dev, width)
        else:
            # per-row rollback: KV entries beyond `len` are masked by
            # validity, so rewinding the per-row length is the whole
            # rollback; pages now wholly beyond a frontier go back to the
            # pool right away
            cache = dict(self.cache)
            cache["len"] = snap_len + 1 + jnp.asarray(accepted_vec)
            self.cache = cache
            self._logits = lg_dev[
                jnp.arange(self.capacity), jnp.asarray(accepted_vec)
            ].astype(jnp.float32)
            self._shrink_pages()

    def _verify_row(self, slot: int, props: List[int],
                    lg_row: np.ndarray) -> int:
        """Greedy per-row verification, identical to the single-request
        path: accept the longest prefix where the proposal matches the
        (masked) selection at each position.  All policy — temperature,
        opportunistic checking, EOS id — is the row's own."""
        eng = self.eng
        sess = self.slots[slot]
        ch = sess.checker
        greedy = sess.temperature <= 0.0
        accepted = 0
        for i, prop in enumerate(props):
            if sess.budget <= 0:
                break
            if not np.all(np.isfinite(lg_row[i])):
                # surfaces as internal_error via the caller's quarantine
                raise RuntimeError(
                    "non-finite logits in speculative verify window")
            tok_i = None
            if greedy and int(lg_row[i].argmax()) == prop:
                t0 = time.perf_counter()
                ok = ch.check_token(prop)
                sess.mask_time += time.perf_counter() - t0
                if ok:
                    tok_i = prop
            if tok_i is None:
                # a full mask is needed at this position — worth
                # prebuilding again next tick under opportunistic mode
                self._opp_intervened[slot] = True
                # position 0 selects from the state the overlapped
                # prebuild saw; later positions advanced past it
                pre = self._premask.pop(slot, None) if i == 0 else None
                # under opportunistic mode _pick may accept the raw
                # argmax without reading the premask — don't count a hit
                # we can't attest
                if not (sess.opportunistic and greedy):
                    self.premask_hits += int(pre is not None)
                hits0 = getattr(ch, "n_mask_memo_hits", 0)
                tok_i, intervened, mask_dt = eng._pick(lg_row[i], ch,
                                                       premask=pre,
                                                       policy=sess)
                # _pick may have built a full mask (memo-eligible):
                # keep the scheduler aggregate consistent with the
                # per-session checker counters
                self.mask_cache_hits += \
                    getattr(ch, "n_mask_memo_hits", 0) - hits0
                sess.mask_time += mask_dt
                if tok_i is None:          # dead end mid-verification
                    sess.dead_end = True
                    break
                sess.n_int += intervened
            if tok_i != prop:
                break
            sess.speculator.observe(ch.state_key(), tok_i)
            if self._inject("advance_error", sess):
                raise InjectedFault(
                    f"injected advance failure (rid={sess.rid})")
            ch.advance(tok_i)
            self._premask.pop(slot, None)   # state moved: mask stale
            if self._dev_state[slot] >= 0:
                # tok_i was checker-validated above; only the table state
                # needs mirroring (with its periodic escape audit)
                self._advance_sid(slot, sess, tok_i)
            accepted += 1
            if tok_i == sess.eos_id:
                sess.finished_eos = True
                break
            sess.out_ids.append(tok_i)
            sess.budget -= 1
        sess.n_acc += accepted
        if sess.finished_eos or sess.dead_end or sess.budget <= 0:
            self._finish(sess)
        return accepted

    def _fixup_refeed(self, snapshot, live, proposals, accepted_vec,
                      lg_dev, width: int) -> None:
        """SSM/SWA rows cannot rewind state: re-feed each partially-
        accepted row's committed tokens from the pre-speculation cache.
        Rows are grouped by committed length, so each group is ONE
        gather/decode/scatter round (B=K ragged refeed) instead of a B=1
        decode plus whole-cache scatter per row — one compile per
        (group size, width) pair, bounded by capacity x spec_s."""
        eng = self.eng
        groups: Dict[int, List[int]] = {}
        committed: Dict[int, List[int]] = {}
        for slot, tok in live.items():
            sess = self.slots[slot]
            if sess is None:
                # finished during verification: the slot is free and its
                # row state is overwritten at the next admission
                continue
            a = int(accepted_vec[slot])
            props = proposals[slot]
            if a == len(props) and len(props) == width - 1:
                # full accept, no pads: the batch-decoded row state is exact
                self._logits = self._logits.at[slot].set(
                    lg_dev[slot, -1].astype(jnp.float32))
                continue
            groups.setdefault(a, []).append(slot)
            committed[slot] = [tok] + props[:a]
        for a, slots in groups.items():
            idx = jnp.asarray(slots, jnp.int32)
            feed = jnp.asarray([committed[s] for s in slots], jnp.int32)
            t0 = time.perf_counter()
            rows = _gather_rows_jit(snapshot, idx)
            lg_re, rows = eng._decode(eng.params, rows, feed)
            self.cache = _scatter_rows_jit(self.cache, rows, idx)
            self._logits = self._logits.at[idx].set(
                lg_re[:, -1].astype(jnp.float32))
            # block so model_time measures execution, not dispatch (the
            # wait would otherwise hide in the next tick's argmax
            # readback, attributed to nothing)
            lg_re.block_until_ready()
            dt = time.perf_counter() - t0
            self.n_fwd += 1
            for slot in slots:
                sess = self.slots[slot]
                sess.n_fwd += 1
                sess.model_time += dt
