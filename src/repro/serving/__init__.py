from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.faults import (FaultInjector, InjectedFault,
                                  InvariantViolation, check_invariants)
from repro.serving.request import ConstraintSpec, DecodeParams, Request
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.serving.session import GenerationResult, Session

__all__ = ["ServingEngine", "EngineConfig", "GenerationResult", "Session",
           "ContinuousBatchingScheduler", "ConstraintSpec", "DecodeParams",
           "Request", "FaultInjector", "InjectedFault",
           "InvariantViolation", "check_invariants"]
