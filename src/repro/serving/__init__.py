from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.faults import (FaultInjector, InjectedFault,
                                  InvariantViolation, check_invariants)
from repro.serving.journal import (JournalEntry, TokenJournal, read_records,
                                   replay_journal)
from repro.serving.prefix_cache import PrefixCache, RadixNode
from repro.serving.request import ConstraintSpec, DecodeParams, Request
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.serving.session import GenerationResult, Session
from repro.serving.supervisor import DegradationSupervisor

__all__ = ["ServingEngine", "EngineConfig", "GenerationResult", "Session",
           "ContinuousBatchingScheduler", "ConstraintSpec", "DecodeParams",
           "Request", "FaultInjector", "InjectedFault",
           "InvariantViolation", "check_invariants", "TokenJournal",
           "JournalEntry", "read_records", "replay_journal",
           "DegradationSupervisor", "PrefixCache", "RadixNode"]
