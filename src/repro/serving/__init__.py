from repro.serving.engine import EngineConfig, GenerationResult, ServingEngine

__all__ = ["ServingEngine", "EngineConfig", "GenerationResult"]
