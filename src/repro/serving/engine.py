"""Constrained serving engine — DOMINO integrated as a first-class feature.

Modes (the rows of the paper's tables):
  unconstrained          plain decoding
  domino                 DOMINO masks, lookahead k (None = ∞, minimally
                         invasive); opportunistic masking optional
  naive                  greedy single-terminal masking (= DOMINO k=0)
  online                 full-vocab online parser checking (llama.cpp/GCD
                         cost profile, identical masks to domino k=∞)
  template               GUIDANCE-style template programs (forced tokens)

Speculation (§3.6): the grammar-state count model proposes up to ``s``
tokens; ONE decode_step forward scores [pending || proposals]; the longest
verified prefix commits.  Rollback is a cache-length rewind for full-
attention/MLA archs; ring-buffer (SWA) and recurrent (SSM/hybrid) archs
re-feed the accepted tokens from the pre-speculation cache (JAX arrays are
immutable, so "snapshotting" the old cache is keeping a reference — free).

This module keeps the single-request fast path and the template baseline.
Batched serving lives in ``serving/scheduler.py`` (continuous batching
with slot reuse); ``generate_batch`` delegates to it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask
from repro.core.baselines import OnlineParserDecoder, TemplateSession
from repro.core.domino import DominoDecoder
from repro.core.grammar import Grammar
from repro.core.scanner import Scanner
from repro.core.speculation import CountModel, Speculator
from repro.core.trees import TreeCache
from repro.models.model import Model
from repro.serving.session import GenerationResult
from repro.tokenizer import BPETokenizer


@dataclasses.dataclass
class EngineConfig:
    mode: str = "domino"              # unconstrained|domino|naive|online|template
    k: Optional[int] = None           # DOMINO lookahead (None = ∞)
    opportunistic: bool = False
    speculative: bool = False
    spec_s: int = 8
    spec_threshold: float = 0.5
    temperature: float = 0.0          # 0 = greedy
    max_tokens: int = 128
    seed: int = 0
    # token healing (§3.5): strip the last `heal` prompt tokens and force
    # the stripped text as a generation prefix (bridge tokens across the
    # prompt boundary become available)
    heal: int = 0


class ServingEngine:
    def __init__(self, model: Model, params, tok: BPETokenizer,
                 grammar: Optional[Grammar] = None,
                 cfg: Optional[EngineConfig] = None,
                 tree_cache: Optional[TreeCache] = None,
                 count_model: Optional[CountModel] = None,
                 max_len: int = 1024):
        self.model = model
        self.params = params
        self.tok = tok
        self.grammar = grammar
        self.cfg = cfg or EngineConfig()
        self.max_len = max_len
        self.rng = np.random.default_rng(self.cfg.seed)
        if grammar is not None and self.cfg.mode in ("domino", "naive",
                                                     "online"):
            self.tree_cache = tree_cache or TreeCache(
                Scanner(grammar), list(tok.vocab))
        else:
            self.tree_cache = None
        self.speculator = Speculator(
            count_model, s=self.cfg.spec_s,
            threshold=self.cfg.spec_threshold) if self.cfg.speculative else None
        self._v = tok.vocab_size   # model logits may be vocab-padded
        # jit'd steps (compiled once per (batch, s) shape)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        # rollback safety (DESIGN.md §Arch-applicability)
        blocks = self._all_block_kinds()
        self._needs_refeed = any(
            b in ("mamba1", "mamba2", "swa") for b in blocks)

    def _all_block_kinds(self) -> List[str]:
        head, reps, group, tail = self.model.cfg.layer_program
        return list(head) + list(group) + list(tail)

    def precompute(self) -> Dict[str, float]:
        """Offline warm path: build every reachable subterminal tree now
        (paper Algorithm 2) so serving never constructs trees on the
        critical path.  The TreeCache is shared across all sessions."""
        if self.tree_cache is None:
            return {"positions": 0.0, "seconds": 0.0}
        return self.tree_cache.precompute()

    # -- checker factory ---------------------------------------------------------

    def _prep_request(self, prompt: str):
        """Shared request preamble: encode, apply token healing (§3.5),
        build the checker.  Both ``generate`` and the scheduler's
        ``submit`` go through here so their outputs stay token-for-token
        identical."""
        prompt_ids = self.tok.encode(prompt) or [self.tok.bos_id]
        heal_prefix = ""
        if self.cfg.heal > 0 and len(prompt_ids) > self.cfg.heal:
            from repro.core.healing import heal_prompt
            prompt_ids, heal_prefix = heal_prompt(
                prompt_ids, self.tok.vocab, n_strip=self.cfg.heal)
        return prompt_ids, self._make_checker(heal_prefix)

    def make_session(self, rid: int, prompt: str, extra_inputs=None):
        """Create a scheduler :class:`~repro.serving.session.Session` for
        ``prompt`` (used by ``ContinuousBatchingScheduler.submit``)."""
        from repro.serving.session import Session
        prompt_ids, checker = self._prep_request(prompt)
        return Session(rid=rid, prompt=prompt, prompt_ids=prompt_ids,
                       checker=checker, budget=self.cfg.max_tokens,
                       extra_inputs=extra_inputs)

    def _make_checker(self, heal_prefix: str = ""):
        mode = self.cfg.mode
        if mode == "unconstrained" or self.grammar is None:
            return None
        if mode == "domino" and heal_prefix:
            from repro.core.healing import HealedDecoder
            return HealedDecoder(self.grammar, list(self.tok.vocab),
                                 self.tok.eos_id, heal_prefix,
                                 k=self.cfg.k, tree_cache=self.tree_cache)
        if mode == "domino":
            return DominoDecoder(self.grammar, list(self.tok.vocab),
                                 self.tok.eos_id, k=self.cfg.k,
                                 tree_cache=self.tree_cache)
        if mode == "naive":
            return DominoDecoder(self.grammar, list(self.tok.vocab),
                                 self.tok.eos_id, k=0,
                                 tree_cache=self.tree_cache)
        if mode == "online":
            return OnlineParserDecoder(self.grammar, list(self.tok.vocab),
                                       self.tok.eos_id,
                                       tree_cache=self.tree_cache)
        raise ValueError(mode)

    # -- sampling -----------------------------------------------------------------

    def _select(self, logits: np.ndarray, mask: Optional[np.ndarray]) -> int:
        lg = logits.astype(np.float64)
        if mask is not None:
            lg = np.where(mask, lg, -1e30)
        if self.cfg.temperature <= 0.0:
            return int(lg.argmax())
        p = np.exp((lg - lg.max()) / self.cfg.temperature)
        p = p / p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _pick(self, logits: np.ndarray, checker, premask=None
              ) -> Tuple[Optional[int], int, float]:
        """Select the next token under the active constraint mode.

        Returns (token, intervened?, mask_seconds).  ``token`` is None when
        the checker reached a dead end (no legal token, EOS included) —
        callers surface this as ``GenerationResult.dead_end`` instead of
        silently emitting grammar-violating output.  ``premask`` is a mask
        the caller already built from the checker's current state (e.g.
        the scheduler's host/device-overlapped prebuild); its build time
        was accounted at build site, so it does not count here.  A packed
        uint32 premask (the scheduler's native row format) is unpacked
        here — selection below wants the bool view.
        """
        if checker is None:
            return self._select(logits, None), 0, 0.0
        mask_t = 0.0
        if self.cfg.opportunistic and self.cfg.temperature <= 0.0:
            cand = int(logits.argmax())
            t0 = time.perf_counter()
            ok = checker.check_token(cand)
            mask_t += time.perf_counter() - t0
            if ok:
                return cand, 0, mask_t
        if premask is not None:
            if premask.dtype == np.uint32:
                premask = bitmask.unpack(premask, self._v)
            mask = premask
        else:
            t0 = time.perf_counter()
            mask = checker.mask()
            mask_t += time.perf_counter() - t0
        if not mask.any():
            # the checker invariant makes this unreachable for sound
            # grammars; if it happens, report it rather than force EOS
            return None, 0, mask_t
        tok = self._select(logits, mask)
        intervened = int(tok != int(logits.argmax()))
        return tok, intervened, mask_t

    # -- generation -----------------------------------------------------------------

    def generate(self, prompt: str,
                 extra_inputs: Optional[Dict[str, Any]] = None
                 ) -> GenerationResult:
        t_start = time.perf_counter()
        cfg = self.cfg
        prompt_ids, checker = self._prep_request(prompt)
        cache = self.model.init_cache(1, self.max_len)
        inputs = {"tokens": jnp.asarray([prompt_ids], jnp.int32)}
        if extra_inputs:
            inputs.update(extra_inputs)

        model_t = 0.0
        mask_t = 0.0
        n_fwd = 0
        n_int = 0
        n_prop = 0
        n_acc = 0
        out_ids: List[int] = []

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, inputs, cache)
        logits = np.asarray(logits)[0, -1][:self._v]
        model_t += time.perf_counter() - t0
        n_fwd += 1

        finished = False
        dead_end = False
        budget = cfg.max_tokens
        while budget > 0 and not finished and not dead_end:
            # ---- try speculative fast path -------------------------------------
            if (self.speculator is not None and checker is not None
                    and hasattr(checker, "clone")):
                tok0, intervened, dt = self._pick(logits, checker)
                mask_t += dt
                if tok0 is None:
                    dead_end = True
                    break
                n_int += intervened
                if tok0 == self.tok.eos_id:
                    finished = True
                    checker.advance(tok0)
                    break
                self.speculator.observe(checker.state_key(), tok0)
                checker.advance(tok0)
                out_ids.append(tok0)
                budget -= 1
                proposals = self.speculator.propose(checker)
                n_prop += len(proposals)
                feed = [tok0] + proposals
                # static verify width (spec_s + 1): TPU-friendly single
                # compiled program; pad positions are rolled back below
                n_pad = (1 + self.cfg.spec_s) - len(feed)
                feed_p = feed + [self.tok.pad_id] * n_pad
                cache_before = cache
                t0 = time.perf_counter()
                lg_multi, cache = self._decode(
                    self.params, cache, jnp.asarray([feed_p], jnp.int32))
                lg_multi = np.asarray(lg_multi)[0][:, :self._v]
                model_t += time.perf_counter() - t0
                n_fwd += 1
                # verify proposals against (masked) argmax at each position
                accepted = 0
                ch = checker
                for i, prop in enumerate(proposals):
                    if budget <= 0:
                        break
                    # fast verification: if the raw argmax equals the
                    # proposal, an O(token) opportunistic legality check
                    # replaces the full tree-walk mask
                    tok_i = None
                    if cfg.temperature <= 0.0 \
                            and int(lg_multi[i].argmax()) == prop:
                        t0 = time.perf_counter()
                        ok = ch.check_token(prop)
                        mask_t += time.perf_counter() - t0
                        if ok:
                            tok_i = prop
                    if tok_i is None:
                        tok_i, intervened, dt = self._pick(lg_multi[i], ch)
                        mask_t += dt
                        if tok_i is None:
                            dead_end = True
                            break
                        n_int += intervened
                    if tok_i != prop:
                        break
                    self.speculator.observe(ch.state_key(), tok_i)
                    ch.advance(tok_i)
                    accepted += 1
                    if tok_i == self.tok.eos_id:
                        finished = True
                        break
                    out_ids.append(tok_i)
                    budget -= 1
                n_acc += accepted
                rejected = len(proposals) - accepted
                if rejected > 0 or n_pad > 0:
                    if self._needs_refeed:
                        # recompute from the pre-speculation cache (exact
                        # length: recurrent/ring state cannot host pads)
                        t0 = time.perf_counter()
                        lg_re, cache = self._decode(
                            self.params, cache_before,
                            jnp.asarray([feed[:1 + accepted]], jnp.int32))
                        logits = np.asarray(lg_re)[0, -1][:self._v]
                        model_t += time.perf_counter() - t0
                        n_fwd += 1
                    else:
                        cache = self.model.rollback(cache,
                                                    rejected + n_pad)
                        logits = lg_multi[accepted]
                else:
                    logits = lg_multi[len(proposals)]
                continue

            # ---- plain path ------------------------------------------------------
            tok, intervened, dt = self._pick(logits, checker)
            mask_t += dt
            if tok is None:
                dead_end = True
                break
            n_int += intervened
            if checker is not None:
                checker.advance(tok)
            if tok == self.tok.eos_id:
                finished = True
                break
            out_ids.append(tok)
            budget -= 1
            t0 = time.perf_counter()
            lg, cache = self._decode(self.params, cache,
                                     jnp.asarray([[tok]], jnp.int32))
            logits = np.asarray(lg)[0, -1][:self._v]
            model_t += time.perf_counter() - t0
            n_fwd += 1

        return GenerationResult(
            text=self.tok.decode(out_ids),
            token_ids=out_ids,
            n_forward_passes=n_fwd,
            n_tokens=len(out_ids),
            n_interventions=n_int,
            n_spec_proposed=n_prop,
            n_spec_accepted=n_acc,
            mask_time_s=mask_t,
            model_time_s=model_t,
            wall_time_s=time.perf_counter() - t_start,
            finished=finished,
            dead_end=dead_end,
            mask_cache_hits=getattr(checker, "n_mask_memo_hits", 0),
        )

    # -- batched serving -------------------------------------------------------------

    def generate_batch(self, prompts: List[str],
                       max_batch: Optional[int] = None,
                       paged: Optional[bool] = None,
                       page_size: Optional[int] = None,
                       n_pages: Optional[int] = None
                       ) -> List[GenerationResult]:
        """Serve ``prompts`` through the continuous-batching scheduler.

        ``max_batch`` caps the decode batch (slots); extra prompts wait in
        the admission queue and reuse slots as earlier requests finish.
        All architectures are supported: recurrent/ring rows are admitted
        by exact-length prefill and speculation uses per-row refeed.
        On pure full-attention/MLA stacks the KV cache is paged by
        default (``paged``/``page_size``/``n_pages`` size the pool; an
        undersized pool exerts admission backpressure instead of OOM).
        Call :meth:`precompute` first to keep tree construction off the
        serving critical path.
        """
        from repro.serving.scheduler import ContinuousBatchingScheduler
        cap = min(len(prompts), max_batch) if max_batch else len(prompts)
        kwargs = {}
        if paged is not None:
            kwargs["paged"] = paged
        if page_size is not None:
            kwargs["page_size"] = page_size
        if n_pages is not None:
            kwargs["n_pages"] = n_pages
        sched = ContinuousBatchingScheduler(self, capacity=cap, **kwargs)
        sessions = [sched.submit(p) for p in prompts]
        sched.run()
        return [s.result for s in sessions]

    # -- template mode ------------------------------------------------------------

    def generate_template(self, prompt: str, parts) -> GenerationResult:
        """GUIDANCE-style template execution (baseline for Fig. 2/Table 2)."""
        t_start = time.perf_counter()
        session = TemplateSession(parts, list(self.tok.vocab),
                                  self.tok.eos_id, self.tok.encode_greedy)
        prompt_ids = self.tok.encode(prompt) or [self.tok.bos_id]
        cache = self.model.init_cache(1, self.max_len)
        t0 = time.perf_counter()
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray([prompt_ids], jnp.int32)},
            cache)
        logits = np.asarray(logits)[0, -1][:self._v]
        model_t = time.perf_counter() - t0
        n_fwd = 1
        out_ids: List[int] = []
        budget = self.cfg.max_tokens
        while budget > 0:
            action, payload = session.next_action()
            if action == "done":
                break
            if action == "force":
                if not payload:
                    continue
                out_ids.extend(payload)
                budget -= len(payload)
                t0 = time.perf_counter()
                lg, cache = self._decode(
                    self.params, cache, jnp.asarray([payload], jnp.int32))
                logits = np.asarray(lg)[0, -1][:self._v]
                model_t += time.perf_counter() - t0
                n_fwd += 1
                continue
            # gen under slot mask
            tok = self._select(logits, payload)
            session.feed(tok)
            if tok == self.tok.eos_id:
                continue  # slot ended; do not emit eos into output
            out_ids.append(tok)
            budget -= 1
            t0 = time.perf_counter()
            lg, cache = self._decode(self.params, cache,
                                     jnp.asarray([[tok]], jnp.int32))
            logits = np.asarray(lg)[0, -1][:self._v]
            model_t += time.perf_counter() - t0
            n_fwd += 1
        return GenerationResult(
            text=self.tok.decode(out_ids), token_ids=out_ids,
            n_forward_passes=n_fwd, n_tokens=len(out_ids),
            n_interventions=session.forced_tokens,
            n_spec_proposed=0, n_spec_accepted=0,
            mask_time_s=0.0, model_time_s=model_t,
            wall_time_s=time.perf_counter() - t_start, finished=True)
