"""Constrained serving engine — DOMINO integrated as a first-class feature.

The unit of work is a :class:`~repro.serving.request.Request`:
``prompt + ConstraintSpec + DecodeParams``.  The engine itself owns no
grammar and no decode policy — it owns a **grammar registry**:

    engine = ServingEngine(model, params, tok)
    engine.register_grammar("json", json_grammar)   # one shared TreeCache
    engine.register_grammar("c", c_grammar)         # per grammar
    engine.precompute()                             # warm ALL of them
    r = engine.generate(Request(
        "a config: ",
        ConstraintSpec(grammar="json", mode="domino"),
        DecodeParams(max_tokens=64)))

Each registered grammar gets ONE shared ``TreeCache`` (subterminal trees +
packed-mask memo) reused by every request that references it — sessions
never build trees per request — and ``precompute()`` (paper Algorithm 2)
warms every registered cache off the serving critical path.  A
``ConstraintSpec`` may also carry a ``Grammar`` object directly; it is
auto-registered on first use so repeats still share a cache.

Constraint modes (the rows of the paper's tables), per request:
  unconstrained          plain decoding
  domino                 DOMINO masks, lookahead k (None = ∞, minimally
                         invasive); opportunistic masking optional
  naive                  greedy single-terminal masking (= DOMINO k=0)
  online                 full-vocab online parser checking (llama.cpp/GCD
                         cost profile, identical masks to domino k=∞)
  template               GUIDANCE-style template programs (forced tokens)

Speculation (§3.6) is a per-request ``DecodeParams`` knob: the
grammar-state count model (shared engine-wide, so priors learned by one
request speed up the next) proposes up to ``s`` tokens; ONE decode_step
forward scores [pending || proposals]; the longest verified prefix
commits.  Rollback is a cache-length rewind for full-attention/MLA archs;
ring-buffer (SWA) and recurrent (SSM/hybrid) archs re-feed the accepted
tokens from the pre-speculation cache.

Sampling is per-request: each request draws from its own
``np.random.Generator`` seeded by ``DecodeParams.seed``, so a sampled
request's output never depends on batch composition or admission order.

Back-compat: the legacy surface — ``ServingEngine(model, params, tok,
grammar, EngineConfig(...))`` plus ``generate("prompt")`` — still works
token-for-token for greedy decoding (temperature 0, every existing test
and table row).  The constructor grammar is registered under the name
``"default"`` and the ``EngineConfig`` becomes the engine's
default-``Request`` factory (:meth:`make_request`); a bare string
anywhere a ``Request`` is accepted submits that default request.  One
deliberate semantic change: sampled decoding reseeds per request (it
used to consume a shared engine RNG that advanced across calls), so
repeated identical sampled requests return identical output — pass a
different ``DecodeParams.seed`` per request for best-of-n diversity.

This module keeps the single-request fast path and the template baseline.
Batched serving lives in ``serving/scheduler.py`` (continuous batching
with slot reuse and per-row constraint routing); ``generate_batch``
delegates to it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask
from repro.core.analysis import (OFF_FRONTIER, AnalysisReport,
                                 DeviceGrammarTable, analyze, enforce)
from repro.core.baselines import TemplateSession
from repro.core.grammar import Grammar
from repro.core.scanner import Scanner
from repro.core.speculation import CountModel, Speculator
from repro.core.trees import TreeCache
from repro.models.model import Model
from repro.serving.request import (ConstraintSpec, DecodeParams, Request,
                                   packed_argmax, select_token)
from repro.serving.session import GenerationResult
from repro.tokenizer import BPETokenizer

DEFAULT_GRAMMAR = "default"


@dataclasses.dataclass
class EngineConfig:
    """Legacy engine-wide configuration.

    Kept as a back-compat shim: it no longer freezes anything into the
    engine — it is split into the engine's default ``ConstraintSpec`` +
    ``DecodeParams`` (see :meth:`constraint_spec` / :meth:`decode_params`)
    and applies only to requests submitted as bare strings.
    """
    mode: str = "domino"              # unconstrained|domino|naive|online|template
    k: Optional[int] = None           # DOMINO lookahead (None = ∞)
    opportunistic: bool = False
    speculative: bool = False
    spec_s: int = 8
    spec_threshold: float = 0.5
    temperature: float = 0.0          # 0 = greedy
    max_tokens: int = 128
    seed: int = 0
    # token healing (§3.5): strip the last `heal` prompt tokens and force
    # the stripped text as a generation prefix (bridge tokens across the
    # prompt boundary become available)
    heal: int = 0

    def constraint_spec(self, grammar_ref) -> ConstraintSpec:
        return ConstraintSpec(grammar=grammar_ref, mode=self.mode,
                              k=self.k, opportunistic=self.opportunistic,
                              heal=self.heal)

    def decode_params(self) -> DecodeParams:
        return DecodeParams(temperature=self.temperature,
                            max_tokens=self.max_tokens, seed=self.seed,
                            speculative=self.speculative,
                            spec_s=self.spec_s,
                            spec_threshold=self.spec_threshold)


@dataclasses.dataclass
class _RowPolicy:
    """Selection policy for the single-request path (the scheduler passes
    the Session itself, which exposes the same fields)."""
    temperature: float
    opportunistic: bool
    decode: DecodeParams
    _rng: Optional[np.random.Generator] = None

    @property
    def rng(self) -> np.random.Generator:
        if self._rng is None:
            self._rng = self.decode.make_rng()
        return self._rng


class DeviceTableSet:
    """All certified grammars' :class:`DeviceGrammarTable`\\ s merged into
    ONE device-resident pair of arrays so the scheduler's fused loop can
    gather any row's mask with a single index, whatever grammar the row
    decodes under:

      ``mask_dev``  — ``(total_states, ceil(V/32))`` uint32 packed masks
      ``trans_dev`` — ``(total_states, V)`` int32 token→next-state table

    Per-grammar state ids are offset into the concatenated range
    (``offsets[name]``); ``OFF_FRONTIER`` (negative) edges stay negative
    after offsetting, so a state id ``< 0`` ALWAYS means "host path".
    Host mirrors (``mask_host`` / ``trans_host``) serve the scheduler's
    per-token bookkeeping (transition lookups, opportunistic bit tests)
    without device readbacks.  Built once by
    :meth:`ServingEngine.build_device_tables`; immutable afterwards."""

    def __init__(self, tables: Dict[str, DeviceGrammarTable]):
        names = sorted(tables)
        self.tables = {n: tables[n] for n in names}
        self.offsets: Dict[str, int] = {}
        masks, trans = [], []
        off = 0
        for n in names:
            t = tables[n]
            self.offsets[n] = off
            masks.append(t.mask_table)
            tr = t.trans.astype(np.int32, copy=True)
            tr[tr >= 0] += off          # remap edges into the concat range
            trans.append(tr)
            off += t.n_states
        self.n_states = off
        self.mask_host = np.concatenate(masks, axis=0)
        self.trans_host = np.concatenate(trans, axis=0)
        self.mask_dev = jnp.asarray(self.mask_host)
        self.trans_dev = jnp.asarray(self.trans_host)

    @property
    def n_bytes(self) -> int:
        return int(self.mask_host.nbytes + self.trans_host.nbytes)

    def sid_for(self, name: str, checker) -> int:
        """Global state id for ``checker``'s current state under grammar
        ``name``, or ``OFF_FRONTIER`` when the grammar has no table or
        the state is outside the certified frontier."""
        off = self.offsets.get(name)
        if off is None:
            return OFF_FRONTIER
        sid = self.tables[name].sid_for(checker)
        return sid + off if sid >= 0 else OFF_FRONTIER


class ServingEngine:
    def __init__(self, model: Model, params, tok: BPETokenizer,
                 grammar: Optional[Grammar] = None,
                 cfg: Optional[EngineConfig] = None,
                 tree_cache: Optional[TreeCache] = None,
                 count_model: Optional[CountModel] = None,
                 max_len: int = 1024,
                 analysis_policy: str = "off",
                 max_adhoc_grammars: int = 32,
                 device_tables: bool = False):
        self.model = model
        self.params = params
        self.tok = tok
        self.grammar = grammar
        self.cfg = cfg or EngineConfig()
        self.max_len = max_len
        # registration-time static analysis (repro.core.analysis):
        #   off    — skip entirely (default; analysis costs ~seconds per
        #            grammar, opt in for serving deployments)
        #   warn   — run, report problems as a RuntimeWarning, register
        #   strict — run, refuse to register a grammar with any problem
        #            (raises AnalysisError BEFORE the registry commits)
        self.analysis_policy = analysis_policy
        self.analysis_reports: Dict[str, AnalysisReport] = {}
        # device-resident decode tables (ISSUE 8): when enabled,
        # precompute() uploads each CLEANLY-certified grammar's packed
        # mask + transition tables so the scheduler's fused loop can run
        # N tokens per host sync.  Grammars whose certificate is dirty
        # (non-finite closure, merge conflicts, truncations, traps) are
        # silently left on the host path — correctness never depends on
        # certification, only the sync cadence does.
        self.enable_device_tables = device_tables
        self.device_tables: Dict[str, DeviceGrammarTable] = {}
        self._device_table_set: Optional[DeviceTableSet] = None
        # refcounts + ad-hoc bookkeeping so rotating per-request Grammar
        # objects does not leak (TreeCache, mask memo) pairs forever
        self._grammar_refs: Dict[str, int] = {}
        self._adhoc_order: List[str] = []
        self.max_adhoc_grammars = max_adhoc_grammars
        # grammar registry: name -> (Grammar, shared TreeCache).  The
        # cache slot may be None: the legacy constructor registers its
        # grammar lazily when the default mode never consults trees, so
        # an unconstrained/template engine does no tree work (old
        # behavior) while per-request specs can still name "default"
        self.registry: Dict[str, Tuple[Grammar, Optional[TreeCache]]] = {}
        if grammar is not None:
            if (cfg or EngineConfig()).mode in ("domino", "naive",
                                                "online"):
                self.register_grammar(DEFAULT_GRAMMAR, grammar,
                                      tree_cache=tree_cache)
            else:
                self.registry[DEFAULT_GRAMMAR] = (grammar, None)
        # engine defaults: what a bare-string submission decodes with
        self.default_constraint = self.cfg.constraint_spec(
            DEFAULT_GRAMMAR if grammar is not None else None)
        self.default_decode = self.cfg.decode_params()
        # back-compat attribute: the default grammar's shared cache (only
        # when the default mode actually consumes trees, as before)
        if grammar is not None and self.cfg.mode in ("domino", "naive",
                                                     "online"):
            self.tree_cache = self.registry[DEFAULT_GRAMMAR][1]
        else:
            self.tree_cache = None
        # speculation: ONE count model engine-wide (priors transfer across
        # requests); Speculator instances are pooled per (s, threshold) so
        # identical knobs share the proposal-chain memo
        self.count_model = count_model or CountModel()
        self._speculators: Dict[Tuple[int, float], Speculator] = {}
        self.speculator = self._speculator_for(self.default_decode)
        # engine-default prompts (system preambles / few-shot headers)
        # registered via pin_prompt(): a prefix-cache-enabled scheduler
        # prefills and PINS their full KV pages at warm() time, so the
        # very first live request sharing the preamble already hits
        self.pinned_prompts: List[str] = []
        # engine-level rng: used only by the template baseline (which has
        # no Request); request sampling is per-session
        self.rng = np.random.default_rng(self.cfg.seed)
        self._v = tok.vocab_size   # model logits may be vocab-padded
        # jit'd steps (compiled once per (batch, s) shape)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        # rollback safety (DESIGN.md §Arch-applicability)
        blocks = self._all_block_kinds()
        self._needs_refeed = any(
            b in ("mamba1", "mamba2", "swa") for b in blocks)

    def _all_block_kinds(self) -> List[str]:
        head, reps, group, tail = self.model.cfg.layer_program
        return list(head) + list(group) + list(tail)

    # -- grammar registry --------------------------------------------------------

    def register_grammar(self, name: str, grammar: Grammar,
                         tree_cache: Optional[TreeCache] = None,
                         policy: Optional[str] = None) -> TreeCache:
        """Register ``grammar`` under ``name`` with ONE shared TreeCache
        (subterminal trees + packed-mask memo).  Every request whose
        ``ConstraintSpec.grammar == name`` builds its checker against
        this cache — no per-request tree construction.

        Under ``analysis_policy`` (or the per-call ``policy`` override)
        ``warn``/``strict`` the grammar is statically analyzed against
        the engine's vocabulary FIRST — a strict failure raises
        :class:`~repro.core.analysis.AnalysisError` and registers
        nothing.  The report lands in ``self.analysis_reports[name]``.

        Re-registering a name with the SAME grammar object is a no-op
        that bumps its refcount (see :meth:`unregister_grammar`); a
        different grammar replaces the entry.  Returns the cache."""
        prev = self.registry.get(name)
        if prev is not None and prev[0] is grammar and prev[1] is not None:
            self._grammar_refs[name] = self._grammar_refs.get(name, 0) + 1
            return prev[1]
        tc = tree_cache if tree_cache is not None else TreeCache(
            Scanner(grammar), list(self.tok.vocab))
        pol = policy if policy is not None else self.analysis_policy
        if pol != "off":
            report = analyze(grammar, list(self.tok.vocab),
                             self.tok.eos_id, name=name, tree_cache=tc)
            enforce(report, pol)       # strict: raises before committing
            self.analysis_reports[name] = report
        self.registry[name] = (grammar, tc)
        self._grammar_refs[name] = self._grammar_refs.get(name, 0) + 1
        return tc

    def unregister_grammar(self, name: str) -> bool:
        """Drop one reference to ``name``; when the count reaches zero the
        registry entry — (Grammar, TreeCache) pair, mask memo and
        analysis report — is released.  Engines that rotate through
        ad-hoc grammars must pair each ``register_grammar`` with one
        ``unregister_grammar`` or rely on the ad-hoc LRU cap.  Returns
        True when the entry was fully removed."""
        if name not in self.registry:
            raise KeyError(f"grammar {name!r} is not registered")
        n = self._grammar_refs.get(name, 1) - 1
        if n > 0:
            self._grammar_refs[name] = n
            return False
        self.registry.pop(name, None)
        self._grammar_refs.pop(name, None)
        self.analysis_reports.pop(name, None)
        if name in self._adhoc_order:
            self._adhoc_order.remove(name)
        if self.tree_cache is not None and name == DEFAULT_GRAMMAR:
            self.tree_cache = None
        return True

    def resolve_grammar(self, ref) -> Tuple[Optional[Grammar],
                                            Optional[TreeCache]]:
        """Resolve a ConstraintSpec grammar reference to (grammar,
        shared TreeCache).  Accepts a registered name, a Grammar object
        (auto-registered keyed by identity so repeats share the cache),
        or None.  Auto-registered ad-hoc grammars live in a bounded LRU
        (``max_adhoc_grammars``): once it is full the oldest entry whose
        refcount is 1 (i.e. held only by the auto-registration itself)
        is evicted, so per-request throwaway grammars cannot leak
        (TreeCache, memo) pairs without bound."""
        if ref is None:
            return None, None
        if isinstance(ref, str):
            entry = self.registry.get(ref)
            if entry is None:
                raise KeyError(
                    f"grammar {ref!r} is not registered (have: "
                    f"{sorted(self.registry)}); call "
                    f"engine.register_grammar({ref!r}, grammar) first")
            if entry[1] is None:       # lazily-registered: build now
                self._grammar_refs.pop(ref, None)  # re-count the rebuild
                return entry[0], self.register_grammar(ref, entry[0])
            return entry
        # Grammar object: reuse an existing registration, else auto-add
        for name, (g, tc) in self.registry.items():
            if g is ref:
                if tc is None:
                    self._grammar_refs.pop(name, None)
                    return g, self.register_grammar(name, g)
                if name in self._adhoc_order:      # LRU touch
                    self._adhoc_order.remove(name)
                    self._adhoc_order.append(name)
                return g, tc
        name = f"grammar@{id(ref):x}"
        self.register_grammar(name, ref)
        self._adhoc_order.append(name)
        while len(self._adhoc_order) > self.max_adhoc_grammars:
            victim = next((n for n in self._adhoc_order
                           if self._grammar_refs.get(n, 1) <= 1), None)
            if victim is None:         # every ad-hoc entry is pinned
                break
            self._adhoc_order.remove(victim)
            self.registry.pop(victim, None)
            self._grammar_refs.pop(victim, None)
            self.analysis_reports.pop(victim, None)
        return self.registry[name]

    def analyze_grammar(self, name: str, policy: Optional[str] = None,
                        **kwargs) -> AnalysisReport:
        """(Re-)run static analysis for a registered grammar against the
        engine's vocabulary, on the registry's SHARED TreeCache (so the
        trees it builds are the trees serving will use).  ``kwargs`` pass
        through to :func:`repro.core.analysis.analyze` (clamp,
        max_states, ...).  The report is stored and policy-enforced."""
        grammar, tc = self.resolve_grammar(name)
        report = analyze(grammar, list(self.tok.vocab), self.tok.eos_id,
                         name=name, tree_cache=tc, **kwargs)
        self.analysis_reports[name] = report
        enforce(report, policy if policy is not None
                else self.analysis_policy)
        return report

    def precompute(self) -> Dict[str, float]:
        """Offline warm path: build every reachable subterminal tree for
        EVERY registered grammar now (paper Algorithm 2) so serving never
        constructs trees on the critical path.  Each per-grammar
        TreeCache is shared across all of that grammar's sessions.

        Under ``analysis_policy != "off"`` this is also the analysis
        sweep: any registered grammar without a stored report is analyzed
        (and the policy enforced) here — reports in
        ``self.analysis_reports``, aggregate cost in the returned
        ``analysis_seconds``."""
        out = {"positions": 0.0, "seconds": 0.0, "analysis_seconds": 0.0}
        for name, (grammar, tc) in list(self.registry.items()):
            if tc is None:             # lazily registered, never resolved
                continue
            if self.analysis_policy != "off" \
                    and name not in self.analysis_reports:
                report = analyze(grammar, list(self.tok.vocab),
                                 self.tok.eos_id, name=name,
                                 tree_cache=tc)
                self.analysis_reports[name] = report
                out["analysis_seconds"] += report.analysis_time_s
                enforce(report, self.analysis_policy)
            stats = tc.precompute()
            out["positions"] += stats["positions"]
            out["seconds"] += stats["seconds"]
        if self.enable_device_tables:
            out["device_table_seconds"] = self.build_device_tables()
        return out

    def pin_prompt(self, prompt: str) -> None:
        """Register an engine-default prompt (shared system preamble /
        few-shot header) for prefix pinning: a prefix-cache-enabled
        scheduler's ``warm()`` prefills its whole-page prefix once and
        pins the pages against eviction.  A no-op for schedulers without
        ``prefix_cache=True``."""
        if prompt not in self.pinned_prompts:
            self.pinned_prompts.append(prompt)

    def build_device_tables(self) -> float:
        """Build + upload a :class:`DeviceGrammarTable` for every
        registered grammar whose closure certificate is CLEAN (finite,
        zero merge conflicts, zero hypothesis truncations, zero traps,
        and an overall-``ok()`` report); dirty grammars stay host-only.

        A grammar with a STORED report is judged by that report — never
        re-analyzed behind its back — so a certificate that was
        downgraded (e.g. by a stricter re-analysis, or a test doctoring
        conflicts in) durably excludes the grammar from the device path.
        Grammars without a stored report are analyzed here with
        ``emit_device_table=True`` on their shared TreeCache.  Returns
        the seconds spent analyzing."""
        spent = 0.0
        for name, (grammar, tc) in list(self.registry.items()):
            if tc is None or name in self.device_tables:
                continue
            report = self.analysis_reports.get(name)
            if report is None:
                report = analyze(grammar, list(self.tok.vocab),
                                 self.tok.eos_id, name=name,
                                 tree_cache=tc, emit_device_table=True)
                self.analysis_reports[name] = report
                spent += report.analysis_time_s
            elif report.device_table is None:
                # stored report: trust its certificate.  Dirty -> skip
                # WITHOUT re-analysis (the downgrade stands); clean but
                # table-less (analyzed without emit) -> re-run with emit.
                if (not report.closure.finite or report.n_mask_conflicts
                        or report.n_hyp_truncations or not report.ok()):
                    continue
                report = analyze(grammar, list(self.tok.vocab),
                                 self.tok.eos_id, name=name,
                                 tree_cache=tc, emit_device_table=True)
                self.analysis_reports[name] = report
                spent += report.analysis_time_s
            if report.device_table is not None and report.ok():
                self.device_tables[name] = report.device_table
                tc.device_table = report.device_table
                self._device_table_set = None      # rebuild lazily
        return spent

    @property
    def device_table_set(self) -> Optional[DeviceTableSet]:
        """The merged device upload over every certified grammar (None
        until :meth:`build_device_tables` certifies at least one)."""
        if self._device_table_set is None and self.device_tables:
            self._device_table_set = DeviceTableSet(self.device_tables)
        return self._device_table_set

    # -- request / checker factory -----------------------------------------------

    def make_request(self, prompt: str,
                     constraint: Optional[ConstraintSpec] = None,
                     decode: Optional[DecodeParams] = None,
                     extra_inputs: Optional[Dict[str, Any]] = None
                     ) -> Request:
        """Default-``Request`` factory: unspecified parts come from the
        legacy engine-level ``EngineConfig`` / constructor grammar, which
        is how bare-string submissions keep their exact old behavior."""
        return Request(prompt=prompt,
                       constraint=constraint or self.default_constraint,
                       decode=decode or self.default_decode,
                       extra_inputs=extra_inputs)

    def _coerce(self, request: Union[str, Request]) -> Request:
        return (self.make_request(request) if isinstance(request, str)
                else request)

    def _eos_for(self, spec: ConstraintSpec) -> int:
        return spec.eos_id if spec.eos_id is not None else self.tok.eos_id

    def _checker_from_spec(self, spec: ConstraintSpec,
                           heal_prefix: str = ""):
        grammar = tc = None
        if spec.grammar is not None and spec.mode != "unconstrained":
            grammar, tc = self.resolve_grammar(spec.grammar)
        return spec.make_checker(grammar, list(self.tok.vocab),
                                 self._eos_for(spec), tree_cache=tc,
                                 heal_prefix=heal_prefix)

    def _make_checker(self, heal_prefix: str = ""):
        """Checker factory for the engine-DEFAULT constraint (kept as a
        seam: tests monkeypatch it to inject checker stubs into both the
        single-request and the scheduler path)."""
        return self._checker_from_spec(self.default_constraint,
                                       heal_prefix)

    def _prep(self, req: Request):
        """Shared request preamble: encode, apply token healing (§3.5),
        build the checker from the grammar registry.  ``generate`` and
        the scheduler's ``submit`` both go through here so their outputs
        stay token-for-token identical."""
        spec = req.constraint
        prompt_ids = self.tok.encode(req.prompt) or [self.tok.bos_id]
        prompt_ids, heal_prefix = spec.prep_prompt(prompt_ids,
                                                   self.tok.vocab)
        if spec is self.default_constraint:
            checker = self._make_checker(heal_prefix)
        else:
            checker = self._checker_from_spec(spec, heal_prefix)
        return prompt_ids, checker

    def _prep_request(self, prompt: str):
        """Back-compat alias: prep the engine-default request."""
        return self._prep(self.make_request(prompt))

    def make_session(self, rid: int, request: Union[str, Request],
                     extra_inputs=None):
        """Create a scheduler :class:`~repro.serving.session.Session`
        carrying the request's full per-row decode policy (used by
        ``ContinuousBatchingScheduler.submit``)."""
        from repro.serving.session import Session
        req = self._coerce(request)
        prompt_ids, checker = self._prep(req)
        dp = req.decode
        # request-level side inputs first, call-level overrides on top
        merged = dict(req.extra_inputs or {})
        merged.update(extra_inputs or {})
        return Session(rid=rid, prompt=req.prompt, prompt_ids=prompt_ids,
                       checker=checker, budget=dp.max_tokens,
                       eos_id=self._eos_for(req.constraint), decode=dp,
                       opportunistic=req.constraint.opportunistic,
                       speculator=self._speculator_for(dp), request=req,
                       extra_inputs=merged or None)

    def _speculator_for(self, dp: DecodeParams) -> Optional[Speculator]:
        # speculation is greedy-verified: at temperature>0 proposals
        # almost never match the sampled pick (no forward savings), and
        # every mismatched verify position would burn a per-request RNG
        # draw whose count depends on the SHARED count model's state —
        # breaking the guarantee that a sampled request's output is
        # independent of batch composition.  Sampled rows decode plain.
        if not dp.speculative or dp.temperature > 0.0:
            return None
        key = (dp.spec_s, dp.spec_threshold)
        sp = self._speculators.get(key)
        if sp is None:
            sp = Speculator(self.count_model, s=dp.spec_s,
                            threshold=dp.spec_threshold)
            self._speculators[key] = sp
        return sp

    # -- sampling -----------------------------------------------------------------

    def _default_policy(self) -> _RowPolicy:
        pol = _RowPolicy(temperature=self.cfg.temperature,
                         opportunistic=self.cfg.opportunistic,
                         decode=self.default_decode)
        pol._rng = self.rng            # template/legacy path: engine rng
        return pol

    def _select(self, logits: np.ndarray, mask: Optional[np.ndarray],
                policy=None) -> int:
        pol = policy or self._default_policy()
        return select_token(logits, mask, pol.temperature,
                            pol.rng if pol.temperature > 0.0 else None)

    def _pick(self, logits: np.ndarray, checker, premask=None,
              policy=None) -> Tuple[Optional[int], int, float]:
        """Select the next token under the row's constraint + decode
        policy (``policy``: a Session or _RowPolicy; None = engine
        defaults).

        Returns (token, intervened?, mask_seconds).  ``token`` is None
        when the checker reached a dead end (no legal token, EOS
        included) — callers surface this as ``GenerationResult.dead_end``
        instead of silently emitting grammar-violating output.
        ``premask`` is a mask the caller already built from the checker's
        current state (e.g. the scheduler's host/device-overlapped
        prebuild); its build time was accounted at build site, so it does
        not count here.  Packed uint32 masks (the pipeline's native row
        format) stay packed on the greedy branch — bit test on the
        candidate + legal-id argmax — and are unpacked to bool only for
        temperature>0 sampling.
        """
        pol = policy or self._default_policy()
        if checker is None:
            return self._select(logits, None, pol), 0, 0.0
        mask_t = 0.0
        greedy = pol.temperature <= 0.0
        if pol.opportunistic and greedy:
            cand = int(logits.argmax())
            t0 = time.perf_counter()
            ok = checker.check_token(cand)
            mask_t += time.perf_counter() - t0
            if ok:
                return cand, 0, mask_t
        bits = mask = None
        if premask is not None:
            if premask.dtype == np.uint32:
                bits = premask
            else:
                mask = premask                 # bool premask (stub checkers)
        elif greedy and hasattr(checker, "mask_bits"):
            t0 = time.perf_counter()
            bits = checker.mask_bits()
            mask_t += time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            mask = checker.mask()
            mask_t += time.perf_counter() - t0
        if bits is not None:
            if greedy:
                raw = int(logits.argmax())
                if bitmask.get_bit(bits, raw):
                    return raw, 0, mask_t      # legal argmax: no unpack
                tok = packed_argmax(logits, bits, self._v)
                if tok is None:
                    # the checker invariant makes this unreachable for
                    # sound grammars; report it rather than force EOS
                    return None, 0, mask_t
                return tok, 1, mask_t          # raw argmax was illegal
            # temperature>0 host sampling is the one place bits may
            # widen; greedy/verify paths above stay packed
            mask = bitmask.unpack(bits, self._v)  # hotpath-lint: allow
        if not mask.any():
            return None, 0, mask_t
        tok = self._select(logits, mask, pol)
        intervened = int(tok != int(logits.argmax()))
        return tok, intervened, mask_t

    # -- generation -----------------------------------------------------------------

    def generate(self, request: Union[str, Request],
                 extra_inputs: Optional[Dict[str, Any]] = None
                 ) -> GenerationResult:
        """Serve one request on the single-request fast path.  ``request``
        is a :class:`Request` or a bare prompt string (= the engine's
        default request)."""
        t_start = time.perf_counter()
        req = self._coerce(request)
        dp = req.decode
        eos_id = self._eos_for(req.constraint)
        policy = _RowPolicy(temperature=dp.temperature,
                            opportunistic=req.constraint.opportunistic,
                            decode=dp)
        speculator = self._speculator_for(dp)
        prompt_ids, checker = self._prep(req)
        cache = self.model.init_cache(1, self.max_len)
        inputs = {"tokens": jnp.asarray([prompt_ids], jnp.int32)}
        # request-level side inputs first, call-level overrides on top
        inputs.update(req.extra_inputs or {})
        inputs.update(extra_inputs or {})

        model_t = 0.0
        mask_t = 0.0
        n_fwd = 0
        n_int = 0
        n_prop = 0
        n_acc = 0
        out_ids: List[int] = []

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, inputs, cache)
        logits = np.asarray(logits)[0, -1][:self._v]
        model_t += time.perf_counter() - t0
        n_fwd += 1

        finished = False
        dead_end = False
        status: Optional[str] = None       # non-ok terminal override
        error: Optional[str] = None
        budget = dp.max_tokens
        while budget > 0 and not finished and not dead_end \
                and status is None:
            # fault edges shared with the scheduler path: a request-level
            # deadline bounds wall time, and non-finite logits terminate
            # with an explicit status instead of committing garbage
            if dp.deadline_s is not None \
                    and time.perf_counter() - t_start > dp.deadline_s:
                status = "deadline_exceeded"
                error = f"deadline {dp.deadline_s:g}s exceeded"
                break
            if not np.all(np.isfinite(logits)):
                status = "internal_error"
                error = "non-finite logits from device step"
                break
            # ---- try speculative fast path -------------------------------------
            if (speculator is not None and checker is not None
                    and hasattr(checker, "clone")):
                tok0, intervened, dt = self._pick(logits, checker,
                                                  policy=policy)
                mask_t += dt
                if tok0 is None:
                    dead_end = True
                    break
                n_int += intervened
                if tok0 == eos_id:
                    finished = True
                    checker.advance(tok0)
                    break
                speculator.observe(checker.state_key(), tok0)
                checker.advance(tok0)
                out_ids.append(tok0)
                budget -= 1
                proposals = speculator.propose(checker)
                n_prop += len(proposals)
                feed = [tok0] + proposals
                # static verify width (spec_s + 1): TPU-friendly single
                # compiled program; pad positions are rolled back below
                n_pad = (1 + dp.spec_s) - len(feed)
                feed_p = feed + [self.tok.pad_id] * n_pad
                cache_before = cache
                t0 = time.perf_counter()
                lg_multi, cache = self._decode(
                    self.params, cache, jnp.asarray([feed_p], jnp.int32))
                lg_multi = np.asarray(lg_multi)[0][:, :self._v]
                model_t += time.perf_counter() - t0
                n_fwd += 1
                # verify proposals against (masked) argmax at each position
                accepted = 0
                ch = checker
                for i, prop in enumerate(proposals):
                    if budget <= 0:
                        break
                    if not np.all(np.isfinite(lg_multi[i])):
                        status = "internal_error"
                        error = ("non-finite logits in speculative "
                                 "verify window")
                        break
                    # fast verification: if the raw argmax equals the
                    # proposal, an O(token) opportunistic legality check
                    # replaces the full tree-walk mask
                    tok_i = None
                    if dp.temperature <= 0.0 \
                            and int(lg_multi[i].argmax()) == prop:
                        t0 = time.perf_counter()
                        ok = ch.check_token(prop)
                        mask_t += time.perf_counter() - t0
                        if ok:
                            tok_i = prop
                    if tok_i is None:
                        tok_i, intervened, dt = self._pick(lg_multi[i], ch,
                                                           policy=policy)
                        mask_t += dt
                        if tok_i is None:
                            dead_end = True
                            break
                        n_int += intervened
                    if tok_i != prop:
                        break
                    speculator.observe(ch.state_key(), tok_i)
                    ch.advance(tok_i)
                    accepted += 1
                    if tok_i == eos_id:
                        finished = True
                        break
                    out_ids.append(tok_i)
                    budget -= 1
                n_acc += accepted
                rejected = len(proposals) - accepted
                if rejected > 0 or n_pad > 0:
                    if self._needs_refeed:
                        # recompute from the pre-speculation cache (exact
                        # length: recurrent/ring state cannot host pads)
                        t0 = time.perf_counter()
                        lg_re, cache = self._decode(
                            self.params, cache_before,
                            jnp.asarray([feed[:1 + accepted]], jnp.int32))
                        logits = np.asarray(lg_re)[0, -1][:self._v]
                        model_t += time.perf_counter() - t0
                        n_fwd += 1
                    else:
                        cache = self.model.rollback(cache,
                                                    rejected + n_pad)
                        logits = lg_multi[accepted]
                else:
                    logits = lg_multi[len(proposals)]
                continue

            # ---- plain path ------------------------------------------------------
            tok, intervened, dt = self._pick(logits, checker, policy=policy)
            mask_t += dt
            if tok is None:
                dead_end = True
                break
            n_int += intervened
            if checker is not None:
                checker.advance(tok)
            if tok == eos_id:
                finished = True
                break
            out_ids.append(tok)
            budget -= 1
            t0 = time.perf_counter()
            lg, cache = self._decode(self.params, cache,
                                     jnp.asarray([[tok]], jnp.int32))
            logits = np.asarray(lg)[0, -1][:self._v]
            model_t += time.perf_counter() - t0
            n_fwd += 1

        return GenerationResult(
            status=status or ("dead_end" if dead_end else "ok"),
            error=error,
            text=self.tok.decode(out_ids),
            token_ids=out_ids,
            n_forward_passes=n_fwd,
            n_tokens=len(out_ids),
            n_interventions=n_int,
            n_spec_proposed=n_prop,
            n_spec_accepted=n_acc,
            mask_time_s=mask_t,
            model_time_s=model_t,
            wall_time_s=time.perf_counter() - t_start,
            finished=finished,
            dead_end=dead_end,
            mask_cache_hits=getattr(checker, "n_mask_memo_hits", 0),
            n_hyp_truncations=getattr(checker, "n_hyp_truncations", 0),
            max_hyp_fanout=getattr(checker, "max_hyp_fanout", 1),
        )

    # -- batched serving -------------------------------------------------------------

    def generate_batch(self, requests: List[Union[str, Request]],
                       max_batch: Optional[int] = None,
                       paged: Optional[bool] = None,
                       page_size: Optional[int] = None,
                       n_pages: Optional[int] = None,
                       queue_limit: Optional[int] = None,
                       queue_timeout_s: Optional[float] = None,
                       default_deadline_s: Optional[float] = None,
                       fault_injector=None,
                       debug_invariants: bool = False,
                       device_loop: bool = False,
                       sync_n: int = 8,
                       journal=None,
                       supervisor=None,
                       prefix_cache: bool = False
                       ) -> List[GenerationResult]:
        """Serve ``requests`` (Requests or bare prompt strings) through
        the continuous-batching scheduler.  Rows may mix grammars,
        constraint modes, EOS ids, budgets and sampling policies freely —
        each row decodes under its own ``ConstraintSpec``/``DecodeParams``.

        ``max_batch`` caps the decode batch (slots); extra requests wait
        in the admission queue and reuse slots as earlier requests
        finish.  All architectures are supported: recurrent/ring rows are
        admitted by exact-length prefill and speculation uses per-row
        refeed.  On pure full-attention/MLA stacks the KV cache is paged
        by default (``paged``/``page_size``/``n_pages`` size the pool; an
        undersized pool exerts admission backpressure instead of OOM).
        Call :meth:`precompute` first to keep tree construction off the
        serving critical path.

        Fault-tolerance knobs pass straight through to the scheduler:
        ``queue_limit`` / ``queue_timeout_s`` bound the waiting queue,
        ``default_deadline_s`` bounds wall time for requests that carry
        no ``DecodeParams.deadline_s``, ``fault_injector`` wires a
        :class:`~repro.serving.faults.FaultInjector`, and
        ``debug_invariants`` audits every tick boundary.  Every request
        gets a result regardless — non-ok outcomes carry an explicit
        ``status`` / ``error``.

        ``device_loop=True`` enables the device-resident fused decode
        loop for rows whose grammar carries a clean device table (build
        them first: ``ServingEngine(..., device_tables=True)`` +
        :meth:`precompute`); ``sync_n`` is the number of decode steps
        fused per host sync.  Rows without a certified table decode on
        the host path, token-for-token identical to ``device_loop=False``.

        ``journal`` wires a
        :class:`~repro.serving.journal.TokenJournal` (crash-consistent
        WAL — see :meth:`restore`); ``supervisor`` a
        :class:`~repro.serving.supervisor.DegradationSupervisor`
        (watchdogs + the fused->host->dense degradation ladder).

        ``prefix_cache=True`` (paged only) shares whole KV pages across
        requests with identical token prefixes through a radix tree with
        copy-on-write refcounting — admissions skip prefill for the
        cached prefix and re-prefill only the tail (observationally
        pure: outputs are bitwise-identical to a cold cache).
        """
        from repro.serving.scheduler import ContinuousBatchingScheduler
        cap = min(len(requests), max_batch) if max_batch else len(requests)
        kwargs = {}
        if paged is not None:
            kwargs["paged"] = paged
        if page_size is not None:
            kwargs["page_size"] = page_size
        if n_pages is not None:
            kwargs["n_pages"] = n_pages
        sched = ContinuousBatchingScheduler(
            self, capacity=cap, queue_limit=queue_limit,
            queue_timeout_s=queue_timeout_s,
            default_deadline_s=default_deadline_s,
            fault_injector=fault_injector,
            debug_invariants=debug_invariants,
            device_loop=device_loop, sync_n=sync_n,
            journal=journal, supervisor=supervisor,
            prefix_cache=prefix_cache, **kwargs)
        if prefix_cache:
            # install engine-default pinned prompts before admission
            # (precompute() is the caller's job and may already be done)
            sched._pin_prompts()
        sessions = [sched.submit(r) for r in requests]
        sched.run()
        return [s.result for s in sessions]

    def restore(self, journal_path: str, max_batch: Optional[int] = None,
                journal=None, **scheduler_kwargs):
        """Cold-restart recovery: replay the crash journal at
        ``journal_path`` and return a scheduler pre-loaded with every
        journaled request — terminal requests carry their journaled
        result, live requests are reconstructed (prompt + validated
        committed prefix replayed through a fresh concrete checker, RNG
        stream restored) and queued for re-prefill through the
        recompute-preemption machinery.  Call ``run()`` (or ``step()``)
        on the returned scheduler to finish them; greedy rows complete
        bitwise-identical to an uninterrupted run.

        Pass ``journal`` (typically ``TokenJournal(journal_path)``, which
        truncates any torn tail) to keep the resumed run durable in the
        SAME file — replayed state is journaled idempotently, so repeated
        crash/restore cycles converge instead of compounding."""
        from repro.serving.journal import replay_journal
        from repro.serving.scheduler import ContinuousBatchingScheduler
        entries = replay_journal(journal_path)
        cap = scheduler_kwargs.pop("capacity", None)
        if cap is None:
            live = sum(1 for e in entries.values()
                       if e.terminal is None and e.recoverable)
            cap = max(1, min(live, max_batch) if max_batch else live)
        sched = ContinuousBatchingScheduler(
            self, capacity=cap, journal=journal, **scheduler_kwargs)
        for entry in entries.values():
            sched.adopt(entry)
        return sched

    # -- template mode ------------------------------------------------------------

    def generate_template(self, prompt: str, parts) -> GenerationResult:
        """GUIDANCE-style template execution (baseline for Fig. 2/Table 2)."""
        t_start = time.perf_counter()
        session = TemplateSession(parts, list(self.tok.vocab),
                                  self.tok.eos_id, self.tok.encode_greedy)
        prompt_ids = self.tok.encode(prompt) or [self.tok.bos_id]
        cache = self.model.init_cache(1, self.max_len)
        t0 = time.perf_counter()
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray([prompt_ids], jnp.int32)},
            cache)
        logits = np.asarray(logits)[0, -1][:self._v]
        model_t = time.perf_counter() - t0
        n_fwd = 1
        out_ids: List[int] = []
        budget = self.cfg.max_tokens
        while budget > 0:
            action, payload = session.next_action()
            if action == "done":
                break
            if action == "force":
                if not payload:
                    continue
                out_ids.extend(payload)
                budget -= len(payload)
                t0 = time.perf_counter()
                lg, cache = self._decode(
                    self.params, cache, jnp.asarray([payload], jnp.int32))
                logits = np.asarray(lg)[0, -1][:self._v]
                model_t += time.perf_counter() - t0
                n_fwd += 1
                continue
            # gen under slot mask
            tok = self._select(logits, payload)
            session.feed(tok)
            if tok == self.tok.eos_id:
                continue  # slot ended; do not emit eos into output
            out_ids.append(tok)
            budget -= 1
            t0 = time.perf_counter()
            lg, cache = self._decode(self.params, cache,
                                     jnp.asarray([[tok]], jnp.int32))
            logits = np.asarray(lg)[0, -1][:self._v]
            model_t += time.perf_counter() - t0
            n_fwd += 1
        return GenerationResult(
            text=self.tok.decode(out_ids), token_ids=out_ids,
            n_forward_passes=n_fwd, n_tokens=len(out_ids),
            n_interventions=session.forced_tokens,
            n_spec_proposed=0, n_spec_accepted=0,
            mask_time_s=0.0, model_time_s=model_t,
            wall_time_s=time.perf_counter() - t_start, finished=True)
