"""Crash-consistent write-ahead token journal for the serving scheduler.

The durability contract (ISSUE 9 tentpole): a process crash — SIGKILL at
ANY byte of the file, a torn write, a lost page-cache tail — never loses
an acknowledged commit and never resurrects an unacknowledged one.  On
restart, :func:`replay_journal` reconstructs every request's lifecycle
(prompt, constraint, decode policy, committed-token prefix, sampling-RNG
state, terminal status) and ``ServingEngine.restore`` re-prefills the
non-terminal ones through the scheduler's recompute-preemption machinery,
so a greedy request's post-restore output is bitwise-identical to an
uninterrupted run (a sampled request resumes its exact RNG stream).

File format
-----------

``MAGIC`` (6 bytes), then length-prefixed CRC-framed records::

    [u32 LE payload length][u32 LE crc32(payload)][payload: UTF-8 JSON]

A record is durable only once fsynced.  Opening an existing journal
scans from the front and TRUNCATES at the first frame that is short,
overlong, or fails its CRC — a torn tail (crash mid-write, lost cache
pages) silently disappears instead of poisoning replay.  Truncation can
only drop suffixes, so every record that was acknowledged (fsynced
before the crash) survives, and no partial record is ever parsed.

Record kinds (``payload["kind"]``):

    submit    rid, prompt, constraint (ConstraintSpec fields or null),
              decode (DecodeParams fields), recoverable, reason
    admit     rid, slot           (informational: admission trace)
    preempt   rid                 (informational: recompute preemption)
    demote    rid, reason         (device-table row left the fused path)
    commit    rid, off, toks, n_draws[, rng]   — checker-VALIDATED tokens
              only; ``off`` is the number of previously-journaled tokens,
              which makes replay idempotent under duplicated deltas
    terminal  rid, status, error, finished, dead_end

Hot-path discipline: :meth:`TokenJournal.append` only buffers; all file
I/O (write + batched fsync, ``sync_every`` ticks per fsync) happens in
:meth:`TokenJournal.commit_tick`, which the scheduler calls ONCE per tick
boundary — ``tools/lint_hotpath.py`` rule R5 forbids fsync/flush calls
inside the per-token tick functions.  Terminal records force a sync at
the next tick so acknowledged results are always durable.

Fault hooks: the ``journal_torn_write`` injector site simulates a torn
write (half a frame reaches the file, the journal goes dead);
``crash_point`` fires :attr:`crash_hook` (default: SIGKILL our own
process) immediately before or after an fsync; ``crash_after_syncs``
deterministically crashes after the N-th fsync — the CI restart smoke
uses it to die between fused blocks.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import struct
import zlib
from typing import Any, Dict, List, Optional, Tuple

MAGIC = b"DOMJ1\n"
_HDR = struct.Struct("<II")            # payload length, crc32(payload)
#: refuse to parse absurd frames (a corrupt length would otherwise make
#: the scanner swallow the rest of the file as one "record")
MAX_RECORD = 16 * 1024 * 1024


class JournalError(RuntimeError):
    """The file is not a journal (bad magic) or cannot be opened."""


def _encode(payload: Dict[str, Any]) -> bytes:
    body = json.dumps(payload, separators=(",", ":"),
                      sort_keys=True).encode("utf-8")
    return _HDR.pack(len(body), zlib.crc32(body)) + body


def scan_records(path: str) -> Tuple[List[Dict[str, Any]], int]:
    """Read every intact record; returns ``(records, valid_end)`` where
    ``valid_end`` is the byte offset after the last frame that parsed —
    anything beyond it is a torn tail (or garbage) to truncate."""
    with open(path, "rb") as fh:
        blob = fh.read()
    if blob[:len(MAGIC)] != MAGIC:
        raise JournalError(f"{path}: bad journal magic "
                           f"{blob[:len(MAGIC)]!r}")
    records: List[Dict[str, Any]] = []
    off = len(MAGIC)
    while off + _HDR.size <= len(blob):
        length, crc = _HDR.unpack_from(blob, off)
        start, end = off + _HDR.size, off + _HDR.size + length
        if length > MAX_RECORD or end > len(blob):
            break                       # torn / corrupt length
        body = blob[start:end]
        if zlib.crc32(body) != crc:
            break                       # torn / corrupt payload
        try:
            records.append(json.loads(body.decode("utf-8")))
        except ValueError:
            break                       # CRC collision on garbage: stop
        off = end
    return records, off


def read_records(path: str) -> List[Dict[str, Any]]:
    """Every intact record in write order (torn tail ignored)."""
    return scan_records(path)[0]


def _default_crash() -> None:
    os.kill(os.getpid(), signal.SIGKILL)


class TokenJournal:
    """Append-only crash-consistent journal (see module docstring).

    ``sync_every`` batches fsyncs: one fsync per N ``commit_tick`` calls
    (terminal records force one at the next tick regardless).  A smaller
    value narrows the window of re-decoded (never wrong, merely
    re-computed) tokens after a crash; it never risks correctness —
    unsynced commits are simply regenerated bitwise-identically.
    """

    def __init__(self, path: str, sync_every: int = 1,
                 injector=None, crash_after_syncs: Optional[int] = None,
                 crash_hook=None):
        self.path = path
        self.sync_every = max(1, int(sync_every))
        self.injector = injector
        self.crash_after_syncs = crash_after_syncs
        self.crash_hook = crash_hook or _default_crash
        self.n_syncs = 0
        self.n_records = 0
        self.dead = False              # a torn write poisons the handle
        self._pending: List[bytes] = []
        self._force_sync = False
        self._ticks_since_sync = 0
        if os.path.exists(path) and os.path.getsize(path) > 0:
            _, valid_end = scan_records(path)
            with open(path, "r+b") as fh:
                fh.truncate(valid_end)  # drop the torn tail, if any
            self._fh = open(path, "ab")
        else:
            self._fh = open(path, "wb")
            self._fh.write(MAGIC)
            self._fh.flush()
            os.fsync(self._fh.fileno())

    # -- hot-path side: buffer only ------------------------------------------

    def append(self, payload: Dict[str, Any]) -> None:
        """Buffer one record.  NO file I/O happens here — the scheduler
        may call this from any tick phase; bytes reach the OS only at
        the next :meth:`commit_tick`."""
        if self.dead:
            return
        self._pending.append(_encode(payload))
        if payload.get("kind") == "terminal":
            self._force_sync = True

    # -- tick-boundary side: batched write + fsync ---------------------------

    def commit_tick(self) -> None:
        """Write buffered records and fsync if one is due (every
        ``sync_every`` ticks, or immediately after a terminal record).
        Called once per scheduler tick, never per token."""
        if self.dead:
            return
        if self._pending:
            if self._fire("journal_torn_write"):
                # simulated torn write: half of the first frame reaches
                # the file, then the "disk" goes away.  The half-frame
                # fails its CRC on reopen, so replay never sees it.
                frame = self._pending[0]
                self._fh.write(frame[:max(1, len(frame) // 2)])
                self._fh.flush()
                self._pending.clear()
                self.dead = True
                return
            self._fh.write(b"".join(self._pending))
            self.n_records += len(self._pending)
            self._pending.clear()
        self._ticks_since_sync += 1
        if self._force_sync or self._ticks_since_sync >= self.sync_every:
            self._do_sync()

    def _do_sync(self) -> None:
        if self._fire("crash_point"):
            self.crash_hook()          # crash BEFORE fsync: tail not
            return                     # durable -> replay regenerates it
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self.n_syncs += 1
        self._ticks_since_sync = 0
        self._force_sync = False
        if self._fire("crash_point"):
            self.crash_hook()          # crash AFTER fsync: tail durable
            return
        if self.crash_after_syncs is not None \
                and self.n_syncs >= self.crash_after_syncs:
            self.crash_hook()

    def _fire(self, site: str) -> bool:
        return self.injector is not None and self.injector.fire(site)

    def close(self) -> None:
        if self._fh.closed:
            return
        if not self.dead and self._pending:
            self._fh.write(b"".join(self._pending))
            self.n_records += len(self._pending)
            self._pending.clear()
        if not self.dead:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self.n_syncs += 1
        self._fh.close()


# -- replay --------------------------------------------------------------------


@dataclasses.dataclass
class JournalEntry:
    """One request's reconstructed lifecycle after replay."""
    rid: int
    prompt: str = ""
    constraint: Optional[Dict[str, Any]] = None   # ConstraintSpec fields
    decode: Optional[Dict[str, Any]] = None       # DecodeParams fields
    toks: List[int] = dataclasses.field(default_factory=list)
    n_draws: int = 0
    rng_state: Optional[Dict[str, Any]] = None
    n_preempts: int = 0
    n_demotes: int = 0
    # prefix-cache adoption observability (admit records): cumulative
    # cached pages block-mapped at this rid's admissions, and whether
    # any admission ran with an adopt()-cloned checker snapshot.
    # Informational only — replay correctness never depends on it
    # (re-admission through the cache and a cold re-prefill are
    # bitwise-identical by prefix determinism)
    n_cached_pages: int = 0
    cached_checker: bool = False
    terminal: Optional[Dict[str, Any]] = None
    recoverable: bool = True
    reason: Optional[str] = None


def replay_journal(path: str) -> Dict[int, JournalEntry]:
    """Fold the journal into per-request entries, rid -> JournalEntry in
    first-submit order.  Commit deltas are applied idempotently via
    their ``off`` field (a duplicated delta — e.g. re-journaled by a
    restored run — contributes nothing new); a GAP (a delta whose ``off``
    exceeds the tokens seen so far, impossible with in-order fsyncs)
    marks the entry unrecoverable rather than guessing."""
    entries: Dict[int, JournalEntry] = {}
    for rec in read_records(path):
        rid = rec.get("rid")
        if rid is None:
            continue
        e = entries.get(rid)
        if e is None:
            e = entries[rid] = JournalEntry(rid=rid)
        kind = rec.get("kind")
        if kind == "submit":
            e.prompt = rec.get("prompt", "")
            e.constraint = rec.get("constraint")
            e.decode = rec.get("decode")
            e.recoverable = bool(rec.get("recoverable", True))
            e.reason = rec.get("reason")
        elif kind == "commit":
            off = int(rec.get("off", len(e.toks)))
            toks = [int(t) for t in rec.get("toks", [])]
            if off > len(e.toks):
                e.recoverable = False
                e.reason = (f"commit gap: delta at offset {off} but only "
                            f"{len(e.toks)} tokens journaled")
                continue
            e.toks.extend(toks[len(e.toks) - off:])
            e.n_draws = int(rec.get("n_draws", e.n_draws))
            if "rng" in rec:
                e.rng_state = rec["rng"]
        elif kind == "admit":
            e.n_cached_pages += int(rec.get("cached_pages", 0))
            e.cached_checker = (e.cached_checker
                                or bool(rec.get("cached_checker", False)))
        elif kind == "preempt":
            e.n_preempts += 1
        elif kind == "demote":
            e.n_demotes += 1
        elif kind == "terminal":
            e.terminal = {"status": rec.get("status"),
                          "error": rec.get("error"),
                          "finished": bool(rec.get("finished", False)),
                          "dead_end": bool(rec.get("dead_end", False))}
    return entries
