"""Deterministic fault injection + tick invariants for the scheduler.

The serving-layer analogue of the paper's "non-invasive" claim is that
one request's failure must not perturb its batch-mates: a row that hits
non-finite logits, a checker exception, or pool exhaustion is quarantined
to its own slot and surfaces an explicit terminal status, while every
surviving row's output stays bitwise-identical to a fault-free run.  That
property cannot be proven by happy-path tests, so this module provides
the two tools the chaos suite drives:

 - :class:`FaultInjector` — a seeded, deterministic fault plan.  The
   scheduler consults it at well-defined injection sites (one per tick
   phase); each consultation draws from the injector's own
   ``np.random.Generator``, so a given (seed, rates, workload) triple
   replays the same storm every run.  Sites:

     ``prefill_nan``      corrupt a just-admitted row's prefill logits
                          (admission phase)
     ``decode_nan``       corrupt one row of the batched decode's logits
                          (device-step phase)
     ``mask_error``       raise :class:`InjectedFault` inside a mask
                          build (selection phase, incl. the overlapped
                          prebuild)
     ``advance_error``    raise :class:`InjectedFault` at a checker
                          advance (commit / speculative-verify phase)
     ``page_exhaustion``  pretend the page pool cannot cover this tick's
                          growth or admission (allocation phase — drives
                          backpressure and recompute preemption, which
                          are output-invariant by design)
     ``mask_delay``       sleep ``delay_s`` inside a mask build (drives
                          deadline enforcement)
     ``device_timeout``   pretend a fused-block dispatch wedged past its
                          watchdog (consulted PRE-dispatch so retry is
                          donation-safe; drives the degradation ladder)
     ``device_error``     simulate an XLA/runtime error surfacing at a
                          device readback (readback / post-block phase)
     ``alloc_fail``       simulate an HBM allocation failure during page
                          growth (drives capacity shrink + preemption)
     ``table_corrupt``    pretend a device-table row audit found a
                          corrupted mask row (drives audited demotion)
     ``journal_torn_write``  tear a journal write mid-frame (the torn
                          tail must truncate away on restart)
     ``crash_point``      crash the process at a journal fsync boundary
                          (before or after — both windows are exercised)

 - :func:`check_invariants` — the debug-mode tick invariant checker:
   free-list/block-table consistency (every page exactly once across
   free list + resident rows, vacant rows hold nothing), slot<->session
   bijection, premask hygiene, and per-row length within its page
   allocation.  ``ContinuousBatchingScheduler(debug_invariants=True)``
   runs it at every tick boundary and raises
   :class:`InvariantViolation` on the first breach, so a chaos storm
   that leaks a single page fails loudly at the tick that leaked it.

Nothing here imports the scheduler: the checker is duck-typed on the
scheduler's public attributes so it can also audit partially-constructed
or deliberately-corrupted instances under test.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Set

import numpy as np


class InjectedFault(RuntimeError):
    """Raised by injection sites that simulate checker/mask failures."""


class InvariantViolation(AssertionError):
    """A tick-boundary invariant does not hold (page leak, slot/session
    mismatch, ...).  Raised by the scheduler under ``debug_invariants``."""


#: every site the scheduler consults, in tick-phase order
SITES = ("prefill_nan", "decode_nan", "mask_error", "advance_error",
         "page_exhaustion", "mask_delay", "device_timeout", "device_error",
         "alloc_fail", "table_corrupt", "journal_torn_write", "crash_point")


@dataclasses.dataclass(frozen=True)
class FaultRecord:
    """One fired fault: which site, at which scheduler tick, on which
    request (None for pool-level sites)."""
    site: str
    tick: int
    rid: Optional[int]


class FaultInjector:
    """Seeded, deterministic fault plan.

    ``rates`` maps site name -> per-consultation firing probability.
    ``targets`` (optional) restricts row-scoped faults to a set of rids —
    pool-level consultations (``rid=None``) are unaffected — which is how
    targeted tests pin a fault to one known request.  ``max_faults``
    bounds the total number of fired faults (the storm eventually lets
    the system drain).  ``delay_s`` is the sleep a fired ``mask_delay``
    asks the scheduler to take.

    Every consultation with a nonzero rate draws exactly one uniform
    from the injector's private Generator, so the fired-fault sequence
    is a pure function of (seed, rates, consultation order); the
    consultation order is a pure function of the workload.  Fired faults
    are logged in :attr:`log` so tests can partition requests into
    affected / unaffected after the run.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 max_faults: Optional[int] = None,
                 delay_s: float = 0.0,
                 targets: Optional[Iterable[int]] = None):
        for site in (rates or {}):
            if site not in SITES:
                raise ValueError(
                    f"unknown fault site {site!r} (have: {SITES})")
        self.rng = np.random.default_rng(seed)
        self.rates = dict(rates or {})
        self.max_faults = max_faults
        self.delay_s = delay_s
        self.targets: Optional[Set[int]] = (
            None if targets is None else set(targets))
        self.log: List[FaultRecord] = []
        self.tick = 0

    def begin_tick(self) -> None:
        self.tick += 1

    def fire(self, site: str, rid: Optional[int] = None) -> bool:
        """One consultation: True = the fault fires at this site now."""
        rate = self.rates.get(site, 0.0)
        if rate <= 0.0:
            return False
        if self.max_faults is not None and len(self.log) >= self.max_faults:
            return False
        if self.rng.random() >= rate:
            return False
        if self.targets is not None and rid is not None \
                and rid not in self.targets:
            return False
        self.log.append(FaultRecord(site, self.tick, rid))
        return True

    def faulted_rids(self, *sites: str) -> Set[int]:
        """Rids that had at least one fault fired at the given sites
        (all row-scoped sites when none are named)."""
        pick = sites or SITES
        return {r.rid for r in self.log
                if r.rid is not None and r.site in pick}

    def n_fired(self, site: Optional[str] = None) -> int:
        return len([r for r in self.log
                    if site is None or r.site == site])


# -- tick invariants -----------------------------------------------------------


def check_invariants(sched) -> List[str]:
    """Audit one scheduler's tick-boundary invariants; returns a list of
    human-readable violations (empty == clean).

    Checked: slot<->session bijection (resident sessions point back at
    their slot, appear once, and are unfinished; waiting sessions hold no
    slot), premask rows only for occupied slots, and — when paged —
    free-list/block-table consistency: the free list and the resident
    rows' allocations partition pages 1..n_pages-1 exactly (no leak, no
    double-booking, no trash-page allocation), vacant rows hold zero
    pages with a zeroed table row, and every resident row's cache length
    fits inside its allocation.
    """
    problems: List[str] = []
    seen: Dict[int, str] = {}
    for i, sess in enumerate(sched.slots):
        if sess is None:
            continue
        if sess.slot != i:
            problems.append(
                f"slot {i} holds rid={sess.rid} whose .slot={sess.slot}")
        if id(sess) in seen:
            problems.append(
                f"rid={sess.rid} resident in slot {i} and {seen[id(sess)]}")
        seen[id(sess)] = f"slot {i}"
        if sess.result is not None:
            problems.append(f"finished rid={sess.rid} still resident "
                            f"in slot {i}")
    for sess in sched.waiting:
        if sess.slot != -1:
            problems.append(
                f"waiting rid={sess.rid} still claims slot {sess.slot}")
        if id(sess) in seen:
            problems.append(f"rid={sess.rid} both waiting and resident")
        seen[id(sess)] = "waiting"
        if sess.result is not None:
            problems.append(f"finished rid={sess.rid} still waiting")
    for slot in getattr(sched, "_premask", {}):
        if sched.slots[slot] is None:
            problems.append(f"premask staged for vacant slot {slot}")

    if not getattr(sched, "paged", False):
        return problems

    free = list(sched.pool._free)
    if len(set(free)) != len(free):
        problems.append("duplicate page ids in the free list")
    if 0 in free:
        problems.append("reserved trash page 0 in the free list")
    allocated: List[int] = []
    for i in range(sched.capacity):
        n = int(sched._n_pages_row[i])
        row = sched._page_tbl[i]
        if sched.slots[i] is None:
            if n != 0 or row.any():
                problems.append(f"vacant slot {i} holds pages "
                                f"(n={n}, tbl={row[row != 0].tolist()})")
            continue
        pages = row[:n].tolist()
        if 0 in pages:
            problems.append(f"slot {i} block table maps a live position "
                            f"to the trash page")
        if row[n:].any():
            problems.append(f"slot {i} block table has stale entries "
                            f"beyond its {n} allocated pages")
        allocated.extend(pages)
    cache = getattr(sched, "prefix_cache", None)
    counts: Dict[int, int] = {}
    for p in allocated:
        counts[p] = counts.get(p, 0) + 1
    if cache is None:
        if len(set(allocated)) != len(allocated):
            problems.append("a pool page is block-mapped by two rows")
    else:
        # COW partition audit: multi-mapping is legal ONLY for pages the
        # radix tree owns (shared read-only prefixes)
        for p, c in counts.items():
            if c > 1 and not cache.owns(p):
                problems.append(f"pool page {p} block-mapped by {c} rows "
                                f"but not owned by the prefix cache")
    overlap = set(allocated) & set(free)
    if overlap:
        problems.append(f"pages {sorted(overlap)} both allocated and free")
    cached_pages = set() if cache is None else set(cache.pages())
    bad = cached_pages & set(free)
    if bad:
        problems.append(f"pages {sorted(bad)} owned by the prefix cache "
                        f"AND on the free list")
    universe = set(range(1, sched.n_pages))
    missing = universe - set(allocated) - set(free) - cached_pages
    if missing:
        problems.append(f"page leak: {sorted(missing)} neither free, "
                        f"cache-owned, nor block-mapped by any resident "
                        f"row")
    # refcount audit: every live page's count equals its block-table
    # mappings plus its radix-node ownership (free pages count 0)
    refcount = getattr(sched.pool, "refcount", None)
    if refcount is not None:
        free_set = set(free)
        for p in universe:
            expect = (0 if p in free_set
                      else counts.get(p, 0) + (1 if p in cached_pages
                                               else 0))
            got = refcount(p)
            if got != expect:
                problems.append(f"page {p} refcount {got} != {expect} "
                                f"(= {counts.get(p, 0)} table refs + "
                                f"{int(p in cached_pages)} node refs)")
    lens = np.asarray(sched.cache["len"])
    for i, sess in enumerate(sched.slots):
        if sess is None:
            continue
        cap = int(sched._n_pages_row[i]) * sched.page_size
        if int(lens[i]) > cap:
            problems.append(f"slot {i} cache length {int(lens[i])} "
                            f"exceeds its {cap}-token page allocation")
    # write-barrier audit: a slot's shared (cached) pages must all be
    # cache-owned and must sit strictly below its write frontier — no
    # shared page is ever writable by a decode/rollback/refeed
    shared = getattr(sched, "_n_shared_row", None)
    if cache is not None and shared is not None:
        for i, sess in enumerate(sched.slots):
            ns = int(shared[i])
            if sess is None:
                if ns:
                    problems.append(f"vacant slot {i} claims {ns} shared "
                                    f"pages")
                continue
            for d in range(min(ns, int(sched._n_pages_row[i]))):
                p = int(sched._page_tbl[i, d])
                if not cache.owns(p):
                    problems.append(f"slot {i} shared page {p} (depth "
                                    f"{d}) is not cache-owned")
            if int(lens[i]) < ns * sched.page_size:
                problems.append(f"slot {i} frontier {int(lens[i])} is "
                                f"inside its {ns}-page shared prefix — "
                                f"a decode write could corrupt a shared "
                                f"page")
    return problems
