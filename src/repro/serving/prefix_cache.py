"""Radix prefix cache: copy-on-write paged KV + checker-state sharing.

Production structured-output traffic is dominated by shared system
prompts and few-shot preambles; DOMINO's thesis is that constrained
decoding should amortize work via precomputation.  This module extends
that amortization ACROSS requests: a radix tree over token-id sequences
whose nodes own refcounted pages in the scheduler's :class:`PagePool`,
so the thousandth identical-preamble request pays only for its suffix.

Soundness rests on two exact-prefix arguments (no quotienting, no
approximation — see README "Prefix cache & copy-on-write"):

* **KV pages.**  With causal attention, K/V at position ``i`` is a pure
  function of tokens ``0..i``.  Two requests whose first ``n`` token ids
  are identical therefore have bitwise-identical cache content for
  positions ``0..n-1``, so a full page written by one request can be
  block-mapped read-only into another request's table.  Matching is
  page-granular (``BLOCK_T == page_size`` is preserved: a node covers
  exactly one page); the partial tail page is always re-prefilled
  privately, which doubles as the copy-on-write barrier — a shared page
  is NEVER the write frontier of any live row, so the "first divergent
  write" lands on a private page by construction and no page is ever
  copied at all.

* **Checker state.**  A :class:`~repro.core.domino.DominoDecoder`'s
  state is a pure function of the token ids advanced through it.  In
  this engine prompts are never advanced (state covers GENERATED tokens
  only), so snapshots are keyed on ``(grammar signature, prompt length,
  full token prefix)``: same grammar/k/EOS, same prompt/generated split,
  same tokens ⇒ the exact same hypothesis set, and a restart-recovery
  replay may clone the snapshot instead of re-running ``advance()``
  token by token.

Eviction is refcount-aware LRU over UNREFERENCED radix leaves: a page a
live block table maps has pool refcount ≥ 2 and is never freed from
under the row; pinned nodes (engine-default prompts installed by
``precompute()``/``warm()``) are never evicted.  All mutation happens at
admission/teardown boundaries — lint rule R6 keeps ``insert``/``lookup``
and checker serialization off the per-token tick path (only ``evict`` /
``evictable`` may run under allocation pressure).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["PrefixCache", "RadixNode"]


class RadixNode:
    """One radix-tree node covering exactly one KV page.

    ``key`` is the tuple of ``page_size`` token ids the page holds;
    children are keyed the same way, so a root-to-node path spells out a
    token-id prefix in whole pages.  The node owns one pool refcount on
    ``page`` for as long as it exists.
    """

    __slots__ = ("key", "page", "parent", "children", "last_used",
                 "pinned")

    def __init__(self, key: Tuple[int, ...], page: int,
                 parent: Optional["RadixNode"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "RadixNode"] = {}
        self.last_used = 0
        self.pinned = False


class PrefixCache:
    """Radix tree over token-id sequences owning refcounted KV pages,
    plus an LRU store of DOMINO checker snapshots at fork points.

    The cache never allocates pages itself: ``insert`` adopts pages a
    row already owns (taking one extra pool refcount per new node) and
    ``lookup`` hands out one refcount per matched page for the caller's
    block table.  ``page_size`` must equal the scheduler's, or prefix
    boundaries would not line up with page boundaries.
    """

    def __init__(self, pool, page_size: int,
                 max_checker_snaps: int = 256):
        self.pool = pool
        self.page_size = int(page_size)
        self.root = RadixNode((), 0, None)   # sentinel; owns no page
        self._by_page: Dict[int, RadixNode] = {}
        self._clock = 0                      # logical LRU time
        # fork-point checker snapshots: (sig, prompt_len, token-tuple)
        # -> pristine DominoDecoder snapshot (never advanced; cloned on
        # every get).  Token granularity, independent of the page tree.
        self.max_checker_snaps = int(max_checker_snaps)
        self._snaps: "collections.OrderedDict[tuple, object]" = \
            collections.OrderedDict()
        self.n_hits = 0                      # lookups matching >= 1 page
        self.n_hit_pages = 0
        self.n_inserted = 0                  # nodes created
        self.n_evicted = 0                   # nodes evicted for pages
        self.n_checker_hits = 0

    # -- page tree --------------------------------------------------------------

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    @property
    def n_pages(self) -> int:
        """Pages currently owned by radix nodes."""
        return len(self._by_page)

    def owns(self, page: int) -> bool:
        return int(page) in self._by_page

    def pages(self) -> List[int]:
        return list(self._by_page)

    def lookup(self, ids: Sequence[int],
               max_pages: Optional[int] = None) -> List[int]:
        """Longest whole-page prefix match for ``ids``.

        Returns the matched page ids root-first, each RETAINED once on
        behalf of the caller's block table (release them via
        ``pool.free``/``release`` exactly like allocated pages).  At most
        ``max_pages`` pages are matched — admission caps this at
        ``(len(ids) - 1) // page_size`` so at least one token is always
        re-prefilled privately (the row needs a live write frontier, and
        the boundary page must be private for COW-by-construction).
        """
        ps = self.page_size
        cap = len(ids) // ps if max_pages is None else int(max_pages)
        now = self._tick()
        node, got = self.root, []
        while len(got) < cap:
            key = tuple(int(t) for t in
                        ids[len(got) * ps:(len(got) + 1) * ps])
            child = node.children.get(key)
            if child is None or len(key) < ps:
                break
            child.last_used = now
            got.append(child.page)
            node = child
        if got:
            self.pool.retain(got)
            self.n_hits += 1
            self.n_hit_pages += len(got)
        return got

    def insert(self, ids: Sequence[int], pages: Sequence[int],
               pin: bool = False) -> int:
        """Install the whole-page prefix of ``ids`` backed by ``pages``
        (one page id per full page, root-first; the caller keeps its own
        references — each NEW node takes one extra pool refcount).

        Where a node for a page-key already exists the existing page is
        kept and the offered one ignored: by prefix determinism the two
        hold bitwise-identical K/V, and keeping the incumbent preserves
        every block table already mapping it.  Returns the number of
        nodes created.
        """
        ps = self.page_size
        n_full = min(len(ids) // ps, len(pages))
        now = self._tick()
        node, created = self.root, 0
        for d in range(n_full):
            key = tuple(int(t) for t in ids[d * ps:(d + 1) * ps])
            child = node.children.get(key)
            if child is None:
                page = int(pages[d])
                if page in self._by_page:
                    # same page offered under a second path — impossible
                    # unless the caller's table is corrupt; refuse the
                    # alias rather than double-own one refcount
                    break
                self.pool.retain([page])
                child = RadixNode(key, page, node)
                node.children[key] = child
                self._by_page[page] = child
                created += 1
            child.last_used = now
            if pin:
                child.pinned = True
            node = child
        self.n_inserted += created
        return created

    # -- eviction ---------------------------------------------------------------

    def _evictable_leaves(self) -> List[RadixNode]:
        return [n for n in self._by_page.values()
                if not n.children and not n.pinned
                and self.pool.refcount(n.page) == 1]

    def evictable(self) -> int:
        """Pages the cache could surrender right now — every node whose
        page only the cache references, counted transitively (evicting a
        leaf exposes its parent)."""
        # a node is reclaimable iff no live block table maps any page in
        # its subtree and nothing in the subtree is pinned; count by
        # peeling leaves on a scratch copy of the child counts
        kids = {id(n): len(n.children) for n in self._by_page.values()}
        blocked = {id(n) for n in self._by_page.values()
                   if n.pinned or self.pool.refcount(n.page) > 1}
        frontier = [n for n in self._by_page.values()
                    if kids[id(n)] == 0 and id(n) not in blocked]
        count = 0
        while frontier:
            n = frontier.pop()
            count += 1
            p = n.parent
            if p is not None and p is not self.root:
                kids[id(p)] -= 1
                if kids[id(p)] == 0 and id(p) not in blocked:
                    frontier.append(p)
        return count

    def evict(self, n: int) -> int:
        """Free up to ``n`` pages back to the pool, LRU-first over
        unreferenced unpinned leaves (interior nodes become leaves as
        their children go).  Never touches a page a live block table
        maps.  Returns the number of pages actually freed."""
        freed = 0
        while freed < max(0, n):
            leaves = self._evictable_leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_used)
            self._drop(victim)
            freed += 1
            self.n_evicted += 1
        return freed

    def _drop(self, node: RadixNode) -> None:
        assert not node.children
        del node.parent.children[node.key]
        del self._by_page[node.page]
        self.pool.release([node.page])

    def reset(self) -> None:
        """Drop every node and snapshot (engine reset: the pool leaves'
        contents are gone, so cached pages are garbage).  Node refcounts
        are released; pages still table-mapped survive until their rows
        are preempted."""
        for node in list(self._by_page.values()):
            node.children.clear()
        for node in list(self._by_page.values()):
            self.pool.release([node.page])
        self._by_page.clear()
        self.root.children.clear()
        self._snaps.clear()

    # -- checker snapshots -------------------------------------------------------

    def put_checker(self, sig: tuple, prompt_len: int,
                    ids: Sequence[int], checker) -> None:
        """Store a pristine snapshot of ``checker`` (state = tokens
        ``ids[prompt_len:]`` advanced after a ``prompt_len``-token
        prompt).  ``sig`` must capture everything that shapes checker
        state besides the tokens (grammar name, mode, k, EOS id)."""
        snap = getattr(checker, "snapshot", None)
        if snap is None:
            return
        key = (sig, int(prompt_len), tuple(int(t) for t in ids))
        self._snaps[key] = snap()
        self._snaps.move_to_end(key)
        while len(self._snaps) > self.max_checker_snaps:
            self._snaps.popitem(last=False)

    def get_checker(self, sig: tuple, prompt_len: int,
                    ids: Sequence[int]):
        """Longest stored snapshot covering a prefix of ``ids`` (at
        token granularity, but never splitting the prompt: candidates
        run from the full sequence down to ``prompt_len + 1``).  Returns
        ``(n_covered, clone)`` or None; the stored snapshot stays
        pristine — the caller gets a fresh fork."""
        ids = [int(t) for t in ids]
        for n in range(len(ids), int(prompt_len), -1):
            snap = self._snaps.get((sig, int(prompt_len), tuple(ids[:n])))
            if snap is not None:
                self._snaps.move_to_end((sig, int(prompt_len),
                                         tuple(ids[:n])))
                self.n_checker_hits += 1
                return n, snap.snapshot()
        return None

    # -- reporting ---------------------------------------------------------------

    def stats(self) -> Dict[str, int]:
        return dict(n_pages=self.n_pages, n_hits=self.n_hits,
                    n_hit_pages=self.n_hit_pages,
                    n_inserted=self.n_inserted, n_evicted=self.n_evicted,
                    n_checker_hits=self.n_checker_hits,
                    n_checker_snaps=len(self._snaps))
