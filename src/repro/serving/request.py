"""Per-request constraint API — the unit the serving stack speaks.

DOMINO's pitch is *non-invasive* constrained generation, so the request
surface must not bake one grammar into the engine: a deployment serving
JSON, C, and unconstrained traffic runs ONE engine (one KV pool, one
scheduler) and routes constraints per request.

 - :class:`ConstraintSpec` — WHAT to constrain with: a grammar reference
   (a name registered on the engine's grammar registry, a ``Grammar``
   object, or None), the constraint mode, the DOMINO lookahead ``k``,
   opportunistic checking, token healing, and an optional per-request EOS
   id.  The checker factory lives here (``make_checker`` /
   ``prep_prompt``), not on the engine.
 - :class:`DecodeParams` — HOW to decode: temperature, token budget,
   sampling seed, and the speculation knobs.
 - :class:`Request` — prompt + ConstraintSpec + DecodeParams (+ optional
   model side inputs).  ``ServingEngine.generate`` and
   ``Scheduler.submit`` both take one (a bare string submits the
   engine-default request, which is how the legacy ``EngineConfig``
   surface keeps working).

Sampling helpers (``select_token`` / ``packed_argmax``) also live here so
the engine and the scheduler share one selection definition: greedy
selection operates directly on packed uint32 rows (bit test + legal-id
argmax, no ``(V,)`` bool materialization), and the bool unpack survives
only on the temperature>0 branch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import bitmask

#: grammar reference inside a ConstraintSpec: a registry name, an actual
#: Grammar object (auto-registered on first use), or None (unconstrained)
GrammarRef = Union[str, Any, None]

_CONSTRAINED_MODES = ("domino", "naive", "online")


@dataclasses.dataclass(frozen=True)
class ConstraintSpec:
    """What to constrain one request with.

    ``grammar`` is a reference, not a tree cache: the engine's grammar
    registry resolves it to a shared per-grammar ``TreeCache``, so a
    thousand requests on the same grammar share one set of subterminal
    trees and one mask memo.
    """
    grammar: GrammarRef = None
    mode: str = "unconstrained"   # unconstrained|domino|naive|online
    k: Optional[int] = None       # DOMINO lookahead (None = ∞)
    opportunistic: bool = False
    # token healing (§3.5): strip the last `heal` prompt tokens and force
    # the stripped text as a generation prefix
    heal: int = 0
    # per-request EOS id; None = the tokenizer's default
    eos_id: Optional[int] = None

    @property
    def constrained(self) -> bool:
        return self.grammar is not None and self.mode in _CONSTRAINED_MODES

    # -- prompt preparation ---------------------------------------------------

    def prep_prompt(self, prompt_ids: List[int],
                    vocab: Sequence[Optional[bytes]]):
        """Apply token healing (§3.5) to an encoded prompt.  Returns
        ``(prompt_ids, heal_prefix)``."""
        if self.heal > 0 and len(prompt_ids) > self.heal:
            from repro.core.healing import heal_prompt
            return heal_prompt(prompt_ids, vocab, n_strip=self.heal)
        return list(prompt_ids), ""

    # -- checker factory ------------------------------------------------------

    def make_checker(self, grammar, vocab: Sequence[Optional[bytes]],
                     eos_id: int, tree_cache=None, heal_prefix: str = ""):
        """Build this spec's grammar checker against a resolved grammar
        and its shared TreeCache (the engine registry resolves
        ``self.grammar`` to both).  Returns None for unconstrained."""
        mode = self.mode
        if mode == "unconstrained" or grammar is None:
            return None
        if mode == "domino" and heal_prefix:
            from repro.core.healing import HealedDecoder
            return HealedDecoder(grammar, list(vocab), eos_id, heal_prefix,
                                 k=self.k, tree_cache=tree_cache)
        if mode == "domino":
            from repro.core.domino import DominoDecoder
            return DominoDecoder(grammar, list(vocab), eos_id, k=self.k,
                                 tree_cache=tree_cache)
        if mode == "naive":
            from repro.core.domino import DominoDecoder
            return DominoDecoder(grammar, list(vocab), eos_id, k=0,
                                 tree_cache=tree_cache)
        if mode == "online":
            from repro.core.baselines import OnlineParserDecoder
            return OnlineParserDecoder(grammar, list(vocab), eos_id,
                                       tree_cache=tree_cache)
        raise ValueError(mode)


@dataclasses.dataclass(frozen=True)
class DecodeParams:
    """How to decode one request."""
    temperature: float = 0.0      # 0 = greedy
    max_tokens: int = 128
    seed: int = 0                 # per-request sampling seed
    speculative: bool = False
    spec_s: int = 8
    spec_threshold: float = 0.5
    # wall-clock deadline in seconds, measured from submission (queue
    # wait included).  None = unbounded.  An overdue request terminates
    # with status ``deadline_exceeded`` at the next tick boundary, its
    # slot and pages freed for batch-mates.
    deadline_s: Optional[float] = None

    def make_rng(self) -> np.random.Generator:
        """Per-request sampling RNG: seeded from the request, so a
        sampled request's output never depends on batch composition or
        admission order."""
        return np.random.default_rng(self.seed)


@dataclasses.dataclass
class Request:
    """One serving request: prompt + constraint + decode policy."""
    prompt: str
    constraint: ConstraintSpec = dataclasses.field(
        default_factory=ConstraintSpec)
    decode: DecodeParams = dataclasses.field(default_factory=DecodeParams)
    # extra model inputs (e.g. multimodal features), merged into the
    # prefill inputs dict
    extra_inputs: Optional[Dict[str, Any]] = None


# -- shared token selection ----------------------------------------------------


def select_token(logits: np.ndarray, mask: Optional[np.ndarray],
                 temperature: float,
                 rng: Optional[np.random.Generator]) -> int:
    """Reference (bool-mask) selection: greedy masked argmax at
    temperature 0, softmax sampling otherwise.  Ties break to the lowest
    index, matching the fused device kernel."""
    lg = logits.astype(np.float64)
    if mask is not None:
        lg = np.where(mask, lg, -1e30)
    if temperature <= 0.0:
        return int(lg.argmax())
    p = np.exp((lg - lg.max()) / temperature)
    p = p / p.sum()
    return int(rng.choice(len(p), p=p))


def packed_argmax(logits: np.ndarray, bits: np.ndarray,
                  v: int) -> Optional[int]:
    """Greedy masked argmax directly on a packed uint32 row: gather the
    legal token ids from the bitset and argmax their logits — no ``(V,)``
    bool round-trip.  Returns None when no bit is set (dead end).  Tie
    break matches ``select_token``/the fused kernel (lowest legal id)."""
    ids = bitmask.to_ids(bits, v)
    if ids.size == 0:
        return None
    lg = logits.astype(np.float64)
    return int(ids[int(np.argmax(lg[ids]))])
