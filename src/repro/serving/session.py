"""Per-request serving state.

A :class:`Session` is everything the continuous-batching scheduler needs to
know about one request: its :class:`~repro.serving.request.Request` (the
constraint spec and decode policy), the grammar checker built from the
engine's grammar registry, its budget, per-row decode policy (EOS id,
temperature, sampling RNG, speculator), the KV slot it occupies while
resident, and per-request statistics (mask time, forward passes,
speculation counters, wall-clock).  Sessions are created by
``ServingEngine.make_session`` / ``Scheduler.submit`` and carry their
:class:`GenerationResult` once finished.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class GenerationResult:
    text: str
    token_ids: List[int]
    n_forward_passes: int
    n_tokens: int
    n_interventions: int              # argmax rejected by the mask
    n_spec_proposed: int
    n_spec_accepted: int
    mask_time_s: float
    model_time_s: float
    wall_time_s: float
    finished: bool
    # portion of mask_time_s the scheduler hid under device execution
    # (host builds step t+1's grammar mask while the device runs step t);
    # mask_time_s - mask_overlap_s is what actually sat on the critical
    # path
    mask_overlap_s: float = 0.0
    # full-mask builds served by the state-keyed memo on the shared
    # per-grammar TreeCache (recurring grammar states are a dict lookup
    # instead of a tree walk) — attributed per request, so a mixed batch
    # reports each row's own hits
    mask_cache_hits: int = 0
    # times this request was recompute-preempted by the paged-KV
    # scheduler (pages reclaimed under pool pressure, prompt + generated
    # prefix re-prefilled on re-admission)
    n_preemptions: int = 0
    # tokens committed through the device-resident fused decode loop
    # (certified-grammar rows under device_loop=True; 0 on the host path)
    n_device_tokens: int = 0
    # tokens restored from the crash journal on restart (replayed through
    # the concrete checker, not re-decoded) rather than generated live
    n_replayed_tokens: int = 0
    # prefill positions served from the radix prefix cache (shared KV
    # pages block-mapped instead of recomputed) across every admission
    # of this request — the per-row "prefill FLOPs skipped" signal
    n_cached_prefix_tokens: int = 0
    # the checker reached a state with NO legal token (including EOS).
    # Output up to this point is a valid *prefix* but cannot be completed;
    # forcing EOS here would silently emit grammar-violating output.
    dead_end: bool = False
    # times the checker's scanner-hypothesis set overflowed
    # MAX_HYPOTHESES and was truncated (a nonzero count means masks were
    # potentially UNSOUND — legal tokens may have been excluded).  The
    # static analyzer's ambiguity report (max abstract fan-out) predicts
    # this: a grammar certified with fan-out well under the cap can never
    # truncate at runtime.
    n_hyp_truncations: int = 0
    # peak size of the checker's hypothesis set over this request —
    # compare against AnalysisReport.max_abstract_fanout to validate the
    # analyzer's ambiguity model on real traffic
    max_hyp_fanout: int = 1
    # terminal-status taxonomy (fault-tolerant serving).  Exactly one of:
    #   ok                 normal completion (per-request EOS or budget)
    #   dead_end           checker state with no legal token (see above)
    #   deadline_exceeded  the request's wall-clock deadline elapsed
    #                      (queue wait included) before completion
    #   cancelled          cancel(rid) took effect at a tick boundary
    #   rejected           never decoded: unsatisfiable admission demand
    #                      (prompt pages > pool capacity), bounded-queue
    #                      load shedding, or queue-wait timeout
    #   internal_error     a failure quarantined to this row — non-finite
    #                      logits from the device step, a checker/mask
    #                      exception — while batch-mates kept decoding
    status: str = "ok"
    # human-readable reason accompanying any non-ok status
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def tokens_per_forward(self) -> float:
        return self.n_tokens / max(1, self.n_forward_passes)


@dataclasses.dataclass
class Session:
    """One request's lifecycle through the scheduler.

    States: waiting (slot < 0) -> active (slot >= 0) -> finished
    (result is not None, slot freed).

    The per-row decode policy lives here: ``eos_id``, ``decode``
    (temperature / budget / seed / speculation knobs), ``opportunistic``,
    the per-request sampling ``rng`` and the (engine-shared-count-model)
    ``speculator``.  The scheduler reads policy from the session, never
    from an engine-global config — that is what lets one batch mix
    grammars, modes and sampling policies per row.
    """
    rid: int
    prompt: str
    prompt_ids: List[int]
    checker: Any                      # DominoDecoder-like, or None
    budget: int
    # -- per-row decode policy (filled by ServingEngine.make_session) --
    eos_id: int = -1
    decode: Any = None                # DecodeParams
    opportunistic: bool = False
    speculator: Any = None            # Speculator sharing the engine's
    #                                   count model, or None
    request: Any = None               # the originating Request
    extra_inputs: Optional[Dict[str, Any]] = None
    slot: int = -1
    out_ids: List[int] = dataclasses.field(default_factory=list)
    # per-request statistics
    n_fwd: int = 0                    # forwards while this request resident
    n_int: int = 0
    n_prop: int = 0
    n_acc: int = 0
    n_preempt: int = 0                # paged-KV recompute preemptions
    # sampling-draw counter: number of temperature>0 selections this
    # request has made.  The device sampling kernel folds it into the
    # request's counter-based PRNG key, so a sampled row's stream depends
    # only on (seed, draw index) — never on batch composition — matching
    # the host np.random.Generator contract in spirit (same independence
    # guarantee, different bit stream).
    n_draws: int = 0
    # tokens this request committed through the device-resident fused
    # decode loop (0 for host-path rows)
    n_device_tokens: int = 0
    # tokens restored from the crash journal (see GenerationResult)
    n_replayed: int = 0
    # prefill positions skipped via prefix-cache page hits (cumulative
    # over re-admissions), and whether adopt() cloned a cached checker
    # snapshot instead of replaying the journal through advance()
    n_cached_tokens: int = 0
    cached_checker: bool = False
    mask_time: float = 0.0            # this request's checker time only
    mask_overlap: float = 0.0         # ... of which hidden under device
    model_time: float = 0.0
    # lifecycle (done == result is not None)
    finished_eos: bool = False
    dead_end: bool = False
    # terminal-status override: the scheduler sets this for
    # cancelled/deadline_exceeded/rejected/internal_error terminations;
    # None resolves to "dead_end" or "ok" at finish time
    status: Optional[str] = None
    error: Optional[str] = None
    # set by Scheduler.cancel(rid); honored at the next tick boundary
    cancel_requested: bool = False
    t_submit: float = dataclasses.field(default_factory=time.perf_counter)
    t_admit: float = 0.0
    t_finish: float = 0.0
    result: Optional[GenerationResult] = None
    _rng: Optional[np.random.Generator] = dataclasses.field(
        default=None, repr=False)

    @property
    def temperature(self) -> float:
        return 0.0 if self.decode is None else self.decode.temperature

    @property
    def deadline_s(self) -> Optional[float]:
        """Per-request wall-clock deadline (seconds from submit, queue
        wait included); None defers to the scheduler default."""
        return getattr(self.decode, "deadline_s", None)

    @property
    def rng(self) -> np.random.Generator:
        """Per-request sampling RNG, created lazily from the request's
        seed: sampled output depends only on the request, never on batch
        composition or admission order."""
        if self._rng is None:
            self._rng = (self.decode.make_rng() if self.decode is not None
                         else np.random.default_rng(0))
        return self._rng

    def finish(self, decode_text) -> GenerationResult:
        self.t_finish = time.perf_counter()
        status = self.status
        if status is None:
            status = "dead_end" if self.dead_end else "ok"
        self.result = GenerationResult(
            status=status,
            error=self.error,
            text=decode_text(self.out_ids),
            token_ids=list(self.out_ids),
            n_forward_passes=self.n_fwd,
            n_tokens=len(self.out_ids),
            n_interventions=self.n_int,
            n_spec_proposed=self.n_prop,
            n_spec_accepted=self.n_acc,
            mask_time_s=self.mask_time,
            mask_overlap_s=self.mask_overlap,
            mask_cache_hits=getattr(self.checker, "n_mask_memo_hits", 0),
            n_preemptions=self.n_preempt,
            n_device_tokens=self.n_device_tokens,
            n_replayed_tokens=self.n_replayed,
            n_cached_prefix_tokens=self.n_cached_tokens,
            model_time_s=self.model_time,
            wall_time_s=self.t_finish - self.t_submit,
            finished=self.finished_eos,
            dead_end=self.dead_end,
            n_hyp_truncations=getattr(self.checker,
                                      "n_hyp_truncations", 0),
            max_hyp_fanout=getattr(self.checker, "max_hyp_fanout", 1),
        )
        return self.result
