"""Tiered degradation supervisor for the serving scheduler.

PR 7 quarantined failures to their row; PR 8's audits demote escaped
rows off the fused path.  This module generalizes both into an
engine-wide ladder for when the *device* (not a row) is sick:

    level 0  "fused"   device-resident sync_n block loop (PR 8)
    level 1  "host"    per-token host loop, pallas kernels
    level 2  "dense"   per-token host loop, jnp reference ops
                       (``masked_argmax(..., use_ref=True)`` + host
                       ``select_token`` — no pallas dispatch at all)

The scheduler consults :attr:`level` when choosing a tick path; a step
down is triggered by a device timeout, an XLA/runtime error escaping a
dispatch, or repeated allocation failure — each first retried with
bounded exponential backoff via :meth:`guard`.  Recovery climbs one
level per ``recover_after`` consecutive clean ticks, so a transiently
sick device ends back at the fused path and MTTR is measurable.

All timing goes through injectable ``clock``/``sleep`` so tests drive
watchdogs deterministically; every transition is recorded in
:attr:`events` and summarized by :meth:`stats` (surfaced in scheduler
session stats and ``BENCH_serving.json``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

LEVELS = ("fused", "host", "dense")


@dataclasses.dataclass
class SupervisorEvent:
    t: float
    kind: str          # "degrade" | "recover" | "retry"
    level: int         # level AFTER the transition
    what: str          # site/operation name
    error: Optional[str] = None


class DegradationSupervisor:
    """Watchdogs + bounded retry + the fused→host→dense ladder.

    ``watchdog_s`` bounds a guarded per-tick operation (e.g. the
    ``_raw_stats`` readback); ``block_watchdog_s`` bounds one fused
    sync_n block.  ``None`` disables a watchdog.  Exceeding one is not
    an error by itself — the caller decides whether to keep the result —
    but it counts as a degrade trigger.
    """

    def __init__(self, watchdog_s: Optional[float] = None,
                 block_watchdog_s: Optional[float] = None,
                 max_retries: int = 2, backoff_s: float = 0.005,
                 recover_after: int = 8,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.watchdog_s = watchdog_s
        self.block_watchdog_s = block_watchdog_s
        self.max_retries = max(0, int(max_retries))
        self.backoff_s = backoff_s
        self.recover_after = max(1, int(recover_after))
        self.clock = clock
        self.sleep = sleep
        self.level = 0
        self.events: List[SupervisorEvent] = []
        self.n_degrades = 0
        self.n_recovers = 0
        self.n_retries = 0
        self.n_watchdog_trips = 0
        self.mttr_s: Optional[float] = None   # last full 0→…→0 round trip
        self._clean = 0
        self._dirty = False                   # this tick saw a fault
        self._t_first_degrade: Optional[float] = None

    # -- guarded execution ---------------------------------------------------

    def guard(self, what: str, thunk: Callable[[], Any],
              inject: Optional[Callable[[], bool]] = None,
              watchdog_s: Optional[float] = None) -> Tuple[bool, Any]:
        """Run ``thunk`` with bounded retry + exponential backoff.

        Returns ``(True, value)`` on success or ``(False, error)`` after
        retries are exhausted.  ``inject`` is consulted BEFORE each
        attempt (an injected fault is a simulated failure, so retrying
        it is always safe — nothing was dispatched); a real exception
        from ``thunk`` is caught and retried the same way.  NOTE: only
        pass re-runnable thunks — a dispatch that donates buffers must
        be guarded pre-dispatch (inject-only thunk) instead.
        """
        wd = self.watchdog_s if watchdog_s is None else watchdog_s
        err: Any = None
        for attempt in range(self.max_retries + 1):
            if attempt > 0:
                self.n_retries += 1
                self.events.append(SupervisorEvent(
                    self.clock(), "retry", self.level, what, str(err)))
                self.sleep(self.backoff_s * (2 ** (attempt - 1)))
            if inject is not None and inject():
                err = RuntimeError(f"injected fault at {what}")
                continue
            t0 = self.clock()
            try:
                value = thunk()
            except Exception as e:
                err = e
                continue
            if wd is not None and self.clock() - t0 > wd:
                self.n_watchdog_trips += 1
                err = TimeoutError(
                    f"{what} exceeded watchdog {wd:g}s")
                # the value is GOOD (the op finished, just slowly) —
                # hand it back; the caller degrades but keeps it
                return True, value
            return True, value
        return False, err

    # -- ladder transitions --------------------------------------------------

    def degrade(self, what: str, error: Optional[BaseException] = None) -> int:
        """Step one level down (capped at dense).  Marks the current
        tick dirty so it doesn't count toward recovery."""
        self._dirty = True
        self._clean = 0
        if self._t_first_degrade is None:
            self._t_first_degrade = self.clock()
        if self.level < len(LEVELS) - 1:
            self.level += 1
            self.n_degrades += 1
            self.events.append(SupervisorEvent(
                self.clock(), "degrade", self.level, what,
                None if error is None else str(error)))
        return self.level

    def tick_ok(self) -> None:
        """Called once per scheduler tick that completed without a
        device fault.  After ``recover_after`` consecutive clean ticks,
        climb one level; reaching level 0 closes the MTTR window."""
        if self._dirty:
            self._dirty = False        # faulted tick: reset, don't count
            return
        if self.level == 0:
            return
        self._clean += 1
        if self._clean < self.recover_after:
            return
        self._clean = 0
        self.level -= 1
        self.n_recovers += 1
        self.events.append(SupervisorEvent(
            self.clock(), "recover", self.level, "clean-ticks"))
        if self.level == 0 and self._t_first_degrade is not None:
            self.mttr_s = self.clock() - self._t_first_degrade
            self._t_first_degrade = None

    # -- reporting -----------------------------------------------------------

    @property
    def level_name(self) -> str:
        return LEVELS[self.level]

    def stats(self) -> Dict[str, Any]:
        return {
            "level": self.level,
            "level_name": self.level_name,
            "n_degrades": self.n_degrades,
            "n_recovers": self.n_recovers,
            "n_retries": self.n_retries,
            "n_watchdog_trips": self.n_watchdog_trips,
            "mttr_s": self.mttr_s,
        }
