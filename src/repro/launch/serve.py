"""Constrained-serving driver: loads (or trains) a small model and serves
batched requests through the per-request constraint API.

``--grammar`` takes a comma-separated list ("none" = unconstrained rows);
every listed grammar is registered on ONE engine's grammar registry and
the prompts cycle through them, so a single continuous batch carries
mixed-grammar traffic:

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --grammar json,c,none --mode domino --prompts 6
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--grammar", default="json",
                    help="comma-separated grammar names cycled across "
                         "prompts; 'none' entries serve unconstrained rows")
    ap.add_argument("--mode", default="domino",
                    choices=["unconstrained", "domino", "naive", "online"])
    ap.add_argument("--k", type=int, default=-1, help="-1 = infinity")
    ap.add_argument("--opportunistic", action="store_true")
    ap.add_argument("--speculative", action="store_true")
    ap.add_argument("--spec-s", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="per-request sampling seed base (request i uses "
                         "seed+i)")
    ap.add_argument("--prompts", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching decode slots")
    ap.add_argument("--kernels", action="store_true",
                    help="route decode through the fused Pallas kernels "
                         "(ragged flash-decode; interpret mode off-TPU)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="paged-KV pool page length in tokens (pageable "
                         "archs only; the fused kernel's BLOCK_T)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="paged-KV pool size in pages (default: "
                         "capacity-equivalent slots*max_len/page_size; "
                         "smaller pools trade admission backpressure for "
                         "HBM)")
    ap.add_argument("--no-paged", action="store_true",
                    help="force contiguous per-slot KV stripes")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline in seconds "
                         "(queue wait included); overdue requests end "
                         "with status deadline_exceeded instead of "
                         "holding a slot (default: unbounded)")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="bound the scheduler's waiting queue: overflow "
                         "submissions are shed immediately with status "
                         "rejected (default: unbounded)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over the paged KV pool: "
                         "admissions sharing a token prefix block-map "
                         "the cached pages copy-on-write and prefill "
                         "only the tail (outputs bitwise-identical to a "
                         "cold cache); requires paged KV")
    ap.add_argument("--device-tables", action="store_true",
                    help="build device grammar tables at precompute for "
                         "every registered grammar that certifies clean "
                         "(finite closure, no mask conflicts/truncations)")
    ap.add_argument("--device-loop", action="store_true",
                    help="run certified greedy rows through the fused "
                         "device-resident decode loop: one host sync per "
                         "--sync-n tokens instead of per token "
                         "(implies --device-tables)")
    ap.add_argument("--sync-n", type=int, default=8,
                    help="fused-loop block length: decode steps committed "
                         "on device between host syncs")
    ap.add_argument("--analyze", default="off",
                    choices=["off", "warn", "strict"],
                    help="registration-time grammar analysis policy: "
                         "'warn' reports traps/alignment gaps, 'strict' "
                         "refuses to serve a grammar with any")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="crash-consistent token journal (write-ahead "
                         "log): per-request lifecycle events and "
                         "committed-token batches, fsynced at tick "
                         "boundaries; restart with --restore to resume")
    ap.add_argument("--journal-sync-every", type=int, default=1,
                    help="fsync the journal every N ticks (larger = "
                         "less durable tail, less write amplification)")
    ap.add_argument("--restore", action="store_true",
                    help="replay --journal PATH instead of submitting "
                         "fresh prompts: live requests resume from "
                         "their validated committed prefix (greedy rows "
                         "bitwise-identical to an uninterrupted run)")
    ap.add_argument("--crash-after-syncs", type=int, default=None,
                    metavar="K",
                    help="fault drill: SIGKILL this process after the "
                         "journal's K-th fsync (exercised by "
                         "tools/restart_smoke.py)")
    ap.add_argument("--print-ids", action="store_true",
                    help="emit one machine-readable 'IDS <rid> "
                         "<token ids...>' line per result (restart-smoke "
                         "bitwise comparison)")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core import grammars
    from repro.core.sampling import GrammarSampler
    from repro.models import build_model
    from repro.serving import (ConstraintSpec, DecodeParams, Request,
                               ServingEngine, TokenJournal)
    from repro.tokenizer import BPETokenizer, train_bpe
    from repro.training import checkpoint

    gnames = [n.strip() for n in args.grammar.split(",") if n.strip()]
    loaded = {n: grammars.load(n) for n in gnames if n != "none"}
    cfg = get_config(args.arch, smoke=True)
    if args.checkpoint:
        import os
        tok = BPETokenizer.load(os.path.join(args.checkpoint,
                                             "tokenizer.json"))
        cfg = dataclasses.replace(cfg, vocab_size=tok.vocab_size,
                                  max_seq_len=4096)
        model = build_model(cfg)
        params, _, _ = checkpoint.load(
            args.checkpoint, model.init(jax.random.PRNGKey(0)))
    else:
        corpus = b""
        for i, g in enumerate(loaded.values() or
                              [grammars.load("json")]):
            corpus += GrammarSampler(g, seed=i).corpus(
                200 // max(1, len(loaded)))
        tok = train_bpe(corpus, vocab_size=400)
        cfg = dataclasses.replace(cfg, vocab_size=tok.vocab_size,
                                  max_seq_len=4096)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))

    if args.kernels:
        cfg = dataclasses.replace(cfg, use_pallas_kernels=True)
        model = build_model(cfg)

    # ONE engine, one KV pool: constraints ride on each Request
    device_tables = args.device_tables or args.device_loop
    engine = ServingEngine(model, params, tok, max_len=1024,
                           analysis_policy=args.analyze,
                           device_tables=device_tables)
    for name, g in loaded.items():
        engine.register_grammar(name, g)   # analyzed per --analyze policy
    engine.precompute()                 # warm every registered grammar
    for name, rep in engine.analysis_reports.items():
        print(f"[analysis] {name}: "
              f"{'OK' if rep.ok() else 'PROBLEMS'} "
              f"({rep.closure.n_states} states, "
              f"{'finite' if rep.closure.finite else 'open'}, "
              f"{rep.analysis_time_s:.2f}s)")
    if device_tables:
        for name, tbl in engine.device_tables.items():
            print(f"[device-table] {name}: {tbl.n_states} states, "
                  f"{tbl.n_bytes / 1024:.0f} KiB uploaded")
        missing = set(loaded) - set(engine.device_tables)
        if missing:
            print(f"[device-table] not certified (host path): "
                  f"{','.join(sorted(missing))}")

    journal = None
    if args.journal:
        journal = TokenJournal(args.journal,
                               sync_every=args.journal_sync_every,
                               crash_after_syncs=args.crash_after_syncs)

    if args.restore:
        if journal is None:
            ap.error("--restore requires --journal PATH")
        # same deterministic engine (seeded tokenizer corpus + PRNGKey(0)
        # init) as the crashed run, so recompute-prefill regenerates the
        # exact logits; the reopened journal keeps the resumed run durable
        sched = engine.restore(
            args.journal, max_batch=args.slots, journal=journal,
            paged=False if args.no_paged else None,
            page_size=args.page_size, n_pages=args.pool_pages,
            device_loop=args.device_loop, sync_n=args.sync_n,
            prefix_cache=args.prefix_cache)
        n_live = len(sched.waiting)
        results = sched.run()
        print(f"[restore] {args.journal}: {len(results)} journaled "
              f"request(s), {n_live} resumed live; "
              f"stats={sched.stats()}")
        for r in results:
            print(f"--- out[status={r.status}, {r.n_tokens} toks, "
                  f"{r.n_replayed_tokens} replayed]: {r.text[:120]!r}"
                  + (f" error={r.error}" if r.error else ""))
        if args.print_ids:
            for s in sorted(sched.finished, key=lambda s: s.rid):
                print(f"IDS {s.rid} " + " ".join(
                    str(t) for t in s.result.token_ids))
        return

    decode = DecodeParams(
        temperature=args.temperature, max_tokens=args.max_tokens,
        speculative=args.speculative, spec_s=args.spec_s,
        deadline_s=args.deadline_s)
    specs = []
    for name in gnames:
        if name == "none" or args.mode == "unconstrained":
            specs.append(ConstraintSpec())
        else:
            specs.append(ConstraintSpec(
                grammar=name, mode=args.mode,
                k=(None if args.k < 0 else args.k),
                opportunistic=args.opportunistic))

    base_prompts = ["A person encoded as a JSON object: ",
                    "Results: ",
                    "Config: ",
                    "Data record: "]
    requests = [
        Request(base_prompts[i % len(base_prompts)],
                specs[i % len(specs)],
                dataclasses.replace(decode, seed=args.seed + i))
        for i in range(args.prompts)]
    labels = [gnames[i % len(gnames)] for i in range(args.prompts)]

    if len(requests) > 1 or journal is not None:
        # continuous batching covers every arch (SSM/SWA rows are admitted
        # by exact-length prefill; speculation refeeds per row); pure
        # full-attention/MLA stacks serve from a paged KV pool; rows mix
        # grammars/modes freely
        print(f"[continuous batching: {len(requests)} requests "
              f"({','.join(sorted(set(labels)))}), "
              f"{min(len(requests), args.slots)} slots, "
              f"{'contiguous KV' if args.no_paged else 'paged KV'}]")
        results = engine.generate_batch(
            requests, max_batch=args.slots,
            paged=False if args.no_paged else None,
            page_size=args.page_size, n_pages=args.pool_pages,
            queue_limit=args.queue_limit,
            device_loop=args.device_loop, sync_n=args.sync_n,
            journal=journal, prefix_cache=args.prefix_cache)
    else:
        results = [engine.generate(r) for r in requests]
    for lbl, req, r in zip(labels, requests, results):
        print(f"--- prompt[{lbl}]: {req.prompt!r}")
        print(f"    out[status={r.status}, {r.n_tokens} toks, "
              f"{r.n_forward_passes} fwd, "
              f"{r.n_interventions} interventions, "
              f"spec {r.n_spec_accepted}/{r.n_spec_proposed}"
              + (f", {r.n_device_tokens} device-committed"
                 if args.device_loop else "")
              + f"]: {r.text[:120]!r}"
              + (f" error={r.error}" if r.error else ""))
    if args.print_ids:
        for rid, r in enumerate(results):
            print(f"IDS {rid} " + " ".join(str(t) for t in r.token_ids))


if __name__ == "__main__":
    main()
