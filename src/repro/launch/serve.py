"""Constrained-serving driver: loads (or trains) a small model and serves
batched requests under a grammar with the selected constraint mode.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --grammar json --mode domino --speculative --prompts 4
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--grammar", default="json")
    ap.add_argument("--mode", default="domino",
                    choices=["unconstrained", "domino", "naive", "online"])
    ap.add_argument("--k", type=int, default=-1, help="-1 = infinity")
    ap.add_argument("--opportunistic", action="store_true")
    ap.add_argument("--speculative", action="store_true")
    ap.add_argument("--spec-s", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prompts", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching decode slots")
    ap.add_argument("--kernels", action="store_true",
                    help="route decode through the fused Pallas kernels "
                         "(ragged flash-decode; interpret mode off-TPU)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="paged-KV pool page length in tokens (pageable "
                         "archs only; the fused kernel's BLOCK_T)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="paged-KV pool size in pages (default: "
                         "capacity-equivalent slots*max_len/page_size; "
                         "smaller pools trade admission backpressure for "
                         "HBM)")
    ap.add_argument("--no-paged", action="store_true",
                    help="force contiguous per-slot KV stripes")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core import grammars
    from repro.core.sampling import GrammarSampler
    from repro.models import build_model
    from repro.serving import EngineConfig, ServingEngine
    from repro.tokenizer import BPETokenizer, train_bpe
    from repro.training import checkpoint

    g = grammars.load(args.grammar)
    cfg = get_config(args.arch, smoke=True)
    if args.checkpoint:
        import os
        tok = BPETokenizer.load(os.path.join(args.checkpoint,
                                             "tokenizer.json"))
        cfg = dataclasses.replace(cfg, vocab_size=tok.vocab_size,
                                  max_seq_len=4096)
        model = build_model(cfg)
        params, _, _ = checkpoint.load(
            args.checkpoint, model.init(jax.random.PRNGKey(0)))
    else:
        corpus = GrammarSampler(g, seed=0).corpus(200)
        tok = train_bpe(corpus, vocab_size=400)
        cfg = dataclasses.replace(cfg, vocab_size=tok.vocab_size,
                                  max_seq_len=4096)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))

    if args.kernels:
        cfg = dataclasses.replace(cfg, use_pallas_kernels=True)
        model = build_model(cfg)
    ecfg = EngineConfig(
        mode=args.mode, k=(None if args.k < 0 else args.k),
        opportunistic=args.opportunistic, speculative=args.speculative,
        spec_s=args.spec_s, temperature=args.temperature,
        max_tokens=args.max_tokens)
    engine = ServingEngine(model, params, tok, g, ecfg, max_len=1024)

    prompts = ["A person encoded as a JSON object: ",
               "Results as JSON: ",
               "Config: ",
               "Data record: "][:args.prompts]
    if len(prompts) > 1:
        # continuous batching covers every arch (SSM/SWA rows are admitted
        # by exact-length prefill; speculation refeeds per row); pure
        # full-attention/MLA stacks serve from a paged KV pool
        print(f"[continuous batching: {len(prompts)} requests, "
              f"{min(len(prompts), args.slots)} slots, "
              f"{'contiguous KV' if args.no_paged else 'paged KV'}]")
        results = engine.generate_batch(
            prompts, max_batch=args.slots,
            paged=False if args.no_paged else None,
            page_size=args.page_size, n_pages=args.pool_pages)
    else:
        results = [engine.generate(p) for p in prompts]
    for p, r in zip(prompts, results):
        print(f"--- prompt: {p!r}")
        print(f"    out[{r.n_tokens} toks, {r.n_forward_passes} fwd, "
              f"{r.n_interventions} interventions, "
              f"spec {r.n_spec_accepted}/{r.n_spec_proposed}]: "
              f"{r.text[:120]!r}")


if __name__ == "__main__":
    main()
