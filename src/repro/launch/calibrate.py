"""Collective-bytes trip-count calibration.

XLA:CPU's HLO text contains each while-loop body once, so collectives
inside the scan-over-layers are counted once instead of ``reps`` times.
Fix by a two-point fit: compile the same (arch, shape) with the layer
group repeated 1x and 2x; then per op type

    bytes(R) = base + R * per_layer

and the corrected total at the real R is base + R*per_layer.  For the
encoder-decoder arch the encoder depth is scaled with R too (its real
depth equals the decoder's), keeping the fit exact.

Appends {"collectives_corrected": ..., "collective_bytes_corrected": N}
to the dry-run record JSON.

  PYTHONPATH=src python -m repro.launch.calibrate [--mesh 16x16]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import dataclasses
import json
import time

from repro.configs import ALIASES, get_config
from repro.configs.base import INPUT_SHAPES
from repro.launch.dryrun import (ART, TRAIN_MICROBATCHES, applicable,
                                 build_step, parse_collectives)
from repro.launch import sharding
from repro.launch.mesh import make_production_mesh


def with_reps(cfg, r: int):
    head, reps, group, tail = cfg.layer_program
    real = [b for b in list(head) + list(group) * r + list(tail)
            if b != "shared_attn"]
    kw = dict(group_reps=r, n_layers=len(real))
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = r
    return dataclasses.replace(cfg, **kw)


def collect(cfg, shape, mesh, microbatches):
    import jax
    from repro.models import act_sharding
    act_sharding.register_mesh(mesh)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    act_sharding.configure(dp, "model")
    fn, args, in_shard, donate = build_step(cfg, shape, mesh,
                                            microbatches=microbatches)
    named = sharding.to_named(mesh, in_shard)
    with mesh:
        compiled = jax.jit(fn, in_shardings=named,
                           donate_argnums=donate).lower(*args).compile()
    return parse_collectives(compiled.as_text())


def calibrate(arch: str, shape_name: str, multi_pod: bool = False) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, _ = applicable(cfg, shape)
    if not ok:
        return {}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mb = TRAIN_MICROBATCHES.get(arch, 1) if shape_name == "train_4k" else 1
    c1 = collect(with_reps(cfg, 1), shape, mesh, mb)
    c2 = collect(with_reps(cfg, 2), shape, mesh, mb)
    _, reps, _, _ = cfg.layer_program
    corrected = {}
    total = 0.0
    for op in c1:
        per_layer = max(0.0, c2[op]["bytes"] - c1[op]["bytes"])
        base = max(0.0, c1[op]["bytes"] - per_layer)
        val = base + per_layer * reps
        corrected[op] = {"bytes": val,
                         "count_r1": c1[op]["count"],
                         "per_layer_bytes": per_layer}
        total += val
    mesh_name = "pod2x16x16" if multi_pod else "16x16"
    rec_path = ART / f"{arch}_{shape_name}_{mesh_name}.json"
    if rec_path.exists():
        rec = json.loads(rec_path.read_text())
        rec["collectives_corrected"] = corrected
        rec["collective_bytes_corrected"] = total
        rec_path.write_text(json.dumps(rec, indent=1))
    return {"total_bytes": total, "ops": corrected}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ALIASES)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    for a in archs:
        for s in shapes:
            t0 = time.perf_counter()
            try:
                out = calibrate(a, s, args.multi_pod)
                if out:
                    print(f"[cal] {a} x {s}: "
                          f"{out['total_bytes']/2**20:.1f} MiB/device "
                          f"({time.perf_counter()-t0:.0f}s)", flush=True)
            except Exception as e:  # noqa: BLE001
                print(f"[cal-FAIL] {a} x {s}: {type(e).__name__}: "
                      f"{str(e)[:160]}", flush=True)


if __name__ == "__main__":
    main()
