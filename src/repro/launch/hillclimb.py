"""§Perf hillclimb driver: measure one (arch, shape, variant) —
memory_analysis + 2-point-corrected collective bytes + roofline terms.

  PYTHONPATH=src python -m repro.launch.hillclimb gemma3-27b decode_32k \
      --no-serve-fsdp --shard-logits --tag nofsdp_shardlogits
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import dataclasses
import json
import time

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES
from repro.launch import calibrate as cal
from repro.launch import sharding
from repro.launch.dryrun import TRAIN_MICROBATCHES, build_step, \
    parse_collectives, run_one
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline


def measure(arch: str, shape_name: str, tag: str = "base",
            microbatches: int = 0, serve_fsdp: bool = True,
            shard_logits: bool = False, kv_int8: bool = False,
            capacity_factor: float = 0.0, opt_bf16: bool = False,
            save: bool = True):
    import jax
    from repro.training import optimizer as opt
    cfg = get_config(arch)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if capacity_factor > 0 and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=capacity_factor))
    ocfg = opt.AdamWConfig(state_dtype="bfloat16") if opt_bf16 else None
    mb = microbatches or (TRAIN_MICROBATCHES.get(arch, 1)
                          if shape_name == "train_4k" else 1)
    rec = run_one(arch, shape_name, save=save, microbatches=mb,
                  cfg_override=cfg, serve_fsdp=serve_fsdp,
                  shard_logits_out=shard_logits, opt_cfg=ocfg,
                  variant=(tag if tag != "base" else ""))

    # 2-point collective correction on the SAME variant
    mesh = make_production_mesh()
    from repro.models import act_sharding
    act_sharding.register_mesh(mesh)
    act_sharding.configure(("data",), "model")

    def collect(r):
        c = cal.with_reps(cfg, r)
        built = build_step(c, INPUT_SHAPES[shape_name], mesh,
                           microbatches=mb, serve_fsdp=serve_fsdp,
                           shard_logits_out=shard_logits)
        fn, args, in_shard, donate = built[:4]
        out_shard = (sharding.to_named(mesh, built[4]) if len(built) > 4
                     else None)
        named = sharding.to_named(mesh, in_shard)
        with mesh:
            compiled = jax.jit(fn, in_shardings=named,
                               out_shardings=out_shard,
                               donate_argnums=donate).lower(*args).compile()
        return parse_collectives(compiled.as_text())

    c1, c2 = collect(1), collect(2)
    _, reps, _, _ = cfg.layer_program
    total = 0.0
    per_op = {}
    for op in c1:
        per_layer = max(0.0, c2[op]["bytes"] - c1[op]["bytes"])
        base = max(0.0, c1[op]["bytes"] - per_layer)
        per_op[op] = base + per_layer * reps
        total += per_op[op]
    rec["collective_bytes_corrected"] = total
    rec["collectives_corrected_by_op"] = per_op

    rf = roofline(arch, shape_name, "16x16", rec, coll_bytes=total,
                  cfg=cfg, replicated_weights=not serve_fsdp)
    mem = rec["memory"]
    result = {
        "tag": tag, "arch": arch, "shape": shape_name,
        "compute_ms": rf["compute_s"] * 1e3,
        "memory_ms": rf["memory_s"] * 1e3,
        "collective_ms": rf["collective_s"] * 1e3,
        "dominant": rf["dominant"],
        "useful_flops_ratio": rf["useful_flops_ratio"],
        "temp_gib": mem["temp_bytes"] / 2 ** 30,
        "arg_gib": mem["argument_bytes"] / 2 ** 30,
        "collective_bytes_per_dev": total,
        "by_op_mib": {k: v / 2 ** 20 for k, v in per_op.items() if v},
    }
    out = cal.ART.parent / "hillclimb"
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{arch}_{shape_name}_{tag}.json").write_text(
        json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--tag", default="base")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--no-serve-fsdp", action="store_true")
    ap.add_argument("--shard-logits", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--capacity", type=float, default=0.0)
    ap.add_argument("--opt-bf16", action="store_true")
    args = ap.parse_args()
    t0 = time.perf_counter()
    r = measure(args.arch, args.shape, tag=args.tag,
                microbatches=args.microbatches,
                serve_fsdp=not args.no_serve_fsdp,
                shard_logits=args.shard_logits, kv_int8=args.kv_int8,
                capacity_factor=args.capacity, opt_bf16=args.opt_bf16)
    print(json.dumps(r, indent=1))
    print(f"({time.perf_counter()-t0:.0f}s)")


if __name__ == "__main__":
    main()
