"""Distributed training driver (pjit over the production mesh).

On real hardware this runs as-is per host (jax.distributed handles the
rest); in this container it runs on the 1-device host mesh, or under
--fake-devices N for functional multi-device validation of the exact same
program that the dry-run lowers.

Example (CPU, ~20M model, grammar-synthetic JSON task):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 50 --batch 8 --seq 128
"""
import argparse
import os
import sys


def _early_flags() -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="wsd",
                    choices=["constant", "cosine", "wsd"])
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--mesh", default="host",
                    help="host | NxM (e.g. 2x4) with axes data x model")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--grammar", default="json")
    ap.add_argument("--task", action="store_true",
                    help="arithmetic-JSON task data instead of grammar LM")
    ap.add_argument("--save", default=None, help="checkpoint dir")
    args = ap.parse_args()
    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices}")
    return args


def main() -> None:
    args = _early_flags()
    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.core import grammars
    from repro.core.sampling import GrammarSampler
    from repro.launch import sharding as shr
    from repro.models import act_sharding, build_model
    from repro.tokenizer import train_bpe
    from repro.training import checkpoint, optimizer as opt
    from repro.training.data import GrammarLMDataset, TaskDataset
    from repro.training.train_loop import make_train_step

    cfg = get_config(args.arch, smoke=args.smoke)
    # right-size vocab for the in-repo tokenizer
    corpus = GrammarSampler(grammars.load(args.grammar), seed=0).corpus(300)
    tok = train_bpe(corpus, vocab_size=max(300, args.vocab - 3))
    cfg = dataclasses.replace(cfg, vocab_size=tok.vocab_size,
                              max_seq_len=max(cfg.max_seq_len, args.seq + 1))
    model = build_model(cfg)

    if args.mesh == "host":
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2,
                             devices=jax.devices()[:1])
    else:
        d, m = (int(v) for v in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2,
                             devices=jax.devices()[:d * m])
    shr.set_axis_sizes(mesh)
    act_sharding.register_mesh(mesh)
    act_sharding.configure(("data",), "model")

    rng = jax.random.PRNGKey(0)
    with mesh:
        pspec = shr.param_specs(cfg, jax.eval_shape(model.init, rng))
        params = jax.jit(
            model.init,
            out_shardings=shr.to_named(mesh, pspec))(rng)
        ocfg = opt.AdamWConfig(lr=args.lr, schedule=args.schedule,
                               total_steps=args.steps,
                               warmup_steps=max(1, args.steps // 10))
        state = opt.init_state(params)
        step_fn = make_train_step(model, ocfg)

        if args.task:
            data = TaskDataset(tok, seq_len=args.seq).batches(args.batch)
        else:
            data = GrammarLMDataset(tok, args.grammar,
                                    seq_len=args.seq).batches(args.batch)
        import time
        t0 = time.perf_counter()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            params, state, metrics = step_fn(params, state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"({time.perf_counter()-t0:.1f}s)", flush=True)
    if args.save:
        checkpoint.save(args.save, params,
                        meta={"arch": cfg.arch_id, "steps": args.steps,
                              "vocab_size": tok.vocab_size})
        tok.save(os.path.join(args.save, "tokenizer.json"))
        print(f"saved to {args.save}")


if __name__ == "__main__":
    main()
