"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) combination, builds the production
mesh, lowers the appropriate step function with ShapeDtypeStruct stand-ins
(zero allocation), compiles it, and records:

  - memory_analysis(): per-device argument/output/temp bytes (fits-check)
  - cost_analysis(): per-device HLO FLOPs + bytes accessed
  - the collective schedule: bytes moved per collective op, parsed from the
    SPMD-partitioned HLO

Shapes (assignment):
  train_4k     train_step   (B=256, S=4096)
  prefill_32k  prefill      (B=32,  S=32768)
  decode_32k   serve_step   (B=128, one token, 32k cache)
  long_500k    serve_step   (B=1,   one token, 512k cache) — sub-quadratic
               archs only (see DESIGN.md §Arch-applicability)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all 40
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod      # 2x16x16

Results append to artifacts/dryrun/<arch>_<shape>_<mesh>.json.
"""
# The VERY FIRST thing: 512 placeholder devices, before ANY jax import.
import os  # noqa: E402
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse
import json
import pathlib
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.launch import sharding
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training import optimizer as opt

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# long_500k applicability: sub-quadratic context handling only
LONG_OK = {"llava-next-mistral-7b", "gemma3-27b", "zamba2-1.2b",
           "falcon-mamba-7b"}

# gradient-accumulation defaults for train_4k (global batch 256 preserved;
# microbatching bounds per-device activation residency ~ 1/n)
TRAIN_MICROBATCHES = {
    "llava-next-mistral-7b": 8,
    "yi-34b": 8,
    "whisper-tiny": 1,
    "gemma3-27b": 8,
    "zamba2-1.2b": 8,
    "falcon-mamba-7b": 4,
    "minicpm-2b": 2,
    "stablelm-1.6b": 2,
    "arctic-480b": 8,
    "deepseek-v3-671b": 8,
}


def applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.arch_id not in LONG_OK:
        return False, ("full-attention arch: long_500k skipped per "
                       "DESIGN.md §Arch-applicability")
    return True, ""


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this workload."""
    dt = jnp.dtype(cfg.dtype)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
        if cfg.family == "vlm":
            p = cfg.n_prefix_tokens
            batch["tokens"] = jax.ShapeDtypeStruct((b, s + 1 - p), jnp.int32)
            batch["prefix"] = jax.ShapeDtypeStruct((b, p, cfg.d_model), dt)
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq_len, cfg.d_model), dt)
        return batch
    if shape.kind == "prefill":
        inputs: Dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "vlm":
            p = cfg.n_prefix_tokens
            inputs["tokens"] = jax.ShapeDtypeStruct((b, s - p), jnp.int32)
            inputs["prefix"] = jax.ShapeDtypeStruct((b, p, cfg.d_model), dt)
        if cfg.is_encoder_decoder:
            inputs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq_len, cfg.d_model), dt)
        return inputs
    # decode: ONE new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def _result_bytes(line: str) -> int:
    # "%x = (f32[..], f32[..]) all-gather(..." or "%x = f32[..] all-gather(..."
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    rhs = lhs[1]
    head = rhs.split("(", 1)[0] if rhs.startswith(("(",)) is False else rhs
    # take every shape that appears before the op name
    op_pos = min((rhs.find(c) for c in _COLLECTIVES if rhs.find(c) >= 0),
                 default=-1)
    if op_pos < 0:
        return 0
    total = 0
    for m in _SHAPE_RE.finditer(rhs[:op_pos]):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {
        c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        for c in _COLLECTIVES:
            if re.search(rf"\)?\s{c}(-start|-done)?\(", s) or f" {c}(" in s:
                if f"{c}-done" in s:
                    continue  # avoid double counting start/done pairs
                out[c]["count"] += 1
                out[c]["bytes"] += _result_bytes(s)
                break
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_step(cfg: ModelConfig, shape: InputShape, mesh,
               opt_cfg: Optional[opt.AdamWConfig] = None,
               microbatches: int = 1,
               serve_fsdp: bool = True,
               shard_logits_out: bool = False):
    """Returns (fn, args_shapes, in_shardings, donate_argnums[, out_shard])."""
    model = build_model(cfg)
    sharding.set_axis_sizes(mesh)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspec = sharding.param_specs(
        cfg, params_shape,
        fsdp=(True if shape.kind == "train" else serve_fsdp))
    ispecs = input_specs(cfg, shape)
    bspec = sharding.batch_specs(cfg, ispecs, shape.global_batch, dp)

    if shape.kind == "train":
        ocfg = opt_cfg or opt.AdamWConfig()
        opt_shape = jax.eval_shape(lambda p: opt.init_state(p, ocfg),
                                   params_shape)
        ospec = sharding.opt_state_specs(pspec)

        def train_step(params, state, batch):
            if microbatches == 1:
                (loss, _), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch)
            else:
                # gradient accumulation: same global batch, 1/n activation
                # memory; grads accumulate in f32 (one extra sharded copy)
                mb = jax.tree.map(
                    lambda x: x.reshape(
                        (microbatches, x.shape[0] // microbatches)
                        + x.shape[1:]), batch)

                def micro(acc, b):
                    (l, _), g = jax.value_and_grad(
                        model.loss, has_aux=True)(params, b)
                    return jax.tree.map(
                        lambda a, gg: a + gg.astype(jnp.float32), acc, g), l

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                grads, losses = jax.lax.scan(micro, g0, mb)
                grads = jax.tree.map(lambda g: g / microbatches, grads)
                loss = losses.mean()
            params, state, om = opt.apply_updates(params, grads, state, ocfg)
            return params, state, loss

        args = (params_shape, opt_shape, ispecs)
        shardings = (pspec, ospec, bspec)
        return train_step, args, shardings, (0, 1)

    max_len = shape.seq_len
    cache_shape = model.cache_spec(shape.global_batch, max_len)
    cspec = sharding.cache_specs(cfg, cache_shape, shape.global_batch, dp)

    if shape.kind == "prefill":
        def prefill_step(params, inputs, cache):
            return model.prefill(params, inputs, cache)
        return prefill_step, (params_shape, ispecs, cache_shape), \
            (pspec, bspec, cspec), (2,)

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    out = [serve_step, (params_shape, cache_shape, ispecs["tokens"]),
           (pspec, cspec, bspec["tokens"]), (1,)]
    if shard_logits_out:
        # keep logits vocab-sharded on the way out: the engine applies the
        # grammar mask per-shard (two-stage argmax), so gathering the full
        # (B,1,V) logits is pure waste (§Perf pair 3)
        b_ax = dp if shape.global_batch % 16 == 0 else None
        logits_spec = P(b_ax, None,
                        "model" if cfg.vocab_size % 16 == 0 else None)
        out.append((logits_spec, cspec))
    return tuple(out)


# ---------------------------------------------------------------------------
# the dry run
# ---------------------------------------------------------------------------


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            save: bool = True, opt_cfg=None,
            microbatches: int = 1,
            cfg_override: Optional[ModelConfig] = None,
            serve_fsdp: bool = True,
            shard_logits_out: bool = False,
            variant: str = "") -> Dict[str, Any]:
    cfg = cfg_override or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": cfg.arch_id, "shape": shape_name, "mesh": mesh_name,
        "n_devices": 512 if multi_pod else 256,
    }
    if not ok:
        rec["skipped"] = why
        if save:
            _save(rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models import act_sharding
    act_sharding.register_mesh(mesh)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    act_sharding.configure(dp, "model")
    built = build_step(cfg, shape, mesh, opt_cfg,
                       microbatches=microbatches, serve_fsdp=serve_fsdp,
                       shard_logits_out=shard_logits_out)
    fn, args, in_shard, donate = built[:4]
    out_shard = (sharding.to_named(mesh, built[4]) if len(built) > 4
                 else None)
    rec["microbatches"] = microbatches
    if variant:
        rec["variant"] = variant
    named = sharding.to_named(mesh, in_shard)

    t0 = time.perf_counter()
    with mesh:
        # donation mirrors production (cache updated in place; params/opt
        # buffers reused across steps) and is what makes memory_analysis
        # meaningful: without aliasing every cache write doubles the cache.
        lowered = jax.jit(fn, in_shardings=named, out_shardings=out_shard,
                          donate_argnums=donate).lower(*args)
        rec["lower_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
        }
    ca = compiled.cost_analysis()
    if ca:
        rec["cost"] = {
            "flops_per_device": float(ca.get("flops", 0.0)),
            "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        }
    rec["collectives"] = parse_collectives(compiled.as_text())
    rec["model_params"] = cfg.param_count()
    rec["model_params_active"] = cfg.active_param_count()
    if save:
        _save(rec)
    return rec


def _save(rec: Dict[str, Any]) -> None:
    ART.mkdir(parents=True, exist_ok=True)
    suffix = f"_{rec['variant']}" if rec.get("variant") else ""
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{suffix}.json"
    (ART / name.replace("/", "_")).write_text(json.dumps(rec, indent=1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id (dashed), default: all")
    ap.add_argument("--shape", default=None,
                    help="input shape name, default: all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ALIASES.keys())
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES.keys())
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                tag = f"{a} x {s} x {'2x16x16' if mp else '16x16'}"
                try:
                    t0 = time.perf_counter()
                    mb = TRAIN_MICROBATCHES.get(a, 1) if s == "train_4k" else 1
                    rec = run_one(a, s, multi_pod=mp, microbatches=mb)
                    if "skipped" in rec:
                        print(f"[skip] {tag}: {rec['skipped']}", flush=True)
                        continue
                    mem = rec.get("memory", {})
                    print(f"[ok]   {tag}: compile={rec['compile_s']:.1f}s "
                          f"flops/dev={rec['cost']['flops_per_device']:.3e} "
                          f"arg={mem.get('argument_bytes', 0)/2**30:.2f}GiB "
                          f"temp={mem.get('temp_bytes', 0)/2**30:.2f}GiB "
                          f"({time.perf_counter()-t0:.0f}s)", flush=True)
                except Exception as e:  # noqa: BLE001 — report and continue
                    n_fail += 1
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}",
                          flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} combinations failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
