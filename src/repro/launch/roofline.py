"""Roofline analysis (deliverable g).

Three terms per (arch x shape x mesh), in seconds per step:

  compute    = FLOPs / (chips * 197e12)            [bf16 MXU peak, v5e]
  memory     = HBM bytes / (chips * 819e9)
  collective = per-device collective bytes / 50e9  [ICI link]

Sources — and a backend caveat recorded here and in EXPERIMENTS.md:
``compiled.cost_analysis()`` on XLA:CPU counts every while-loop body ONCE,
and this system deliberately lowers scan-over-layers / flash-attention
scans / SSM chunk scans (that is what makes 62-layer x 32k-context
programs compile), so raw HLO FLOPs under-count by the trip counts.
Therefore:

 - FLOPs and HBM bytes come from an exact analytic op-count model of our
   own blocks (we control every matmul; the model is validated against
   cost_analysis() on scan-free configurations in tests);
 - collective bytes come from parsing the SPMD-partitioned HLO, with
   while-loop trip-count correction via a two-point fit: compile the same
   program at reps=1 and reps=2 layer groups, per-op-type
   bytes(R) = base + R * per_layer.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Dict, Optional, Tuple

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts"


# ---------------------------------------------------------------------------
# analytic FLOPs model (forward, per step, GLOBAL = all chips)
# ---------------------------------------------------------------------------


def _attn_flops(cfg: ModelConfig, tokens: int, t_ctx: float,
                window: Optional[int]) -> float:
    """One GQA attention block: projections + scores/out at avg context."""
    d, dh = cfg.d_model, cfg.d_head
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    t_eff = min(t_ctx, window) if window else t_ctx
    proj = 2 * tokens * d * (nq + 2 * nkv) * dh + 2 * tokens * nq * dh * d
    attn = 2 * 2 * tokens * nq * dh * t_eff
    return proj + attn


def _mla_flops(cfg: ModelConfig, tokens: int, t_ctx: float,
               absorbed: bool) -> float:
    m = cfg.mla
    d, nq = cfg.d_model, cfg.n_heads
    dn, dr, dv, r = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                     m.v_head_dim, m.kv_lora_rank)
    f = 2 * tokens * d * m.q_lora_rank \
        + 2 * tokens * m.q_lora_rank * nq * (dn + dr) \
        + 2 * tokens * d * (r + dr) \
        + 2 * tokens * nq * dv * d                       # wo
    if absorbed:
        f += 2 * tokens * nq * dn * r                    # q absorb
        f += 2 * tokens * nq * (r + dr) * t_ctx          # scores
        f += 2 * tokens * nq * r * t_ctx                 # ctx
        f += 2 * tokens * nq * r * dv                    # out absorb
    else:
        f += 2 * tokens * r * nq * (dn + dv)             # kv expand (own kv)
        f += 2 * 2 * tokens * nq * (dn + dr) * t_ctx     # scores+out approx
    return f


def _mlp_flops(cfg: ModelConfig, tokens: int, f_dim: int) -> float:
    return 2 * 3 * tokens * cfg.d_model * f_dim


def _moe_flops(cfg: ModelConfig, tokens: int) -> float:
    mo = cfg.moe
    d = cfg.d_model
    f = 2 * tokens * d * mo.n_experts                    # router
    f += mo.top_k * mo.capacity_factor * _mlp_flops(cfg, tokens,
                                                    mo.d_ff_expert)
    if mo.n_shared_experts:
        f += _mlp_flops(cfg, tokens, mo.d_ff_expert * mo.n_shared_experts)
    if mo.dense_residual_d_ff:
        f += _mlp_flops(cfg, tokens, mo.dense_residual_d_ff)
    # group-limited one-hot dispatch einsums: 2 * 2 * tokens * group * ...
    from repro.models.layers import MOE_GROUP_TOKENS
    cap_frac = mo.top_k * mo.capacity_factor
    f += 2 * 2 * tokens * MOE_GROUP_TOKENS * cap_frac * d / mo.n_experts \
        * mo.n_experts / MOE_GROUP_TOKENS * min(tokens, MOE_GROUP_TOKENS)
    return f


def _mamba_flops(cfg: ModelConfig, tokens: int) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    n = s.d_state
    if s.version == 1:
        dt_rank = max(1, d // 16)
        f = 2 * tokens * d * 2 * d_in                        # in_proj
        f += 2 * tokens * d_in * (dt_rank + 2 * n)           # x_proj
        f += 2 * tokens * dt_rank * d_in                     # dt_proj
        f += tokens * s.d_conv * d_in * 2                    # conv
        f += 6 * tokens * d_in * n                           # scan update
        f += 2 * tokens * d_in * n                           # y = C.h
        f += 2 * tokens * d_in * d                           # out_proj
        return f
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * n
    f = 2 * tokens * d * (2 * d_in + 2 * s.n_groups * n + nh)
    f += tokens * s.d_conv * conv_dim * 2
    # SSD: intra-chunk quadratic (chunk 128) + state passing
    from repro.models.ssm import CHUNK
    lc = min(CHUNK, tokens)
    f += 2 * tokens * lc * nh * (n + s.head_dim)         # cb + y_intra
    f += 4 * tokens * nh * s.head_dim * n                # state update + read
    f += 2 * tokens * d_in * d                           # out_proj
    return f


def _block_flops(cfg: ModelConfig, kind: str, tokens: int, t_ctx: float,
                 decode: bool) -> float:
    if kind in ("attn", "shared_attn"):
        return _attn_flops(cfg, tokens, t_ctx, None) \
            + _mlp_flops(cfg, tokens, cfg.d_ff)
    if kind == "swa":
        return _attn_flops(cfg, tokens, t_ctx, cfg.sliding_window) \
            + _mlp_flops(cfg, tokens, cfg.d_ff)
    if kind == "xattn":
        return _attn_flops(cfg, tokens, t_ctx, None) \
            + _attn_flops(cfg, tokens, cfg.encoder_seq_len, None) \
            + _mlp_flops(cfg, tokens, cfg.d_ff)
    if kind == "mla":
        return _mla_flops(cfg, tokens, t_ctx, absorbed=decode) \
            + _mlp_flops(cfg, tokens, cfg.d_ff)
    if kind == "moe":
        attn = (_mla_flops(cfg, tokens, t_ctx, absorbed=decode)
                if cfg.mla else _attn_flops(cfg, tokens, t_ctx, None))
        return attn + _moe_flops(cfg, tokens)
    if kind in ("mamba1", "mamba2"):
        return _mamba_flops(cfg, tokens)
    raise ValueError(kind)


def flops_model(cfg: ModelConfig, shape: InputShape) -> Dict[str, float]:
    """Global forward FLOPs for one step of this workload + train factor."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens, t_ctx, decode = b * s, s / 2.0, False
    elif shape.kind == "prefill":
        tokens, t_ctx, decode = b * s, s / 2.0, False
    else:
        tokens, t_ctx, decode = b * 1, float(s), True
    head, reps, group, tail = cfg.layer_program
    blocks = list(head) + list(group) * reps + list(tail)
    f_blocks = sum(_block_flops(cfg, k, tokens, t_ctx, decode)
                   for k in blocks)
    if cfg.is_encoder_decoder and shape.kind != "decode":
        enc_tokens = b * cfg.encoder_seq_len
        f_blocks += cfg.n_encoder_layers * (
            _attn_flops(cfg, enc_tokens, cfg.encoder_seq_len / 2, None)
            + _mlp_flops(cfg, enc_tokens, cfg.d_ff))
    logits = 2 * tokens * cfg.d_model * cfg.vocab_size
    fwd = f_blocks + logits
    # train: bwd = 2x fwd, full remat adds ~1x fwd recompute
    factor = 4.0 if shape.kind == "train" else 1.0
    useful = (6.0 if shape.kind == "train" else 2.0) \
        * cfg.active_param_count() * tokens
    return {"fwd": fwd, "total": fwd * factor, "model_flops_6nd": useful}


# ---------------------------------------------------------------------------
# analytic HBM bytes model (GLOBAL)
# ---------------------------------------------------------------------------


def bytes_model(cfg: ModelConfig, shape: InputShape) -> Dict[str, float]:
    b, s = shape.global_batch, shape.seq_len
    dt = 2  # bf16
    p_bytes = cfg.param_count() * dt
    d = cfg.d_model
    head, reps, group, tail = cfg.layer_program
    blocks = list(head) + list(group) * reps + list(tail)
    n_layers = len(blocks)
    if shape.kind == "decode":
        tokens = b
        # params read once per step + cache read + write
        cache_r = _cache_bytes(cfg, b, s)
        act = tokens * d * n_layers * 8 * dt
        total = p_bytes + cache_r["read"] + cache_r["write"] + act
        return {"total": total, "params": p_bytes, **cache_r}
    tokens = b * s
    # per layer: ~6 (B,S,D)-sized reads/writes for matmul IO, plus flash
    # K/V re-reads: (T * kv_width) per q block of 512
    act = tokens * d * n_layers * 6 * dt
    kv_width = 2 * cfg.n_kv_heads * cfg.d_head
    flash_rereads = n_layers * b * (s / 512.0) * s * kv_width * dt * 0.5
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd+bwd+remat sweeps
    p_traffic = p_bytes * (3.0 if shape.kind == "train" else 1.0)
    if shape.kind == "train":
        p_traffic += cfg.param_count() * (4 + 16)  # grads f32? bf16 + m/v f32
    total = p_traffic + (act + flash_rereads) * mult
    return {"total": total, "params": p_traffic, "activations": act * mult,
            "flash_rereads": flash_rereads * mult}


def _cache_bytes(cfg: ModelConfig, b: int, t: int) -> Dict[str, float]:
    dt = 1.02 if cfg.kv_cache_dtype == "int8" else 2  # int8 + 2B/dh scales
    head, reps, group, tail = cfg.layer_program
    blocks = list(head) + list(group) * reps + list(tail)
    read = write = 0.0
    for k in blocks:
        if k in ("attn", "shared_attn", "xattn"):
            read += b * t * 2 * cfg.n_kv_heads * cfg.d_head * dt
            write += b * 2 * cfg.n_kv_heads * cfg.d_head * dt
            if k == "xattn":
                read += b * cfg.encoder_seq_len * 2 * cfg.n_kv_heads \
                    * cfg.d_head * dt
        elif k == "swa":
            w = min(cfg.sliding_window or t, t)
            read += b * w * 2 * cfg.n_kv_heads * cfg.d_head * dt
            write += b * 2 * cfg.n_kv_heads * cfg.d_head * dt
        elif k == "mla" or (k == "moe" and cfg.mla):
            m = cfg.mla
            read += b * t * (m.kv_lora_rank + m.qk_rope_head_dim) * dt
            write += b * (m.kv_lora_rank + m.qk_rope_head_dim) * dt
        elif k == "moe":
            read += b * t * 2 * cfg.n_kv_heads * cfg.d_head * dt
            write += b * 2 * cfg.n_kv_heads * cfg.d_head * dt
        if k in ("mamba1", "mamba2"):
            sscfg = cfg.ssm
            d_in = sscfg.expand * cfg.d_model
            if sscfg.version == 1:
                st = d_in * sscfg.d_state * 4
            else:
                st = (d_in // sscfg.head_dim) * sscfg.head_dim \
                    * sscfg.d_state * 4
            read += b * st
            write += b * st
    return {"read": read, "write": write}


# ---------------------------------------------------------------------------
# report assembly
# ---------------------------------------------------------------------------


def load_dryrun(arch: str, shape: str, mesh: str = "16x16"
                ) -> Optional[Dict[str, Any]]:
    p = ART / "dryrun" / f"{arch}_{shape}_{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def collective_bytes_per_device(rec: Dict[str, Any],
                                rec_r1: Optional[Dict[str, Any]] = None,
                                rec_r2: Optional[Dict[str, Any]] = None,
                                reps: int = 1) -> float:
    """Total collective bytes, trip-count corrected when the 2-point
    calibration records are available."""
    def total(r):
        return sum(v["bytes"] for v in r["collectives"].values())
    if rec_r1 is None or rec_r2 is None:
        return float(total(rec))
    b1, b2 = total(rec_r1), total(rec_r2)
    per_layer = max(0.0, b2 - b1)
    base = max(0.0, b1 - per_layer)
    return float(base + per_layer * reps)


def roofline(arch: str, shape_name: str, mesh: str = "16x16",
             rec: Optional[Dict[str, Any]] = None,
             coll_bytes: Optional[float] = None,
             cfg: Optional[ModelConfig] = None,
             replicated_weights: bool = False) -> Dict[str, Any]:
    from repro.configs import get_config
    cfg = cfg or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    chips = 512 if mesh.startswith("pod") else 256
    rec = rec or load_dryrun(arch, shape_name, mesh)
    fl = flops_model(cfg, shape)
    by = bytes_model(cfg, shape)
    if replicated_weights and shape.kind == "decode":
        # weights replicated over the data axis: every device reads its own
        # full (model-sharded) copy -> global traffic = chips/model * params
        m_shards = 16
        extra = cfg.param_count() * 2 * (chips / m_shards) \
            - cfg.param_count() * 2
        by = dict(by)
        by["total"] += extra
        by["params_replicated_extra"] = extra
    if coll_bytes is None:
        coll_bytes = (sum(v["bytes"] for v in rec["collectives"].values())
                      if rec and "collectives" in rec else 0.0)
    compute_t = fl["total"] / (chips * PEAK_FLOPS_BF16)
    memory_t = by["total"] / (chips * HBM_BW)
    coll_t = coll_bytes / ICI_BW           # parsed bytes are per-device
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh, "chips": chips,
        **terms,
        "dominant": dominant,
        "flops_total": fl["total"],
        "model_flops_6nd": fl["model_flops_6nd"],
        "useful_flops_ratio": fl["model_flops_6nd"] / fl["total"],
        "hbm_bytes": by["total"],
        "collective_bytes_per_device": coll_bytes,
        "hlo_flops_per_device_raw": (rec or {}).get(
            "cost", {}).get("flops_per_device"),
        "memory_per_device": (rec or {}).get("memory"),
    }


SUGGESTIONS = {
    "compute_s": ("compute-bound: raise MXU utilization — larger per-device "
                  "batch/seq tiles, fuse small matmuls, drop remat factor "
                  "with selective checkpointing"),
    "memory_s": ("HBM-bound: cut bytes/step — quantize KV cache, shrink the "
                 "cache via MLA/window, fuse mask+sample (no masked-logit "
                 "round trip), increase arithmetic intensity per pass"),
    "collective_s": ("collective-bound: reshard to cut cross-chip bytes — "
                     "avoid FSDP weight gathers on the decode path, overlap "
                     "collectives with compute, move experts fully onto "
                     "the model axis"),
}


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    from repro.configs import ALIASES
    rows = []
    for arch in ALIASES:
        for shape in INPUT_SHAPES:
            rec = load_dryrun(arch, shape, args.mesh)
            if rec is None or "skipped" in rec:
                continue
            rows.append(roofline(arch, shape, args.mesh, rec))
    rows.sort(key=lambda r: -max(r["compute_s"], r["memory_s"],
                                 r["collective_s"]))
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collective':>11s} dominant")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['compute_s']*1e3:9.2f}ms {r['memory_s']*1e3:9.2f}ms "
              f"{r['collective_s']*1e3:10.2f}ms {r['dominant']}")
    out = ART / "roofline.json"
    out.write_text(json.dumps(rows, indent=1))
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
