"""Production meshes.

Single pod: (data=16, model=16) = 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis extends
data parallelism (weights replicated across pods; gradients cross pods
once per step).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count
BEFORE any jax import (see dryrun.py).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — see EXPERIMENTS.md §Roofline
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devs)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_"
            "count=512 before any jax import")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        devices=devs[:n])


def make_host_mesh():
    """1-device mesh for smoke tests / local examples."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2,
                         devices=jax.devices()[:1])


def data_axes(mesh) -> tuple:
    """The batch-sharding axes for this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
