"""Sharding rules: param/cache/batch pytrees -> PartitionSpec pytrees.

Strategy (MaxText-flavoured 2D):
 - ``model`` axis: tensor/expert parallelism — attention heads & d_ff
   columns, MoE experts, mamba channels, vocab (embedding/lm_head).
 - ``data`` axis (x ``pod``): batch for activations; FSDP for weights —
   the second weight dim shards over ``data`` so per-device parameter
   memory scales with the FULL chip count (671B-class models fit).
 - scanned-group stacking dim (leading ``reps`` axis) is never sharded.

Caches: batch over data axes when divisible; KV heads over ``model`` when
divisible, else the sequence dim (context sharding — exact for decode
since softmax/all-reduce compose; XLA SPMD inserts the collectives).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


# -- parameter rules: (last-key name, rank-without-stacking) -> spec tail ----

_MATRIX_RULES = {
    # attention
    "wq": P("data", "model"),
    "wk": P("data", "model"),
    "wv": P("data", "model"),
    "wo": P("model", "data"),
    # mlp
    "w_gate": P("data", "model"),
    "w_up": P("data", "model"),
    "w_down": P("model", "data"),
    # mla
    "wq_a": P("data", None),
    "wq_b": P(None, "model"),
    "wkv_a": P("data", None),
    "wkv_b": P(None, "model"),
    # moe router
    "router": P("data", None),
    # mamba
    "in_proj": P("data", "model"),
    "z_proj": P("data", "model"),
    "xbc_proj": P("data", "model"),
    "dt_in_proj": P("data", "model"),
    "x_proj": P("model", None),
    "dt_proj": P(None, "model"),
    "conv_w": P(None, "model"),
    "A_log": P("model", None),
    "out_proj": P("model", "data"),
}

_EXPERT_RULES = {  # rank-3 (E, d, f) MoE expert weights
    "w_gate": P("model", None, "data"),
    "w_up": P("model", None, "data"),
    "w_down": P("model", "data", None),
}

_VECTOR_RULES = {
    "conv_b": P("model"),
    "dt_bias": P("model"),
    "D": P("model"),
    "scale": P(),        # norms replicated
}


def _spec_for_leaf(path, leaf) -> P:
    keys = [getattr(k, "key", None) for k in path]
    name = next((k for k in reversed(keys) if isinstance(k, str)), "")
    stacked = ("group" in keys) or ("blocks" in keys)
    nd = leaf.ndim - (1 if stacked else 0)

    if name == "embed":
        # vocab-parallel only: FSDP'ing D here puts the contraction dim of
        # the (tied) logits matmul on 'data', which conflicts with the
        # model-axis activations and makes SPMD gather full-batch logits.
        spec = P("model", None)
    elif name == "lm_head":
        spec = P(None, "model")
    elif nd == 3 and name in _EXPERT_RULES:
        spec = _EXPERT_RULES[name]
    elif nd == 2 and name in _MATRIX_RULES:
        spec = _MATRIX_RULES[name]
    elif nd == 1 and name in _VECTOR_RULES:
        spec = _VECTOR_RULES[name]
    elif nd <= 1:
        spec = P()
    else:
        spec = P(*([None] * nd))
    if stacked:
        spec = P(None, *spec)
    # divisibility guard: drop axes that do not divide the dim
    return _guard(spec, leaf.shape)


def _guard(spec: P, shape: Tuple[int, ...]) -> P:
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        size = _axis_size(ax)
        fixed.append(ax if dim % size == 0 else None)
    return P(*fixed)


_AXIS_SIZES = {"data": 16, "model": 16, "pod": 2}


def _axis_size(ax) -> int:
    if isinstance(ax, (tuple, list)):
        s = 1
        for a in ax:
            s *= _AXIS_SIZES.get(a, 1)
        return s
    return _AXIS_SIZES.get(ax, 1)


def set_axis_sizes(mesh) -> None:
    """Record actual mesh axis sizes for the divisibility guard."""
    global _AXIS_SIZES
    _AXIS_SIZES = dict(zip(mesh.axis_names, mesh.devices.shape))


def param_specs(cfg: ModelConfig, params_shape, fsdp: bool = True) -> Any:
    """PartitionSpec pytree matching a params (shape) pytree.

    ``fsdp=False`` (serve mode) drops the 'data' axis from weight specs:
    weights replicate across the data axis instead of being gathered every
    step — on the decode path the per-step all-gather of FSDP-sharded
    weights dwarfs every other term (§Perf pair 1).  Use fsdp=True for
    training (parameters + optimizer state must scale with all chips).
    """
    tree = jax.tree_util.tree_map_with_path(_spec_for_leaf, params_shape)

    def drop_axis(tree, axis):
        def fix(spec):
            return P(*((None if ax == axis or (isinstance(ax, tuple)
                                               and axis in ax) else ax)
                       for ax in tuple(spec)))
        return jax.tree.map(fix, tree,
                            is_leaf=lambda x: isinstance(x, P))

    if not cfg.tensor_parallel:
        # keep the (padded-) vocab dimension model-sharded even when block
        # weights replicate: the (B,S,V) logits are the fat tensors of a
        # small-width model (whisper: 12.7 GiB/copy unsharded)
        def drop_model_except_vocab(path, spec):
            keys = [getattr(k, "key", None) for k in path]
            name = next((k for k in reversed(keys) if isinstance(k, str)),
                        "")
            if name in ("embed", "lm_head"):
                return spec
            return P(*((None if ax == "model" or (isinstance(ax, tuple)
                                                  and "model" in ax)
                        else ax) for ax in tuple(spec)))
        tree = jax.tree_util.tree_map_with_path(
            drop_model_except_vocab, tree,
            is_leaf=lambda x: isinstance(x, P))
    if not fsdp:
        tree = drop_axis(tree, "data")
    return tree


# -- caches --------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, cache_shape, batch: int,
                dp: Tuple[str, ...]) -> Any:
    """PartitionSpec pytree for a decode cache."""
    dp_size = _axis_size(tuple(dp))
    b_ax = tuple(dp) if batch % dp_size == 0 and batch >= dp_size else None
    m_size = _AXIS_SIZES.get("model", 1)

    def leaf(path, s):
        keys = [getattr(k, "key", None) for k in path]
        name = next((k for k in reversed(keys) if isinstance(k, str)), "")
        stacked = "group" in keys
        shape = s.shape[1:] if stacked else s.shape
        if name == "len":
            spec = P()
        elif name == "pos":                      # (B, W) slot->position map
            spec = P(b_ax, None)
        elif name in ("k", "v", "xk", "xv"):     # (B, T, nkv, dh)
            nkv = shape[2]
            t = shape[1]
            if nkv % m_size == 0:
                spec = P(b_ax, None, "model", None)
            elif t % m_size == 0:
                spec = P(b_ax, "model", None, None)
            else:
                spec = P(b_ax, None, None, None)
        elif name in ("k_scale", "v_scale"):     # (B, T, nkv)
            nkv = shape[2]
            t = shape[1]
            if nkv % m_size == 0:
                spec = P(b_ax, None, "model")
            elif t % m_size == 0:
                spec = P(b_ax, "model", None)
            else:
                spec = P(b_ax, None, None)
        elif name == "ckv":                      # (B, T, r)
            spec = P(b_ax, "model" if shape[1] % m_size == 0 else None, None)
        elif name == "krope":                    # (B, T, 1, dr)
            spec = P(b_ax, "model" if shape[1] % m_size == 0 else None,
                     None, None)
        elif name == "conv":                     # (B, K-1, C)
            spec = P(b_ax, None,
                     "model" if shape[2] % m_size == 0 else None)
        elif name == "ssm":                      # (B, d, N) | (B, nh, hd, N)
            spec = P(b_ax,
                     "model" if shape[1] % m_size == 0 else None,
                     *([None] * (len(shape) - 2)))
        else:
            spec = P(*([None] * len(shape)))
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


def batch_specs(cfg: ModelConfig, batch_shape, batch: int,
                dp: Tuple[str, ...]) -> Any:
    dp_size = _axis_size(tuple(dp))
    b_ax = tuple(dp) if batch % dp_size == 0 and batch >= dp_size else None

    def leaf(path, s):
        return P(b_ax, *([None] * (len(s.shape) - 1)))

    return jax.tree_util.tree_map_with_path(leaf, batch_shape)


def opt_state_specs(param_spec_tree) -> Dict[str, Any]:
    """AdamW m/v shard exactly like the params (ZeRO-style)."""
    return {"m": param_spec_tree, "v": param_spec_tree,
            "step": jax.sharding.PartitionSpec()}


def to_named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
