"""Pure-jnp oracle for the selective-scan kernel (direct recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(dt, x, bmat, cmat, a, h0):
    """Same signature as the kernel: dt/x (B,S,d); bmat/cmat (B,S,N);
    a (d,N); h0 (B,d,N) -> (y (B,S,d), hT (B,d,N)), fp32."""
    dt = dt.astype(jnp.float32)
    x = x.astype(jnp.float32)
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)
    a = a.astype(jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp
        a_bar = jnp.exp(dt_t[:, :, None] * a[None])        # (B,d,N)
        h = a_bar * h + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y_t

    xs = (dt.swapaxes(0, 1), x.swapaxes(0, 1),
          bmat.swapaxes(0, 1), cmat.swapaxes(0, 1))
    h_t, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.swapaxes(0, 1), h_t
