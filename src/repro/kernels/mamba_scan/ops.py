"""Jitted public wrapper for the Mamba1 selective scan."""
from __future__ import annotations

import jax

from repro.kernels.mamba_scan.kernel import mamba_scan_pallas
from repro.kernels.mamba_scan.ref import mamba_scan_ref


def mamba_scan(dt, x, bmat, cmat, a, h0, use_ref: bool = False,
               block_d: int = 512, block_s: int = 128):
    if use_ref:
        return mamba_scan_ref(dt, x, bmat, cmat, a, h0)
    on_tpu = jax.default_backend() == "tpu"
    return mamba_scan_pallas(dt, x, bmat, cmat, a, h0, block_d=block_d,
                             block_s=block_s, interpret=not on_tpu)
