"""Selective-scan (Mamba1) kernel (Pallas TPU).

The CUDA selective-scan kernel is re-thought for TPU (DESIGN.md §3): the
recurrence h_t = exp(dt_t*A) h_{t-1} + (dt_t x_t) B_t is *sequential in
time but dense in (channels x state)* — so the kernel keeps a
(BLOCK_D, N) state tile resident in VMEM and walks the sequence with a
``fori_loop``, vectorizing each step over channels and state on the VPU.
The (B, S, d, N) discretized tensor that the pure-jnp path materializes in
HBM never exists here: a_bar / b_bar are formed in-register per time step.

Grid: (B, d/BLOCK_D, S/BLOCK_S), S minor => VMEM scratch h carries across
sequence tiles of one (batch, channel-block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hT_ref,
            h_scr, *, block_s: int, n_sblocks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    dt = dt_ref[0].astype(jnp.float32)       # (BS, BD)
    xs = x_ref[0].astype(jnp.float32)        # (BS, BD)
    bm = b_ref[0].astype(jnp.float32)        # (BS, N)
    cm = c_ref[0].astype(jnp.float32)        # (BS, N)
    a = a_ref[...].astype(jnp.float32)       # (BD, N)

    def step(t, carry):
        h, y = carry
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]      # (BD,)
        x_t = jax.lax.dynamic_slice_in_dim(xs, t, 1, 0)[0]       # (BD,)
        b_t = jax.lax.dynamic_slice_in_dim(bm, t, 1, 0)[0]       # (N,)
        c_t = jax.lax.dynamic_slice_in_dim(cm, t, 1, 0)[0]       # (N,)
        a_bar = jnp.exp(dt_t[:, None] * a)                       # (BD, N)
        h = a_bar * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=-1)                 # (BD,)
        y = jax.lax.dynamic_update_slice_in_dim(y, y_t[None], t, 0)
        return h, y

    y0 = jnp.zeros(dt.shape, jnp.float32)
    h, y = jax.lax.fori_loop(0, block_s, step, (h_scr[...], y0))
    h_scr[...] = h
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(j == n_sblocks - 1)
    def _done():
        hT_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("block_d", "block_s",
                                             "interpret"))
def mamba_scan_pallas(dt, x, bmat, cmat, a, h0, block_d: int = 512,
                      block_s: int = 128, interpret: bool = True):
    """dt/x (B,S,d); bmat/cmat (B,S,N); a (d,N); h0 (B,d,N)
    -> y (B,S,d) fp32, hT (B,d,N) fp32."""
    b, s, d = dt.shape
    n = a.shape[1]
    if d % block_d != 0:
        block_d = d
    if s % block_s != 0:
        block_s = s
    nd, ns = d // block_d, s // block_s
    kernel = functools.partial(_kernel, block_s=block_s, n_sblocks=ns)
    y, h_t = pl.pallas_call(
        kernel,
        grid=(b, nd, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda i, g, j: (i, j, g)),
            pl.BlockSpec((1, block_s, block_d), lambda i, g, j: (i, j, g)),
            pl.BlockSpec((1, block_s, n), lambda i, g, j: (i, j, 0)),
            pl.BlockSpec((1, block_s, n), lambda i, g, j: (i, j, 0)),
            pl.BlockSpec((block_d, n), lambda i, g, j: (g, 0)),
            pl.BlockSpec((1, block_d, n), lambda i, g, j: (i, g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda i, g, j: (i, j, g)),
            pl.BlockSpec((1, block_d, n), lambda i, g, j: (i, g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, bmat, cmat, a, h0)
    return y, h_t
