"""Pure-jnp oracle for the Mamba2 SSD chunked scan (single group, g=1).

Inputs (fp32):
  x   (B, S, H, D)   gated inputs (already dt-scaled happens inside)
  b   (B, S, N)      input projections (shared across heads, g=1)
  c   (B, S, N)      output projections
  ld  (B, S, H)      log decay  (dt * A, <= 0)
  dt  (B, S, H)      step sizes
  h0  (B, H, D, N)   incoming state
Outputs: y (B, S, H, D) fp32, hT (B, H, D, N) fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, b, c, ld, dt, h0, chunk: int = 64):
    bsz, s, h, d = x.shape
    n = b.shape[-1]
    nc = max(1, s // chunk)
    assert s % nc == 0
    lc = s // nc

    def resh(t, extra):
        return t.reshape((bsz, nc, lc) + extra).swapaxes(0, 1)

    xs = resh(x.astype(jnp.float32), (h, d))
    bc = resh(b.astype(jnp.float32), (n,))
    cc = resh(c.astype(jnp.float32), (n,))
    ldc = resh(ld.astype(jnp.float32), (h,))
    dtc = resh(dt.astype(jnp.float32), (h,))

    def step(hst, inp):
        xc, bch, cch, ldch, dtch = inp
        cum = jnp.cumsum(ldch, axis=1)                       # (B,lc,H)
        cb = jnp.einsum("bin,bjn->bij", cch, bch)            # (B,lc,lc)
        dmat = cum.transpose(0, 2, 1)[:, :, :, None] - \
            cum.transpose(0, 2, 1)[:, :, None, :]            # (B,H,i,j)
        mask = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), bool))
        w = cb[:, None] * jnp.where(mask, jnp.exp(dmat), 0.0)
        xdt = xc * dtch[..., None]                           # (B,lc,H,D)
        y_intra = jnp.einsum("bhij,bjhd->bihd", w, xdt)
        y_state = jnp.einsum("bin,bhdn->bihd", cch, hst) \
            * jnp.exp(cum)[..., None]
        total = cum[:, -1]                                   # (B,H)
        rev = jnp.exp(total[:, None] - cum)                  # (B,lc,H)
        h_new = hst * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bjhd,bjn,bjh->bhdn", xdt, bch, rev)
        return h_new, y_intra + y_state

    h_t, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                           (xs, bc, cc, ldc, dtc))
    y = ys.swapaxes(0, 1).reshape(bsz, s, h, d)
    return y, h_t
