"""Jitted public wrapper for the Mamba2 SSD chunked scan."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def ssd_scan(x, b, c, ld, dt, h0, use_ref: bool = False,
             block_h: int = 4, chunk: int = 64):
    if use_ref:
        return ssd_scan_ref(x, b, c, ld, dt, h0, chunk=chunk)
    on_tpu = jax.default_backend() == "tpu"
    return ssd_scan_pallas(x, b, c, ld, dt, h0, block_h=block_h,
                           chunk=chunk, interpret=not on_tpu)
