"""Mamba2 SSD chunked scan (Pallas TPU).

The SSD block-decomposition is the TPU-native form of the Mamba2
recurrence (DESIGN.md §3): *within* a chunk the output is a masked,
decay-weighted (lc x lc) attention-like matmul — MXU work — and *between*
chunks only the (H, D, N) state is carried.  The kernel keeps that state
in VMEM scratch across sequence tiles, so the only HBM traffic is the
inputs once and the outputs once; the (B, S, H, D, N) discretized tensor
of the naive formulation never exists.

Grid (B, H/BLOCK_H, S/CHUNK), sequence minor.  VMEM per step:
CHUNK*(BLOCK_H*D + 2N) input halves + (CHUNK x CHUNK) weight tile +
(BLOCK_H, D, N) state — ~1 MiB at CHUNK=64, BLOCK_H=4, D=64, N=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, b_ref, c_ref, ld_ref, dt_ref, h0_ref, y_ref, hT_ref,
            h_scr, *, n_chunks: int, chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    xc = x_ref[0].astype(jnp.float32)       # (lc, BH, D)
    bc = b_ref[0].astype(jnp.float32)       # (lc, N)
    cc = c_ref[0].astype(jnp.float32)       # (lc, N)
    ldc = ld_ref[0].astype(jnp.float32)     # (lc, BH)
    dtc = dt_ref[0].astype(jnp.float32)     # (lc, BH)
    h = h_scr[...]                           # (BH, D, N)

    cum = jnp.cumsum(ldc, axis=0)            # (lc, BH)
    cb = jnp.dot(cc, bc.T)                   # (lc, lc) — g=1, head-shared
    dmat = cum.T[:, :, None] - cum.T[:, None, :]          # (BH, i, j)
    mask = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    w = cb[None, :, :] * jnp.where(mask[None], jnp.exp(dmat), 0.0)
    xdt = xc * dtc[..., None]                # (lc, BH, D)
    y_intra = jnp.einsum("hij,jhd->ihd", w, xdt)
    y_state = jnp.einsum("in,hdn->ihd", cc, h) \
        * jnp.exp(cum)[..., None]
    y_ref[0] = (y_intra + y_state).astype(y_ref.dtype)

    total = cum[-1]                          # (BH,)
    rev = jnp.exp(total[None, :] - cum)      # (lc, BH)
    h_scr[...] = h * jnp.exp(total)[:, None, None] + jnp.einsum(
        "jhd,jn,jh->hdn", xdt, bc, rev)

    @pl.when(j == n_chunks - 1)
    def _done():
        hT_ref[0] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("block_h", "chunk",
                                             "interpret"))
def ssd_scan_pallas(x, b, c, ld, dt, h0, block_h: int = 4, chunk: int = 64,
                    interpret: bool = True):
    """x (B,S,H,D); b,c (B,S,N); ld,dt (B,S,H); h0 (B,H,D,N)
    -> (y (B,S,H,D) fp32, hT (B,H,D,N) fp32)."""
    bsz, s, h, d = x.shape
    n = b.shape[-1]
    if h % block_h != 0:
        block_h = h
    if s % chunk != 0:
        chunk = s
    nh, nc = h // block_h, s // chunk
    kernel = functools.partial(_kernel, n_chunks=nc, chunk=chunk)
    y, h_t = pl.pallas_call(
        kernel,
        grid=(bsz, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_h, d), lambda i, g, j: (i, j, g, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, g, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, g, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, block_h), lambda i, g, j: (i, j, g)),
            pl.BlockSpec((1, chunk, block_h), lambda i, g, j: (i, j, g)),
            pl.BlockSpec((1, block_h, d, n), lambda i, g, j: (i, g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_h, d), lambda i, g, j: (i, j, g, 0)),
            pl.BlockSpec((1, block_h, d, n), lambda i, g, j: (i, g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_h, d, n), jnp.float32)],
        interpret=interpret,
    )(x, b, c, ld, dt, h0)
    return y, h_t
