"""Pure-jnp oracle for the fused masked-argmax kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = jnp.float32(-1e30)
WORD_BITS = 32


def unpack_bits(bits: jnp.ndarray, v: int) -> jnp.ndarray:
    """Packed (..., ceil(v/32)) uint32 -> bool (..., v) (bitmask layout:
    bit b of word w, LSB first, is token w*32+b)."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    expanded = (bits[..., :, None] >> shifts) & jnp.uint32(1)
    flat = expanded.reshape(bits.shape[:-1]
                            + (bits.shape[-1] * WORD_BITS,))
    return flat[..., :v] != 0


def masked_argmax_ref(logits: jnp.ndarray, mask: jnp.ndarray):
    """logits (B, V), mask (B, V) int8/bool or packed (B, ceil(V/32))
    uint32 -> (idx (B,) int32, val (B,) float32).

    The unfused baseline: materializes the masked logits then reduces.
    """
    mask = jnp.asarray(mask)
    if mask.dtype == jnp.uint32:
        mask = unpack_bits(mask, logits.shape[-1])
    masked = jnp.where(mask != 0, logits.astype(jnp.float32), NEG)
    idx = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    val = jnp.max(masked, axis=-1)
    return idx, val
