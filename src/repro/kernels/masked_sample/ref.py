"""Pure-jnp oracle for the fused masked-argmax kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = jnp.float32(-1e30)


def masked_argmax_ref(logits: jnp.ndarray, mask: jnp.ndarray):
    """logits (B, V), mask (B, V) -> (idx (B,) int32, val (B,) float32).

    The unfused baseline: materializes the masked logits then reduces.
    """
    masked = jnp.where(mask != 0, logits.astype(jnp.float32), NEG)
    idx = jnp.argmax(masked, axis=-1).astype(jnp.int32)
    val = jnp.max(masked, axis=-1)
    return idx, val
