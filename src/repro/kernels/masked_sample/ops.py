"""Jitted public wrapper: fused grammar-masked argmax.

``masked_argmax(logits, mask)`` dispatches to the Pallas kernel on TPU and
to the interpreted kernel (CPU validation) elsewhere; ``use_ref=True``
selects the unfused jnp oracle (the baseline the §Perf analysis compares
against).

The mask operand picks the kernel layout by dtype: uint32 means a packed
``(B, ceil(V/32))`` bitset row (``core/bitmask.py`` wire format, unpacked
in-register by the kernel); anything else is the legacy ``(B, V)``
int8/bool mask.  Both layouts are bitwise-identical in output — asserted
by the parity tests and by ``benchmarks/mask_bench.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.masked_sample.kernel import (masked_argmax_pallas,
                                                masked_argmax_pallas_packed)
from repro.kernels.masked_sample.ref import masked_argmax_ref


def masked_argmax(logits, mask, use_ref: bool = False, block_v: int = 2048):
    if use_ref:
        return masked_argmax_ref(logits, mask)
    on_tpu = jax.default_backend() == "tpu"
    if jnp.asarray(mask).dtype == jnp.uint32:
        return masked_argmax_pallas_packed(logits, mask, block_v=block_v,
                                           interpret=not on_tpu)
    return masked_argmax_pallas(logits, mask, block_v=block_v,
                                interpret=not on_tpu)
