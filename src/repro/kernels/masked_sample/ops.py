"""Jitted public wrapper: fused grammar-masked argmax.

``masked_argmax(logits, mask)`` dispatches to the Pallas kernel on TPU and
to the interpreted kernel (CPU validation) elsewhere; ``use_ref=True``
selects the unfused jnp oracle (the baseline the §Perf analysis compares
against).
"""
from __future__ import annotations

import jax

from repro.kernels.masked_sample.kernel import masked_argmax_pallas
from repro.kernels.masked_sample.ref import masked_argmax_ref


def masked_argmax(logits, mask, use_ref: bool = False, block_v: int = 2048):
    if use_ref:
        return masked_argmax_ref(logits, mask)
    on_tpu = jax.default_backend() == "tpu"
    return masked_argmax_pallas(logits, mask, block_v=block_v,
                                interpret=not on_tpu)
