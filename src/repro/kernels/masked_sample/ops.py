"""Jitted public wrappers: fused grammar-masked argmax + masked sampling.

``masked_argmax(logits, mask)`` dispatches to the Pallas kernel on TPU and
to the interpreted kernel (CPU validation) elsewhere; ``use_ref=True``
selects the unfused jnp oracle (the baseline the §Perf analysis compares
against).

The mask operand picks the kernel layout by dtype: uint32 means a packed
``(B, ceil(V/32))`` bitset row (``core/bitmask.py`` wire format, unpacked
in-register by the kernel); anything else is the legacy ``(B, V)``
int8/bool mask.  Both layouts are bitwise-identical in output — asserted
by the parity tests and by ``benchmarks/mask_bench.py``.

``masked_sample_packed(logits, bits, temps, keys)`` is the device-side
temperature>0 selection path (ISSUE 8 satellite): masked softmax sampling
via the Gumbel-max identity, with PER-ROW temperature and per-row
counter-based PRNG keys, so sampled rows stop selecting host-side.  It
matches the host ``select_token`` path in DISTRIBUTION (softmax over
``logits/T`` restricted to the mask — asserted statistically by the
parity test), not bitwise: the host path draws from a per-request
``np.random.Generator`` stream, the device path from a JAX
threefry stream keyed on ``fold_in(PRNGKey(seed), draw_index)``.  Both
streams are pure functions of (request seed, draw index), so either way a
sampled row's output is independent of batch composition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.masked_sample.kernel import (NEG, masked_argmax_pallas,
                                                masked_argmax_pallas_packed)
from repro.kernels.masked_sample.ref import masked_argmax_ref


def masked_argmax(logits, mask, use_ref: bool = False, block_v: int = 2048):
    if use_ref:
        return masked_argmax_ref(logits, mask)
    on_tpu = jax.default_backend() == "tpu"
    if jnp.asarray(mask).dtype == jnp.uint32:
        return masked_argmax_pallas_packed(logits, mask, block_v=block_v,
                                           interpret=not on_tpu)
    return masked_argmax_pallas(logits, mask, block_v=block_v,
                                interpret=not on_tpu)


@jax.jit
def masked_sample_packed(logits, bits, temps, keys):
    """Masked softmax sampling on packed uint32 masks, fully on device.

    ``logits`` (B, V) f32; ``bits`` (B, ceil(V/32)) uint32; ``temps``
    (B,) f32 per-row temperature (rows with t <= 0 still produce the
    masked argmax — Gumbel noise over ``logits/1e-6`` cannot flip a
    strict maximum); ``keys`` (B, 2) uint32 per-row PRNG keys (the caller
    derives them as ``fold_in(PRNGKey(seed), n_draws)`` so the stream
    depends only on the request, never on the batch).  Returns (B,) int32
    token ids.

    Gumbel-max: ``argmax(logits/T + G)`` over the legal set samples
    exactly ``softmax(logits/T)`` restricted to that set — one fused
    argmax instead of a host round-trip per sampled row.  Bit b of word w
    is token ``w*32 + b`` (LSB first), matching core/bitmask.
    """
    b, v = logits.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    legal = ((bits[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1))
    legal = legal.astype(jnp.bool_).reshape(b, -1)[:, :v]
    scaled = logits.astype(jnp.float32) \
        / jnp.maximum(temps, 1e-6)[:, None]
    gumbel = jax.vmap(lambda k: jax.random.gumbel(k, (v,),
                                                  jnp.float32))(keys)
    score = jnp.where(legal, scaled + gumbel, NEG)
    return jnp.argmax(score, axis=-1).astype(jnp.int32)
