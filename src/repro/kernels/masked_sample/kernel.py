"""Fused grammar-mask + argmax over the vocabulary (Pallas TPU).

This is where DOMINO touches the accelerator: Algorithm 1 line 7-8
(``v' = m . v; t = decode(v')``).  The naive implementation materializes
the masked logits (B, V) in HBM — 2 extra |V|-sized HBM round trips per
step per sequence (1 MiB at gemma3's V=262144 fp32).  The fused kernel
streams logits tiles HBM->VMEM once, applies the mask in-register and
keeps a running (max, argmax) in VMEM scratch across vocabulary tiles.

Grid: (B, V / BLOCK_V), sequential over the vocab axis (TPU grid order is
minor-first), so the scratch carries state between vocab tiles of the same
row.  The masked-out value is -1e30; ties resolve to the lowest index
(matching jnp.argmax on the reference path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(logits_ref, mask_ref, idx_ref, val_ref, m_scr, i_scr, *,
            block_v: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[0, 0] = jnp.float32(NEG)
        i_scr[0, 0] = 0

    logits = logits_ref[...].astype(jnp.float32)          # (1, BV)
    mask = mask_ref[...]                                   # (1, BV) int8
    masked = jnp.where(mask != 0, logits, NEG)
    local_max = jnp.max(masked)
    local_arg = jnp.argmax(masked[0]).astype(jnp.int32) + j * block_v

    best = m_scr[0, 0]
    take = local_max > best
    m_scr[0, 0] = jnp.where(take, local_max, best)
    i_scr[0, 0] = jnp.where(take, local_arg, i_scr[0, 0])

    @pl.when(j == n_blocks - 1)
    def _done():
        idx_ref[0] = i_scr[0, 0]
        val_ref[0] = m_scr[0, 0]


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def masked_argmax_pallas(logits: jnp.ndarray, mask: jnp.ndarray,
                         block_v: int = 2048,
                         interpret: bool = True):
    """logits (B, V) float, mask (B, V) int8/bool -> (idx (B,), val (B,))."""
    b, v = logits.shape
    if v % block_v != 0:
        block_v = v  # fall back to one tile (v assumed modest) — still fused
    n_blocks = v // block_v
    mask = mask.astype(jnp.int8)
    kernel = functools.partial(_kernel, block_v=block_v, n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_v), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(logits, mask)
