"""Fused grammar-mask + argmax over the vocabulary (Pallas TPU).

This is where DOMINO touches the accelerator: Algorithm 1 line 7-8
(``v' = m . v; t = decode(v')``).  The naive implementation materializes
the masked logits (B, V) in HBM — 2 extra |V|-sized HBM round trips per
step per sequence (1 MiB at gemma3's V=262144 fp32).  The fused kernel
streams logits tiles HBM->VMEM once, applies the mask in-register and
keeps a running (max, argmax) in VMEM scratch across vocabulary tiles.

Two mask operand layouts:

 - int8 (B, V): one byte per token (legacy / oracle layout);
 - packed uint32 (B, ceil(V/32)): the ``core/bitmask.py`` wire format.
   Each vocab tile loads only ``BLOCK_V/32`` words and unpacks them
   in-register — the (BLOCK_V/32, 32) word-broadcast + lane-shift + AND
   below — fused with the running argmax, so the host ships 8x fewer
   mask bytes and the unpack never touches HBM.

Tail tiles: when ``v % block_v != 0`` the operands are padded up to the
next tile boundary (logits to NEG, mask to 0) instead of collapsing to a
single whole-vocabulary tile — ``block_v = v`` at real vocab sizes
(V=262144 -> a 1 MiB+ logits tile plus mask) blows the VMEM budget.

Grid: (B, V / BLOCK_V), sequential over the vocab axis (TPU grid order is
minor-first), so the scratch carries state between vocab tiles of the same
row.  The masked-out value is -1e30; ties resolve to the lowest index
(matching jnp.argmax on the reference path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
WORD_BITS = 32


def _pad_tail(logits: jnp.ndarray, mask: jnp.ndarray, block_v: int,
              mask_pad_words: int = 0):
    """Pad the vocab axis up to a tile boundary: logits with NEG (never
    wins the argmax), mask with 0 (nothing becomes legal)."""
    v = logits.shape[1]
    v_pad = -(-v // block_v) * block_v
    if v_pad != v:
        logits = jnp.pad(logits, ((0, 0), (0, v_pad - v)),
                         constant_values=NEG)
    if mask_pad_words:
        mask = jnp.pad(mask, ((0, 0), (0, mask_pad_words)))
    elif mask.shape[1] != v_pad and mask.shape[1] == v:
        mask = jnp.pad(mask, ((0, 0), (0, v_pad - v)))
    return logits, mask, v_pad


def _kernel(logits_ref, mask_ref, idx_ref, val_ref, m_scr, i_scr, *,
            block_v: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[0, 0] = jnp.float32(NEG)
        i_scr[0, 0] = 0

    logits = logits_ref[...].astype(jnp.float32)          # (1, BV)
    mask = mask_ref[...]                                   # (1, BV) int8
    masked = jnp.where(mask != 0, logits, NEG)
    local_max = jnp.max(masked)
    local_arg = jnp.argmax(masked[0]).astype(jnp.int32) + j * block_v

    best = m_scr[0, 0]
    take = local_max > best
    m_scr[0, 0] = jnp.where(take, local_max, best)
    i_scr[0, 0] = jnp.where(take, local_arg, i_scr[0, 0])

    @pl.when(j == n_blocks - 1)
    def _done():
        idx_ref[0] = i_scr[0, 0]
        val_ref[0] = m_scr[0, 0]


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def masked_argmax_pallas(logits: jnp.ndarray, mask: jnp.ndarray,
                         block_v: int = 2048,
                         interpret: bool = True):
    """logits (B, V) float, mask (B, V) int8/bool -> (idx (B,), val (B,))."""
    b, v = logits.shape
    block_v = min(block_v, -(-v // WORD_BITS) * WORD_BITS)
    logits, mask, v_pad = _pad_tail(logits, mask.astype(jnp.int8), block_v)
    n_blocks = v_pad // block_v
    kernel = functools.partial(_kernel, block_v=block_v, n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_v), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(logits, mask)


def _kernel_packed(logits_ref, bits_ref, idx_ref, val_ref, m_scr, i_scr, *,
                   block_v: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[0, 0] = jnp.float32(NEG)
        i_scr[0, 0] = 0

    bw = block_v // WORD_BITS
    # in-register unpack: token (w, b) of this tile is bit b (LSB first)
    # of word w — broadcast each word across the 32 lanes it governs,
    # shift by the lane's bit position, AND 1
    words = bits_ref[...].reshape(bw, 1)                   # (BW, 1) u32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (bw, WORD_BITS), 1)
    bit = (jnp.broadcast_to(words, (bw, WORD_BITS)) >> shifts) \
        & jnp.uint32(1)
    logits = logits_ref[...].astype(jnp.float32).reshape(bw, WORD_BITS)
    masked = jnp.where(bit != 0, logits, NEG)
    local_max = jnp.max(masked)
    # ties to the LOWEST flat index == first argmax occurrence
    flat = (jax.lax.broadcasted_iota(jnp.int32, (bw, WORD_BITS), 0)
            * WORD_BITS
            + jax.lax.broadcasted_iota(jnp.int32, (bw, WORD_BITS), 1))
    local_arg = jnp.min(jnp.where(masked == local_max, flat, block_v)) \
        + j * block_v

    best = m_scr[0, 0]
    take = local_max > best
    m_scr[0, 0] = jnp.where(take, local_max, best)
    i_scr[0, 0] = jnp.where(take, local_arg, i_scr[0, 0])

    @pl.when(j == n_blocks - 1)
    def _done():
        idx_ref[0] = i_scr[0, 0]
        val_ref[0] = m_scr[0, 0]


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def masked_argmax_pallas_packed(logits: jnp.ndarray, bits: jnp.ndarray,
                                block_v: int = 2048,
                                interpret: bool = True):
    """logits (B, V) float, bits (B, ceil(V/32)) uint32 (bitmask layout,
    tail bits past V zero) -> (idx (B,), val (B,))."""
    b, v = logits.shape
    assert block_v % WORD_BITS == 0, block_v
    block_v = min(block_v, -(-v // WORD_BITS) * WORD_BITS)
    n_blocks = -(-v // block_v)
    pad_words = n_blocks * (block_v // WORD_BITS) - bits.shape[1]
    logits, bits, v_pad = _pad_tail(logits, bits, block_v,
                                    mask_pad_words=pad_words)
    kernel = functools.partial(_kernel_packed, block_v=block_v,
                               n_blocks=n_blocks)
    bw = block_v // WORD_BITS
    return pl.pallas_call(
        kernel,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((1, bw), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(logits, bits)
