"""Jitted public wrapper for flash-decode attention."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k, v, length, use_ref: bool = False,
                     block_t: int = 512):
    """q (B,G,Q,D); k,v (B,T,G,D); length () int32 -> (B,G,Q,D)."""
    if use_ref:
        return decode_attention_ref(q, k, v, length)
    on_tpu = jax.default_backend() == "tpu"
    return decode_attention_pallas(q, k, v, length, block_t=block_t,
                                   interpret=not on_tpu)
