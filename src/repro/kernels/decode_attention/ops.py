"""Jitted public wrapper for ragged flash-decode attention."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k, v, lengths, use_ref: bool = False,
                     block_t: int = 512, scale=None, q2=None, k2=None,
                     block_tables=None):
    """q (B,S,G,Qh,Dk) — or (B,G,Qh,Dk), read as S=1; k (B,T,G,Dk);
    v (B,T,G,Dv); lengths () or (B,) int32 -> matching q's rank.

    ``lengths`` counts the keys visible to the first window position;
    window position s of row b attends keys t < lengths[b] + s.
    Optional (q2, k2) adds a second score term (absorbed-MLA latent+rope
    split): score = (q.k^T + q2.k2^T) * scale.

    Paged caches: with ``block_tables`` (B, max_pages) int32, k/v (and
    k2) are shared pools (n_pages, page_size, G, D) and row b's cache
    tile j streams from pool row block_tables[b, j] (BLOCK_T is the page
    size; ``block_t`` is ignored).
    """
    if use_ref:
        return decode_attention_ref(q, k, v, lengths, scale=scale,
                                    q2=q2, k2=k2,
                                    block_tables=block_tables)
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, None]
        q2 = None if q2 is None else q2[:, None]
    on_tpu = jax.default_backend() == "tpu"
    out = decode_attention_pallas(q, k, v, lengths, block_t=block_t,
                                  interpret=not on_tpu, scale=scale,
                                  q2=q2, k2=k2, block_tables=block_tables)
    return out[:, 0] if squeeze else out
