"""Pure-jnp oracle for the ragged flash-decode kernel."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths, scale=None, q2=None, k2=None):
    """q (B,S,G,Qh,Dk) — or (B,G,Qh,Dk), read as S=1; k (B,T,G,Dk);
    v (B,T,G,Dv); lengths () or (B,) int32 -> (B,S,G,Qh,Dv).

    Window position s of row b attends keys t < lengths[b] + s (causal
    offsets across a speculative verify window).  Rows with no visible
    key produce zeros, matching the kernel's early-exit convention.
    Optional split scores (q2, k2): score = (q.k^T + q2.k2^T) * scale,
    the absorbed-MLA latent+rope decomposition.
    """
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, None]
        q2 = None if q2 is None else q2[:, None]
    b, s_win, g, qh, dk = q.shape
    t = k.shape[1]
    if scale is None:
        scale = 1.0 / (dk ** 0.5)
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    s = jnp.einsum("bsgqd,btgd->bsgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    if q2 is not None:
        s = s + jnp.einsum("bsgqd,btgd->bsgqt", q2.astype(jnp.float32),
                           k2.astype(jnp.float32))
    s = s * scale
    limit = lengths[:, None] + jnp.arange(s_win, dtype=jnp.int32)  # (B,S)
    valid = jnp.arange(t)[None, None, :] < limit[:, :, None]       # (B,S,T)
    vmask = valid[:, :, None, None, :]
    s = jnp.where(vmask, s, -1e30)
    p = jnp.where(vmask, jnp.exp(s - s.max(axis=-1, keepdims=True)), 0.0)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bsgqt,btgd->bsgqd", p, v.astype(jnp.float32))
    out = out.astype(q.dtype)
    return out[:, 0] if squeeze else out
