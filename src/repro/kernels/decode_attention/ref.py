"""Pure-jnp oracle for the ragged flash-decode kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gather_pages(pool: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """Materialize a paged pool as its dense per-row equivalent.

    pool (n_pages, page_size, ...) + block_tables (B, max_pages) ->
    (B, max_pages * page_size, ...).  Vacant (< 0) table entries clamp to
    pool row 0 (the trash page); the positions they cover are beyond the
    owning row's frontier, so the validity mask hides whatever they hold.
    """
    g = pool[jnp.maximum(block_tables, 0)]       # (B, MP, ps, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def decode_attention_ref(q, k, v, lengths, scale=None, q2=None, k2=None,
                         block_tables=None):
    """q (B,S,G,Qh,Dk) — or (B,G,Qh,Dk), read as S=1; k (B,T,G,Dk);
    v (B,T,G,Dv); lengths () or (B,) int32 -> (B,S,G,Qh,Dv).

    Window position s of row b attends keys t < lengths[b] + s (causal
    offsets across a speculative verify window).  Rows with no visible
    key produce zeros, matching the kernel's early-exit convention.
    Optional split scores (q2, k2): score = (q.k^T + q2.k2^T) * scale,
    the absorbed-MLA latent+rope decomposition.

    With ``block_tables`` (B, max_pages), k/v (and k2) are paged pools
    (n_pages, page_size, G, D): the oracle gathers each row's pages into
    the dense stripe they stand for, then proceeds identically — paged
    attention IS dense attention over the gathered view.
    """
    if block_tables is not None:
        k = gather_pages(k, block_tables)
        v = gather_pages(v, block_tables)
        k2 = None if k2 is None else gather_pages(k2, block_tables)
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, None]
        q2 = None if q2 is None else q2[:, None]
    b, s_win, g, qh, dk = q.shape
    t = k.shape[1]
    if scale is None:
        scale = 1.0 / (dk ** 0.5)
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    s = jnp.einsum("bsgqd,btgd->bsgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    if q2 is not None:
        s = s + jnp.einsum("bsgqd,btgd->bsgqt", q2.astype(jnp.float32),
                           k2.astype(jnp.float32))
    s = s * scale
    limit = lengths[:, None] + jnp.arange(s_win, dtype=jnp.int32)  # (B,S)
    valid = jnp.arange(t)[None, None, :] < limit[:, :, None]       # (B,S,T)
    vmask = valid[:, :, None, None, :]
    s = jnp.where(vmask, s, -1e30)
    p = jnp.where(vmask, jnp.exp(s - s.max(axis=-1, keepdims=True)), 0.0)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bsgqt,btgd->bsgqd", p, v.astype(jnp.float32))
    out = out.astype(q.dtype)
    return out[:, 0] if squeeze else out
