"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, length):
    """q (B,G,Q,D); k,v (B,T,G,D); length scalar -> (B,G,Q,D)."""
    b, g, nq, d = q.shape
    t = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bgqd,btgd->bgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    valid = jnp.arange(t) < length
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bgqt,btgd->bgqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
