"""Flash-decode GQA attention against a KV cache (Pallas TPU).

The dominant bytes-consumer of ``decode_32k`` / ``long_500k``: one query
token attends a T-long cache.  Arithmetic intensity is O(1) FLOP/byte, so
the kernel's job is to stream K/V through VMEM exactly once with an
online-softmax accumulator — no (T,) score vector in HBM, no second pass.

Layout: q (B, G, Q, D) where G = n_kv heads and Q = n_q/G query heads per
group; k/v (B, T, G, D); ``length`` (1,) int32 in SMEM masks unwritten
cache slots.  Grid (B, G, T/BLOCK_T) — the T axis is minor, so VMEM
scratch (m, l, acc) carries across cache tiles of one (batch, group).

VMEM working set per step: BLOCK_T*(2D) halves of K/V + Q*D accumulators
— with D=128, BLOCK_T=512: ~256 KiB, comfortably inside the ~16 MiB VMEM
budget; BLOCK_T is the §Perf tuning knob.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_t: int, n_blocks: int, scale: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (Q, D)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (BT, D)
    v = v_ref[0, :, 0].astype(jnp.float32)              # (BT, D)
    length = len_ref[0]

    s = jnp.dot(q, k.T) * scale                          # (Q, BT)
    t_idx = j * block_t + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_t), 1)
    s = jnp.where(t_idx < length, s, NEG)

    m_prev = m_scr[...]                                  # (Q, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)                               # (Q, BT)
    corr = jnp.exp(m_prev - m_new)                       # (Q, 1)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v)   # (Q, D)
    m_scr[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "interpret"))
def decode_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            length: jnp.ndarray, block_t: int = 512,
                            interpret: bool = True) -> jnp.ndarray:
    """q (B,G,Q,D); k,v (B,T,G,D); length () or (1,) int32 -> (B,G,Q,D)."""
    b, g, nq, d = q.shape
    t = k.shape[1]
    if t % block_t != 0:
        block_t = t
    n_blocks = t // block_t
    scale = 1.0 / (d ** 0.5)
    length = jnp.reshape(length, (1,)).astype(jnp.int32)
    kernel = functools.partial(_kernel, block_t=block_t, n_blocks=n_blocks,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, g, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, nq, d), lambda i, h, j, *_: (i, h, 0, 0)),
            pl.BlockSpec((1, block_t, 1, d), lambda i, h, j, *_: (i, j, h, 0)),
            pl.BlockSpec((1, block_t, 1, d), lambda i, h, j, *_: (i, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, nq, d), lambda i, h, j, *_: (i, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nq, 1), jnp.float32),
            pltpu.VMEM((nq, 1), jnp.float32),
            pltpu.VMEM((nq, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, g, nq, d), q.dtype),
        interpret=interpret,
    )(length, q, k, v)
    return out
