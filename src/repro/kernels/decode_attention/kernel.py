"""Ragged flash-decode GQA attention against a KV cache (Pallas TPU).

The dominant bytes-consumer of ``decode_32k`` / ``long_500k`` *and* of the
continuous-batching serving hot path: a short query window attends a
T-long cache.  Arithmetic intensity is O(1) FLOP/byte, so the kernel's job
is to stream K/V through VMEM exactly once with an online-softmax
accumulator — no (T,) score vector in HBM, no second pass — and, in a
ragged batch, to stream only the tiles each row actually owns.

Layout: q (B, S, G, Qh, Dk) where S = query window (1 for plain decode,
1+spec_s for a speculative verify window), G = n_kv heads and Qh = n_q/G
query heads per group; k (B, T, G, Dk); v (B, T, G, Dv) — Dv may differ
from Dk, and an optional second (q2, k2) operand pair adds a split score
term (absorbed-MLA scores q_lat.c_kv^T + q_rope.k_rope^T against Dv = r
latent values, streaming both caches exactly as stored).  ``lengths`` is
a per-row (B,) int32 vector (a scalar
broadcasts): query position s of row b attends keys t < lengths[b] + s,
i.e. ``lengths`` counts the keys visible to the *first* window position
and later positions extend causally one key at a time.

Paged caches: with ``block_tables`` (B, max_pages) int32, K/V are POOLS
(n_pages, page_size, G, D) shared across rows and BLOCK_T == page_size —
cache tile j of row b lives at pool row ``block_tables[b, j]``, so each
grid step gathers one page from a (generally non-contiguous) pool row
instead of slicing a contiguous stripe.  The tile's *logical* positions
are still j*BLOCK_T.., so the in-tile validity mask and the per-row
frontier early-exit are unchanged; only the HBM addresses move.  Both
scalar operands ride the scalar-prefetch channel, which is what lets the
pipeline compute the next DMA's source address from the table before the
tile is needed.

Grid (B, G, T/BLOCK_T) — the T axis is minor, so VMEM scratch (m, l, acc)
carries across cache tiles of one (batch, group).  Raggedness is handled
twice over:
  * ``pl.when(j * BLOCK_T < lengths[b] + S - 1)`` skips compute on tiles
    fully beyond the row's frontier, and
  * the K/V index maps clamp the tile index to the row's last live tile
    (then translate it through the block table when paged), so the
    pipeline re-addresses the same block and elides the HBM copy —
    row b moves ceil((lengths[b]+S-1)/BLOCK_T) tiles, not T/BLOCK_T.

VMEM working set per step: BLOCK_T*(Dk+Dv) halves of K/V + S*Qh*(Dv+2)
f32 accumulators — with Dk=Dv=128, BLOCK_T=512, S*Qh<=32: ~600 KiB,
comfortably inside the ~16 MiB VMEM budget; BLOCK_T (== page_size when
paged) is the §Perf tuning knob.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(len_ref, *rest,
            block_t: int, n_blocks: int, s_win: int, qh: int, scale: float,
            split_k: bool, paged: bool):
    if paged:                               # block table rides the scalar
        rest = rest[1:]                     # channel; index maps consume it
    q_ref, k_ref, v_ref, *rest = rest
    if split_k:                             # second (q2, k2) score operand
        q2_ref, k2_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    i = pl.program_id(0)
    j = pl.program_id(2)
    base = len_ref[i]                       # keys visible to window pos 0
    frontier = base + s_win - 1             # keys visible to the last pos

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * block_t < frontier)        # early-exit past the frontier
    def _accumulate():
        q = q_ref[0, :, 0].reshape(s_win * qh, q_ref.shape[-1])
        q = q.astype(jnp.float32)                        # (S*Qh, Dk)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (BT, Dk)
        v = v_ref[0, :, 0].astype(jnp.float32)           # (BT, Dv)

        s = jnp.dot(q, k.T)                              # (S*Qh, BT)
        if split_k:
            q2 = q2_ref[0, :, 0].reshape(s_win * qh, q2_ref.shape[-1])
            k2 = k2_ref[0, :, 0].astype(jnp.float32)     # (BT, D2)
            s = s + jnp.dot(q2.astype(jnp.float32), k2.T)
        s = s * scale
        t_idx = j * block_t + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_t), 1)
        # row r of the flattened (S*Qh) axis sits at window pos r // Qh
        w_pos = jax.lax.broadcasted_iota(
            jnp.int32, (s_win * qh, 1), 0) // qh
        valid = t_idx < base + w_pos                     # (S*Qh, BT)
        s = jnp.where(valid, s, NEG)

        m_prev = m_scr[...]                              # (S*Qh, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        # explicit re-mask: on an all-masked row s - m_new == 0, and the
        # exp would count dead keys into l (divergence vs ref on empty
        # rows / skipped tiles)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)    # (S*Qh, BT)
        corr = jnp.exp(m_prev - m_new)                   # (S*Qh, 1)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v)
        m_scr[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _done():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0] = out.reshape(
            s_win, qh, o_ref.shape[-1]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "interpret", "scale"))
def decode_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            lengths: jnp.ndarray, block_t: int = 512,
                            interpret: bool = True,
                            scale: float | None = None,
                            q2: jnp.ndarray | None = None,
                            k2: jnp.ndarray | None = None,
                            block_tables: jnp.ndarray | None = None
                            ) -> jnp.ndarray:
    """q (B,S,G,Qh,Dk); k (B,T,G,Dk); v (B,T,G,Dv); lengths (B,) int32
    (scalar broadcasts) -> (B,S,G,Qh,Dv).  Window pos s of row b attends
    keys t < lengths[b] + s.

    Optional split scores: with q2 (B,S,G,Qh,D2) / k2 (B,T,G,D2) the tile
    score is (q.k^T + q2.k2^T) * scale.  Absorbed MLA uses this to run
    the latent (c_kv) and rope (k_rope) caches as-is — no per-step O(T)
    key concatenation on the host side.

    Paged: with ``block_tables`` (B, max_pages) int32, k/v (and k2) are
    pools (n_pages, page_size, G, D); BLOCK_T is forced to page_size and
    tile j of row b streams pool row block_tables[b, j].  Negative /
    vacant table entries are clamped to pool row 0 (the reserved trash
    page) — such tiles are always beyond the row's frontier, so their
    contents never reach the accumulator.
    """
    b, s_win, g, qh, dk = q.shape
    dv = v.shape[-1]
    paged = block_tables is not None
    if paged:
        block_t = k.shape[1]               # BLOCK_T == page_size
        n_blocks = block_tables.shape[1]
        block_tables = jnp.asarray(block_tables, jnp.int32)
    else:
        t = k.shape[1]
        if t % block_t != 0:
            block_t = t
        n_blocks = t // block_t
    if scale is None:
        scale = 1.0 / (dk ** 0.5)
    lengths = jnp.broadcast_to(
        jnp.asarray(lengths, jnp.int32).reshape(-1), (b,))
    split_k = q2 is not None
    kernel = functools.partial(_kernel, block_t=block_t, n_blocks=n_blocks,
                               s_win=s_win, qh=qh, scale=scale,
                               split_k=split_k, paged=paged)

    def last_live(i, len_ref):
        # clamp to the row's last live tile: once past the frontier the
        # block index stops changing and the pipeline skips the HBM copy
        return jnp.maximum(len_ref[i] + s_win - 2, 0) // block_t

    if paged:
        def kv_map(i, h, j, len_ref, tbl_ref):
            page = jnp.minimum(j, last_live(i, len_ref))
            return (jnp.maximum(tbl_ref[i, page], 0), 0, h, 0)
    else:
        def kv_map(i, h, j, len_ref):
            return (i, jnp.minimum(j, last_live(i, len_ref)), h, 0)

    def q_map(i, h, j, *_):
        return (i, 0, h, 0, 0)

    in_specs = [
        pl.BlockSpec((1, s_win, 1, qh, dk), q_map),
        pl.BlockSpec((1, block_t, 1, dk), kv_map),
        pl.BlockSpec((1, block_t, 1, dv), kv_map),
    ]
    operands = [lengths] + ([block_tables] if paged else []) + [q, k, v]
    if split_k:
        d2 = q2.shape[-1]
        in_specs += [pl.BlockSpec((1, s_win, 1, qh, d2), q_map),
                     pl.BlockSpec((1, block_t, 1, d2), kv_map)]
        operands += [q2, k2]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2 if paged else 1,
        grid=(b, g, n_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, s_win, 1, qh, dv), q_map),
        scratch_shapes=[
            pltpu.VMEM((s_win * qh, 1), jnp.float32),
            pltpu.VMEM((s_win * qh, 1), jnp.float32),
            pltpu.VMEM((s_win * qh, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s_win, g, qh, dv), q.dtype),
        interpret=interpret,
    )(*operands)
    return out
