"""Single-host training loop (the distributed version lives in
repro/launch/train.py as a pjit program over the production mesh)."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training import optimizer as opt


def make_train_step(model: Model, ocfg: opt.AdamWConfig):
    def loss_fn(params, batch):
        # labels provided separately (prompt masking) or derived from tokens
        if "labels" in batch:
            inputs = {k: v for k, v in batch.items() if k != "labels"}
            inputs["tokens"] = batch["tokens"][:, :-1]
            logits, aux = model.train_logits(params, inputs)
            labels = batch["labels"]
            if logits.shape[1] != labels.shape[1]:
                logits = logits[:, -labels.shape[1]:]
            from repro.models.model import cross_entropy
            nll = cross_entropy(logits, labels)
            return nll + aux, {"nll": nll, "aux": aux}
        return model.loss(params, batch)

    def step(params, state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, state, om = opt.apply_updates(params, grads, state, ocfg)
        return params, state, {"loss": loss, **metrics, **om}

    return jax.jit(step, donate_argnums=(0, 1))


def train(model: Model, params, data_iter: Iterator[Dict], steps: int,
          ocfg: Optional[opt.AdamWConfig] = None,
          log_every: int = 20,
          log_fn: Callable[[str], None] = print):
    ocfg = ocfg or opt.AdamWConfig()
    state = opt.init_state(params)
    step_fn = make_train_step(model, ocfg)
    history = []
    t0 = time.perf_counter()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data_iter).items()}
        params, state, metrics = step_fn(params, state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            log_fn(f"step {i:5d} loss={m['loss']:.4f} nll={m['nll']:.4f} "
                   f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.2f} "
                   f"({time.perf_counter()-t0:.1f}s)")
    return params, state, history
