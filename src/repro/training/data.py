"""Synthetic data pipeline.

Two streams, both grammar-grounded (no external datasets offline):

1. ``lm_stream`` — free-form strings sampled from a workload grammar
   (JSON / C / XML ...), for language-model pretraining of the in-repo
   models and for tokenizer training.

2. ``task_stream`` — the *GSM8K-JSON analogue*: little arithmetic word
   problems whose gold answers are JSON objects in the paper's guided-
   math-reasoning schema (App. C Listing 4 / App. D Listing 8).  Because
   answers carry a verifiable number, constrained-decoding accuracy
   (Table 2) is measurable end-to-end with a model trained here.
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import grammars
from repro.core.sampling import GrammarSampler
from repro.tokenizer import BPETokenizer

OPS = [("+", lambda a, b: a + b), ("-", lambda a, b: a - b),
       ("*", lambda a, b: a * b)]


@dataclasses.dataclass
class TaskExample:
    prompt: str
    answer_json: str
    answer_value: int


def make_task_example(rng: random.Random, n_steps: Optional[int] = None,
                      easy: bool = False) -> TaskExample:
    """A chained arithmetic problem + JSON reasoning answer.

    ``easy=True`` restricts to single-digit +/- with answers in [-7, 18] —
    learnable by the ~1M-param bench models, so constrained-decoding
    accuracy comparisons (Table 2/4) have signal above zero."""
    n_steps = n_steps or rng.randint(1, 3)
    if easy:
        n_steps = 1
        ops = OPS[:2]
        acc = rng.randint(2, 9)
    else:
        ops = OPS
        acc = rng.randint(2, 20)
    desc = [str(acc)]
    thoughts = []
    for _ in range(n_steps):
        op_s, op_f = ops[rng.randrange(len(ops))]
        b = rng.randint(1, 9) if easy else rng.randint(2, 12)
        new = op_f(acc, b)
        thoughts.append({
            "step": f"apply {op_s}{b}",
            "calculation": f"{acc}{op_s}{b}",
            "result": new,
        })
        desc.append(f"{op_s} {b}")
        acc = new
    prompt = "Q: compute " + " ".join(desc) + "\nA: "
    answer = json.dumps({"thoughts": thoughts, "answer": acc})
    return TaskExample(prompt, answer, acc)


def few_shot_prefix(rng: random.Random, n: int = 3,
                    easy: bool = False) -> str:
    parts = []
    for _ in range(n):
        ex = make_task_example(rng, easy=easy)
        parts.append(ex.prompt + ex.answer_json)
    return "\n".join(parts) + "\n"


_PER = ["Anna", "Bob", "Carla", "David", "Eva", "Frank"]
_LOC = ["Paris", "Berlin", "Tokyo", "Oslo", "Lima"]
_ORG = ["Acme", "Globex", "Initech", "Umbrella"]
_NER_TEMPLATES = [
    ("{p} works at {o}", [("p", "PER"), ("o", "ORG")]),
    ("{p} visited {l}", [("p", "PER"), ("l", "LOC")]),
    ("{o} opened an office in {l}", [("o", "ORG"), ("l", "LOC")]),
    ("{p} met {p2} in {l}", [("p", "PER"), ("p2", "PER"), ("l", "LOC")]),
]


def make_ner_example(rng: random.Random) -> TaskExample:
    """CoNLL-2003 analogue: extract entities into the App. D JSON schema."""
    tmpl, slots = _NER_TEMPLATES[rng.randrange(len(_NER_TEMPLATES))]
    pools = {"PER": _PER, "LOC": _LOC, "ORG": _ORG}
    fills = {}
    ents = []
    for slot, typ in slots:
        val = rng.choice(pools[typ])
        fills[slot] = val
        ents.append({"text": val, "type": typ})
    sent = tmpl.format(**fills)
    prompt = f"S: {sent}\nE: "
    answer = json.dumps({"entities": ents})
    return TaskExample(prompt, answer, len(ents))


def ner_few_shot(rng: random.Random, n: int = 2) -> str:
    parts = []
    for _ in range(n):
        ex = make_ner_example(rng)
        parts.append(ex.prompt + ex.answer_json)
    return "\n".join(parts) + "\n"


def evaluate_entities(text: str, gold_json: str) -> Optional[float]:
    """F1-ish exact-set score of extracted entities, or None if unparsable."""
    try:
        got = json.loads(text)["entities"]
        want = json.loads(gold_json)["entities"]
        gset = {(e["text"], e["type"]) for e in got}
        wset = {(e["text"], e["type"]) for e in want}
        if not gset and not wset:
            return 1.0
        inter = len(gset & wset)
        p = inter / max(1, len(gset))
        r = inter / max(1, len(wset))
        return 2 * p * r / max(1e-9, p + r)
    except (KeyError, TypeError, ValueError, AttributeError):
        return None


class NERDataset:
    """LM rows of few-shot NER extraction examples."""

    def __init__(self, tok: BPETokenizer, seq_len: int = 192, seed: int = 0,
                 few_shot: int = 2):
        self.tok = tok
        self.seq_len = seq_len
        self.rng = random.Random(seed)
        self.few_shot = few_shot

    def sample_row(self) -> Tuple[np.ndarray, np.ndarray]:
        ex = make_ner_example(self.rng)
        prefix = ner_few_shot(self.rng, self.few_shot)
        ids = self.tok.encode(prefix + ex.prompt) \
            + self.tok.encode(ex.answer_json) + [self.tok.eos_id]
        S = self.seq_len + 1
        labels = list(ids)
        if len(ids) >= S:
            ids, labels = ids[:S], labels[:S]
        else:
            pad = S - len(ids)
            ids = ids + [self.tok.pad_id] * pad
            labels = labels + [-1] * pad
        return np.asarray(ids, np.int32), np.asarray(labels, np.int32)

    def batches(self, batch_size: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            rows = [self.sample_row() for _ in range(batch_size)]
            yield {"tokens": np.stack([r[0] for r in rows]),
                   "labels": np.stack([r[1] for r in rows])[:, 1:]}


class TaskDataset:
    """Fixed-length packed LM rows of few-shot + problem + JSON answer.

    Labels: -1 (masked) on prompt/pad positions when ``mask_prompt``;
    otherwise plain LM over the whole row.
    """

    def __init__(self, tok: BPETokenizer, seq_len: int = 256,
                 seed: int = 0, few_shot: int = 2,
                 mask_prompt: bool = False, easy: bool = False):
        self.tok = tok
        self.seq_len = seq_len
        self.rng = random.Random(seed)
        self.few_shot = few_shot
        self.mask_prompt = mask_prompt
        self.easy = easy

    def sample_row(self) -> Tuple[np.ndarray, np.ndarray]:
        ex = make_task_example(self.rng, easy=self.easy)
        prefix = few_shot_prefix(self.rng, self.few_shot, easy=self.easy) \
            if self.few_shot else ""
        p_ids = self.tok.encode(prefix + ex.prompt)
        a_ids = self.tok.encode(ex.answer_json) + [self.tok.eos_id]
        ids = p_ids + a_ids
        labels = ([-1] * len(p_ids) if self.mask_prompt else
                  list(ids[:len(p_ids)])) + list(a_ids)
        S = self.seq_len + 1
        if len(ids) >= S:
            ids, labels = ids[:S], labels[:S]
        else:
            pad = S - len(ids)
            ids = ids + [self.tok.pad_id] * pad
            labels = labels + [-1] * pad
        return np.asarray(ids, np.int32), np.asarray(labels, np.int32)

    def batches(self, batch_size: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            rows = [self.sample_row() for _ in range(batch_size)]
            tokens = np.stack([r[0] for r in rows])
            labels = np.stack([r[1] for r in rows])
            yield {"tokens": tokens, "labels": labels[:, 1:]}


class GrammarLMDataset:
    """Plain LM stream over grammar-sampled strings."""

    def __init__(self, tok: BPETokenizer, grammar_name: str = "json",
                 seq_len: int = 256, seed: int = 0):
        self.tok = tok
        self.seq_len = seq_len
        g = grammars.load(grammar_name)
        self.sampler = GrammarSampler(g, seed=seed)

    def batches(self, batch_size: int) -> Iterator[Dict[str, np.ndarray]]:
        S = self.seq_len + 1
        buf: List[int] = []
        while True:
            rows = []
            while len(rows) < batch_size:
                while len(buf) < S:
                    buf.extend(self.tok.encode_bytes(self.sampler.sample()))
                    buf.append(self.tok.eos_id)
                rows.append(np.asarray(buf[:S], np.int32))
                buf = buf[S:]
            yield {"tokens": np.stack(rows)}


def evaluate_answer(text: str) -> Optional[int]:
    """Parse a generated JSON answer; returns the 'answer' value or None."""
    try:
        obj = json.loads(text)
        v = obj.get("answer")
        if isinstance(v, (int, float)):
            return int(v)
    except (json.JSONDecodeError, AttributeError, TypeError, ValueError):
        pass
    return None
