"""AdamW + learning-rate schedules, hand-rolled (optax is not available).

Schedules include WSD (warmup-stable-decay) — the training recipe of the
assigned minicpm-2b [arXiv:2404.06395] — alongside cosine and constant.
State is a pytree mirroring the params (m, v moments) + a step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"          # constant | cosine | wsd
    warmup_steps: int = 100
    total_steps: int = 1000
    # WSD: stable until decay_start, then linear decay to lr_min
    decay_start_frac: float = 0.8
    lr_min_frac: float = 0.1
    # moment dtype: 'float32' (default) or 'bfloat16' — halves optimizer
    # HBM (the binding constraint for 100B+ models on 16 GiB chips)
    state_dtype: str = "float32"


def schedule_fn(cfg: AdamWConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
        if cfg.schedule == "constant":
            main = 1.0
        elif cfg.schedule == "cosine":
            frac = jnp.clip((step - cfg.warmup_steps)
                            / max(1, cfg.total_steps - cfg.warmup_steps),
                            0.0, 1.0)
            main = 0.5 * (1 + jnp.cos(jnp.pi * frac)) * (1 - cfg.lr_min_frac) \
                + cfg.lr_min_frac
        elif cfg.schedule == "wsd":
            decay_start = cfg.decay_start_frac * cfg.total_steps
            frac = jnp.clip((step - decay_start)
                            / max(1.0, cfg.total_steps - decay_start),
                            0.0, 1.0)
            main = (1 - frac) * 1.0 + frac * cfg.lr_min_frac
        else:
            raise ValueError(cfg.schedule)
        return cfg.lr * warm * main
    return fn


def init_state(params, cfg: "AdamWConfig" = None) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype) if cfg is not None else jnp.float32
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dt), params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule_fn(cfg)(step)

    # global-norm clip
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(sdt), v.astype(sdt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
