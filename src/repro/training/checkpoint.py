"""Checkpointing: params/opt-state as .npz with a flattened key index."""
from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"[{k.idx}]"
    return str(k)


def save(path, params, opt_state=None, meta: Dict[str, Any] = None) -> None:
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    np.savez(path / "params.npz", **_flatten(params))
    if opt_state is not None:
        np.savez(path / "opt_state.npz", **_flatten(opt_state))
    (path / "meta.json").write_text(json.dumps(meta or {}, default=str))


def load(path, params_template, opt_template=None):
    """Restore into the structure of the given templates."""
    path = pathlib.Path(path)
    data = np.load(path / "params.npz")

    def fill(template, npz):
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in paths:
            key = "/".join(_key_str(k) for k in p)
            arr = npz[key]
            if arr.shape != leaf.shape:
                # vocab-padding drift (embed/lm_head grow to a multiple of
                # 256): zero-pad is exact — pad rows/cols are masked out
                if all(a <= b for a, b in zip(arr.shape, leaf.shape)):
                    pad = [(0, b - a) for a, b in zip(arr.shape, leaf.shape)]
                    arr = np.pad(arr, pad)
                else:
                    raise ValueError(
                        f"checkpoint shape mismatch at {key}: "
                        f"{arr.shape} vs {leaf.shape}")
            leaves.append(arr.astype(leaf.dtype))
        return treedef.unflatten(leaves)

    params = fill(params_template, data)
    meta = json.loads((path / "meta.json").read_text())
    if opt_template is not None and (path / "opt_state.npz").exists():
        opt = fill(opt_template, np.load(path / "opt_state.npz"))
        return params, opt, meta
    return params, None, meta
