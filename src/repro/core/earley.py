"""Incremental Earley parser over terminal ids.

DOMINO runs a parser in lock-step with the scanner (§3.4): at inference time
the parser state prunes the precomputed subterminal trees.  We use Earley
because it handles every CFG (the App. C grammars include ambiguity and
nullable rules) and supports O(1)-amortised *incremental* advancing plus
cheap *forking* — the decoder keeps one parser per hypothesis.

The chart is append-only: a fork shares all finalized item-sets, so cloning
is a shallow list copy.

Nullable completion uses the Aycock–Horspool trick (predicting a nullable
nonterminal also advances the predictor), which makes single-pass item-set
construction correct for grammars with epsilon rules.
"""
from __future__ import annotations

from typing import FrozenSet, List, Optional, Set, Tuple

from repro.core.grammar import Grammar, is_terminal, nt_id

# An Earley item: (rule_index, dot_position, origin_set_index)
Item = Tuple[int, int, int]


class _ItemSet:
    __slots__ = ("items", "expected", "wanted_by", "complete_start")

    def __init__(self):
        self.items: Set[Item] = set()
        # terminal id -> list of items expecting it (for scanning)
        self.expected: dict = {}
        # nonterminal id -> list of items expecting it (for completion)
        self.wanted_by: dict = {}
        # True if the start symbol is complete over the whole prefix
        self.complete_start: bool = False


class EarleyParser:
    """Incremental recognizer.

    Usage::

        p = EarleyParser(grammar)
        p.allowed_terminals()      # set of legal next terminal ids
        p2 = p.fork()
        ok = p2.advance(tid)       # feed one terminal; False if illegal
        p2.accepts()               # is the consumed sequence a full parse?
    """

    def __init__(self, grammar: Grammar, _chart: Optional[List[_ItemSet]] = None,
                 _hash: int = 0):
        self.g = grammar
        if _chart is not None:
            self.chart = _chart
            self._hash = _hash
            return
        self.chart = []
        s0 = self._make_set(0, seeds=[(ri, 0, 0)
                                      for ri in grammar.rules_by_lhs.get(
                                          grammar.start, [])])
        self.chart.append(s0)
        self._hash = hash(frozenset(s0.items))

    # -- public API ---------------------------------------------------------

    def fork(self) -> "EarleyParser":
        return EarleyParser(self.g, _chart=list(self.chart), _hash=self._hash)

    @property
    def position(self) -> int:
        return len(self.chart) - 1

    def allowed_terminals(self) -> FrozenSet[int]:
        return frozenset(self.chart[-1].expected.keys())

    def can_accept(self, tid: int) -> bool:
        return tid in self.chart[-1].expected

    def accepts(self) -> bool:
        return self.chart[-1].complete_start

    def advance(self, tid: int) -> bool:
        """Consume terminal ``tid``; returns False (state unchanged) if illegal."""
        cur = self.chart[-1]
        scanners = cur.expected.get(tid)
        if not scanners:
            return False
        pos = len(self.chart)
        seeds = [(ri, dot + 1, org) for (ri, dot, org) in scanners]
        new_set = self._make_set(pos, seeds)
        self.chart.append(new_set)
        # Incremental whole-history fingerprint: equal fingerprints mean the
        # parsers consumed terminal sequences inducing identical charts, so
        # all future behaviour coincides.  Used to deduplicate hypotheses.
        self._hash = hash((self._hash, frozenset(new_set.items)))
        return True

    def chart_fingerprint(self) -> int:
        return self._hash

    def state_signature(self) -> int:
        """A hashable digest of the current item set (used as the parser
        substate β for speculative decoding, §3.6)."""
        return hash(frozenset(self.chart[-1].items))

    def rel_signature(self, clamp: int = 8) -> int:
        """Position-RELATIVE digest of the current item set: every item's
        origin is rebased to its distance from the current position and
        clamped at ``clamp``, so the digest recurs across absolute
        positions (``state_signature`` never does — origins are absolute
        chart indices, so it grows stale with history).

        This is the finite-quotient key the static analyzer
        (:mod:`repro.core.analysis`) explores the decoder state space on.
        It is an ABSTRACTION, not an isomorphism: two parsers with equal
        rel-signatures agree on the current item set shape but may carry
        different charts beyond the clamp horizon, so future completion
        behaviour can diverge.  Callers that need soundness must validate
        conclusions against concrete replays (the analyzer does)."""
        pos = len(self.chart) - 1
        return hash(frozenset(
            (ri, dot, min(pos - org, clamp))
            for (ri, dot, org) in self.chart[-1].items))

    # -- internals ----------------------------------------------------------

    def _make_set(self, pos: int, seeds: List[Item]) -> _ItemSet:
        g = self.g
        st = _ItemSet()
        agenda = list(seeds)
        while agenda:
            item = agenda.pop()
            if item in st.items:
                continue
            st.items.add(item)
            ri, dot, org = item
            rule = g.rules[ri]
            if dot == len(rule.rhs):
                # Completion: lhs finished spanning [org, pos].
                if rule.lhs == g.start and org == 0:
                    st.complete_start = True
                parents = (st.wanted_by.get(rule.lhs, []) if org == pos
                           else self.chart[org].wanted_by.get(rule.lhs, []))
                for (pri, pdot, porg) in list(parents):
                    agenda.append((pri, pdot + 1, porg))
                continue
            sym = rule.rhs[dot]
            if is_terminal(sym):
                st.expected.setdefault(sym, []).append(item)
                continue
            n = nt_id(sym)
            first_want = n not in st.wanted_by
            st.wanted_by.setdefault(n, []).append(item)
            if first_want:
                for nri in g.rules_by_lhs.get(n, []):
                    agenda.append((nri, 0, pos))
            else:
                # A completion of n within this same set may already have
                # happened; re-run completions for already-complete n items.
                for (cri, cdot, corg) in list(st.items):
                    crule = g.rules[cri]
                    if (cdot == len(crule.rhs) and crule.lhs == n
                            and corg == pos):
                        agenda.append((ri, dot + 1, org))
                        break
            if n in g.nullable:
                # Aycock-Horspool: nullable prediction advances the predictor.
                agenda.append((ri, dot + 1, org))
        return st


def parse_terminals(grammar: Grammar, tids: List[int]) -> bool:
    """Convenience recognizer: does the terminal sequence parse fully?"""
    p = EarleyParser(grammar)
    for t in tids:
        if not p.advance(t):
            return False
    return p.accepts()
