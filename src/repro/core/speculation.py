"""Grammar-state-conditioned speculative decoding (§3.6).

A count-based model ``P(l | α, β)`` where α is the scanner subterminal
digest and β a parser item-set signature.  Structured languages are highly
predictable given (α, β) — e.g. after ``"answer":`` in a JSON schema the
next tokens are near-deterministic — so a table of counts proposes up to
``s`` tokens per step; the LLM validates all of them with ONE forward pass
(the transformer scores every proposed position in parallel).  Rejected
suffixes are discarded by rolling the KV cache length back — no
backtracking compute.

Because counts are keyed by *parser* state, proposals are always legal in
the grammar (we additionally re-check against a cloned decoder while
building the proposal chain, which also yields the decoder states needed to
continue proposing).
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Tuple

from repro.core.domino import DominoDecoder

StateKey = Tuple


class CountModel:
    """P(l | alpha, beta) with maximum-likelihood counts.

    ``version`` increments only when an observation CHANGES some state's
    argmax — proposal chains memoized against the version stay valid across
    the (frequent) observations that just reinforce the current mode.
    """

    def __init__(self):
        self.counts: Dict[StateKey, collections.Counter] = {}
        self.totals: Dict[StateKey, int] = collections.defaultdict(int)
        self.version = 0

    def observe(self, state: StateKey, token_id: int) -> None:
        c = self.counts.setdefault(state, collections.Counter())
        prev_top = c.most_common(1)[0][0] if c else None
        c[token_id] += 1
        self.totals[state] += 1
        if c.most_common(1)[0][0] != prev_top:
            self.version += 1

    def predict(self, state: StateKey) -> Optional[Tuple[int, float]]:
        """Most likely token and its probability, or None if unseen state."""
        c = self.counts.get(state)
        if not c:
            return None
        tok, n = c.most_common(1)[0]
        return tok, n / self.totals[state]

    def n_states(self) -> int:
        return len(self.counts)


class Speculator:
    """Builds speculative proposals for a DOMINO decoding session."""

    def __init__(self, model: Optional[CountModel] = None,
                 s: int = 8, threshold: float = 0.5,
                 learn: bool = True):
        self.model = model or CountModel()
        self.s = s
        self.threshold = threshold
        self.learn = learn
        # memoized proposal chains: state_key -> (model.version, chain)
        self._chain_cache: Dict[Tuple, Tuple[int, List[int]]] = {}

    def propose(self, decoder: DominoDecoder) -> List[int]:
        """Chain of up to ``s`` tokens predicted from grammar state.

        Each proposed token is validated against a cloned decoder, so the
        chain is guaranteed grammar-legal.  Chains are memoized per grammar
        state (invalidated when the count model's argmax landscape moves),
        so steady-state proposing is a dict lookup — the host-side analogue
        of the paper's "learned priors remain fixed" measurement setup.
        """
        key = decoder.state_key()
        hit = self._chain_cache.get(key)
        if hit is not None and hit[0] == self.model.version:
            return list(hit[1])
        out: List[int] = []
        d = decoder.clone()
        for _ in range(self.s):
            pred = self.model.predict(d.state_key())
            if pred is None:
                break
            tok, p = pred
            if p < self.threshold:
                break
            if tok == d.eos_id:
                if not d.eos_legal():
                    break
                out.append(tok)
                break
            if not d.advance(tok):
                break
            out.append(tok)
        self._chain_cache[key] = (self.model.version, list(out))
        return out

    def observe(self, decoder_state_key: StateKey, token_id: int) -> None:
        if self.learn:
            self.model.observe(decoder_state_key, token_id)


def verify_greedy(proposed: List[int], model_argmax: List[int]) -> int:
    """Greedy verification: longest prefix where the proposal equals the
    model's argmax at each position.  Returns number of accepted tokens."""
    n = 0
    for p, m in zip(proposed, model_argmax):
        if p != m:
            break
        n += 1
    return n


def verify_stochastic(proposed: List[int], proposal_probs: List[float],
                      model_probs_at: List[float], uniforms: List[float]
                      ) -> int:
    """Speculative-sampling acceptance rule (Chen et al., 2023):
    accept token i iff u_i < min(1, p_model(tok_i) / q(tok_i)).

    ``proposal_probs`` are q(tok) from the count model; the count model is a
    point-mass-ish proposal, so this keeps the output distribution unbiased
    for temperature sampling.
    """
    n = 0
    for q, p, u in zip(proposal_probs, model_probs_at, uniforms):
        if q <= 0.0:
            break
        if u < min(1.0, p / q):
            n += 1
        else:
            break
    return n
