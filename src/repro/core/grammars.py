"""The constraining workloads of the paper (App. C/D) in our EBNF format.

Each function returns grammar source text; ``load(name)`` parses it.
These drive Table 2 (GSM8K / CoNLL JSON schemas), Table 3 (JSON, JSON
w/schema, C, XML w/schema, fixed template) and the benchmarks.
"""
from __future__ import annotations

from repro.core.grammar import Grammar, parse_grammar

_STRING = r'/"([^"\\]|\\(["\\\/bfnrt]|u[0-9a-fA-F]{4}))*"/'
_NUMBER = r'/(-)?([0-9]|[1-9][0-9]*)(\.[0-9]+)?([eE][-+]?[0-9]+)?/'


def json_grammar() -> str:
    """Basic JSON (App. C Listing 3)."""
    return rf'''
start: value
value: object | array | STRING | NUMBER | BOOL | NULL
object: "{{" (pair ("," pair)*)? "}}"
pair: STRING ":" value
array: "[" (value ("," value)*)? "]"
STRING: {_STRING}
NUMBER: {_NUMBER}
BOOL: /true|false/
NULL: "null"
WS: /[ \t\n\r]+/
%ignore WS
'''


def gsm8k_json_grammar() -> str:
    """Guided math reasoning schema (App. C Listing 4):
    {"thoughts": [{"step": s, "calculation": s, "result": n}, ...],
     "answer": n}
    """
    return rf'''
start: object
object: "{{" "\"thoughts\"" ":" "[" thought ("," thought)* "]" "," "\"answer\"" ":" NUMBER "}}"
thought: "{{" "\"step\"" ":" STRING "," "\"calculation\"" ":" STRING "," "\"result\"" ":" NUMBER "}}"
STRING: {_STRING}
NUMBER: {_NUMBER}
WS: /[ \t\n\r]+/
%ignore WS
'''


def conll_json_grammar() -> str:
    """CoNLL2003 NER output schema (App. D Listing 9)."""
    return rf'''
start: "{{" "\"entities\"" ":" "[" (entity ("," entity)*)? "]" "}}"
entity: "{{" "\"text\"" ":" STRING "," "\"type\"" ":" etype "}}"
etype: "\"PER\"" | "\"ORG\"" | "\"LOC\"" | "\"MISC\""
STRING: {_STRING}
WS: /[ \t\n\r]+/
%ignore WS
'''


def c_grammar() -> str:
    """Simple C subset (App. C Listing 5)."""
    return r'''
start: declaration+
declaration: datatype IDENT "(" parameter? ")" "{" statement* "}"
datatype: "int" | "float" | "char"
parameter: datatype IDENT
statement: datatype IDENT "=" expression ";"
         | datatype IDENT "[" expression "]" ("=" expression)? ";"
         | IDENT "=" expression ";"
         | IDENT "(" arglist? ")" ";"
         | "return" expression ";"
         | "while" "(" condition ")" "{" statement* "}"
         | "for" "(" forinit ";" condition ";" forupdate ")" "{" statement* "}"
         | "if" "(" condition ")" "{" statement* "}" ("else" "{" statement* "}")?
forinit: datatype IDENT "=" expression | IDENT "=" expression
forupdate: IDENT "=" expression
condition: expression relop expression
relop: "<=" | "<" | "==" | "!=" | ">=" | ">"
expression: term (addop term)*
addop: "+" | "-"
term: factor (mulop factor)*
mulop: "*" | "/"
factor: IDENT | NUMBER | "-" factor | IDENT "(" arglist? ")"
      | "(" expression ")" | IDENT "[" expression "]" | STRING
arglist: expression ("," expression)*
IDENT: /[a-zA-Z_][a-zA-Z_0-9]*/
NUMBER: /[0-9]+/
STRING: /"([^"\\]|\\(["\\\/bfnrt]|u[0-9a-fA-F]{4}))*"/
COMMENT: /\/\/[^\n]*\n/
WS: /[ \t\n]+/
%ignore WS
%ignore COMMENT
'''


def xml_schema_grammar() -> str:
    """XML person schema (App. C Listing 6)."""
    return r'''
start: person
person: "<person>" nameattr ageattr jobattr friends? "</person>"
nameattr: "<name>" TEXT "</name>"
ageattr: "<age>" TEXT "</age>"
jobattr: "<job>" jobtitle jobsalary "</job>"
jobtitle: "<title>" TEXT "</title>"
jobsalary: "<salary>" TEXT "</salary>"
friends: "<friends>" person+ "</friends>"
TEXT: /[^<]+/
WS: /[ \t\n]+/
%ignore WS
'''


def rpg_template_grammar() -> str:
    """Fixed-template RPG character sheet (App. C Listing 7) as a CFG —
    the schema pins field order and some literal values."""
    return rf'''
start: "{{" idp "," descp "," namep "," agep "," armorp "," weaponp "," classp "," mantrap "," strengthp "," itemsp "}}"
idp: "\"id\"" ":" NUMBER
descp: "\"description\"" ":" "\"A nimble fighter\""
namep: "\"name\"" ":" STRING
agep: "\"age\"" ":" NUMBER
armorp: "\"armor\"" ":" ("\"leather\"" | "\"chainmail\"" | "\"plate\"")
weaponp: "\"weapon\"" ":" ("\"sword\"" | "\"axe\"" | "\"bow\"")
classp: "\"class\"" ":" STRING
mantrap: "\"mantra\"" ":" STRING
strengthp: "\"strength\"" ":" NUMBER
itemsp: "\"items\"" ":" "[" STRING "," STRING "," STRING "]"
STRING: /"[^\n\r"]+"/
NUMBER: /[0-9]+/
WS: /[ \t\n]+/
%ignore WS
'''


def arithmetic_grammar() -> str:
    """The running example of Fig. 3: E -> int | (E) | E + E."""
    return r'''
start: e
e: INT | "(" e ")" | e "+" e
INT: /[1-9][0-9]*|0+/
WS: /[ ]+/
%ignore WS
'''


GRAMMARS = {
    "json": json_grammar,
    "json_gsm8k": gsm8k_json_grammar,
    "json_conll": conll_json_grammar,
    "c": c_grammar,
    "xml_schema": xml_schema_grammar,
    "template_rpg": rpg_template_grammar,
    "arith": arithmetic_grammar,
}


def load(name: str) -> Grammar:
    return parse_grammar(GRAMMARS[name]())
