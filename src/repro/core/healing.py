"""Token healing (§3.5 last paragraph; Lundberg & Ribeiro).

At the prompt/generation boundary the prompt's final tokens may have split
a unit the model would rather express with a bridge token (e.g. prompt ends
with ``{"`` but the model's preferred continuation token is ``{"a``).
GUIDANCE heals this by truncating the prompt to an earlier token boundary
and *forcing the stripped text as a prefix of the generation* — the model
re-tokenizes the boundary freely, bridge tokens included.

The constraint is therefore  L(G) ∩ prefix·Σ*  (the healed output must BE a
grammar string AND start with the stripped text).  ``HealedDecoder`` is the
product checker: while the prefix is being consumed, a token must (a) agree
byte-wise with the remaining prefix and (b) advance the underlying DOMINO
decoder; afterwards it delegates entirely.  The paper implements this by
recompiling the grammar with a forced prefix — the product construction
avoids the recompile (the subterminal trees are shared unchanged), which is
an improvement we record in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.domino import DominoDecoder
from repro.core.grammar import Grammar
from repro.core.trees import TreeCache, VocabTrie


def heal_prompt(prompt_ids: List[int], vocab: Sequence[Optional[bytes]],
                n_strip: int = 1) -> Tuple[List[int], str]:
    """Strip the last ``n_strip`` tokens off the prompt.

    Returns (truncated_prompt_ids, stripped_text).
    """
    if n_strip <= 0 or len(prompt_ids) == 0:
        return list(prompt_ids), ""
    n_strip = min(n_strip, len(prompt_ids))
    kept = list(prompt_ids[:-n_strip])
    stripped = b"".join(vocab[t] or b"" for t in prompt_ids[-n_strip:])
    return kept, stripped.decode("utf-8", errors="surrogateescape")


class HealedDecoder:
    """DOMINO decoder whose output is additionally forced to start with
    ``prefix_text``.  API-compatible subset of DominoDecoder (mask /
    check_token / advance / eos_legal)."""

    def __init__(self, grammar: Grammar, vocab: Sequence[Optional[bytes]],
                 eos_id: int, prefix_text: str,
                 k: Optional[int] = None,
                 tree_cache: Optional[TreeCache] = None):
        self.inner = DominoDecoder(grammar, vocab, eos_id, k=k,
                                   tree_cache=tree_cache)
        self.vocab = list(vocab)
        self.eos_id = eos_id
        self.rest = prefix_text.encode("utf-8")
        self._trie = self.inner.trees.trie

    # -- helpers ---------------------------------------------------------------

    def _prefix_ok(self, data: bytes) -> bool:
        n = min(len(data), len(self.rest))
        return data[:n] == self.rest[:n]

    def _candidates(self) -> List[int]:
        """Tokens compatible with the remaining forced prefix."""
        out: List[int] = []
        node = self._trie
        # tokens that are a prefix of rest
        for b in self.rest:
            node = node.children.get(b)
            if node is None:
                break
            out.extend(node.token_ids)
        else:
            # tokens that extend past the full rest (bridge over boundary)
            stack = [node]
            while stack:
                n = stack.pop()
                for c in n.children.values():
                    out.extend(c.token_ids)
                    stack.append(c)
        return out

    # -- DominoDecoder API -------------------------------------------------------

    @property
    def finished(self) -> bool:
        return self.inner.finished

    def mask(self, k: Optional[int] = None) -> np.ndarray:
        if not self.rest:
            return self.inner.mask(k)
        out = np.zeros(len(self.vocab), dtype=bool)
        for t in self._candidates():
            if self.inner.check_token(t):
                out[t] = True
        return out

    def mask_bits(self, k: Optional[int] = None) -> np.ndarray:
        """Packed mask: delegate to the (memoized) inner decoder once the
        forced prefix is consumed; while the prefix is live, pack the
        candidate scan (few tokens, no tree walk — not worth a memo)."""
        if not self.rest:
            return self.inner.mask_bits(k)
        from repro.core import bitmask
        return bitmask.pack_bool(self.mask(k))

    @property
    def n_mask_memo_hits(self) -> int:
        return self.inner.n_mask_memo_hits

    def check_token(self, token_id: int) -> bool:
        if not self.rest:
            return self.inner.check_token(token_id)
        data = self.vocab[token_id]
        if token_id == self.eos_id or not data:
            return False
        return self._prefix_ok(data) and self.inner.check_token(token_id)

    def advance(self, token_id: int) -> bool:
        if self.rest:
            data = self.vocab[token_id]
            if token_id == self.eos_id or not data \
                    or not self._prefix_ok(data):
                return False
            if not self.inner.advance(token_id):
                return False
            self.rest = self.rest[len(data):]
            return True
        return self.inner.advance(token_id)

    def eos_legal(self) -> bool:
        return not self.rest and self.inner.eos_legal()

    def state_key(self):
        return (len(self.rest),) + self.inner.state_key()

    def clone(self) -> "HealedDecoder":
        h = HealedDecoder.__new__(HealedDecoder)
        h.inner = self.inner.clone()
        h.vocab = self.vocab
        h.eos_id = self.eos_id
        h.rest = self.rest
        h._trie = self._trie
        return h
